//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The offline build environment carries no registry, so the subset of
//! `anyhow` this codebase actually uses is reimplemented here and wired
//! in as a path dependency (`rust/Cargo.toml`): the [`Error`] type, the
//! [`Result`] alias, the [`Context`] extension trait (on both `Result`
//! and `Option`), and the [`anyhow!`]/[`bail!`] macros. Error state is a
//! single pre-rendered message string — no backtraces, no downcasting —
//! which is all the callers in this repository rely on.
//!
//! Deliberate compatibility choices mirrored from the real crate:
//! - `Error` does **not** implement `std::error::Error`, so the blanket
//!   `From<E: std::error::Error>` impl coexists with the reflexive
//!   `From<Error>` (this is what makes `?` work for both concrete errors
//!   and `anyhow::Result` chains).
//! - `{e}` and `{e:#}` both render the full context chain (the real
//!   crate renders only the outermost context for `{}`; everything here
//!   treats the message as opaque text, so the difference is harmless).

use std::fmt;

/// A type-erased error: a rendered message with accumulated context.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (outermost first, like anyhow's chain).
    fn wrap<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Flatten the source chain into the rendered message so nothing
        // is lost by dropping the structured chain.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_on_std_error() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("deep failure {}", 7)
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: deep failure 7");
        // Alternate formatting renders the same chain.
        assert_eq!(format!("{e:#}"), "outer: deep failure 7");
    }

    #[test]
    fn macros_accept_captures_and_args() {
        let x = 3;
        assert_eq!(anyhow!("v={x}").to_string(), "v=3");
        assert_eq!(anyhow!("v={}", x + 1).to_string(), "v=4");
    }
}
