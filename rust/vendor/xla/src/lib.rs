//! Stub of the `xla` (xla-rs) PJRT binding for offline builds.
//!
//! The real crate links against a native `xla_extension` build, which is
//! not present in this container. This stub provides the exact API
//! surface `crate::runtime` compiles against; every entry point returns
//! [`XlaError`] at runtime, so `PjrtEngine::load` fails gracefully with
//! a clear message and the native engine remains the serving backend.
//! The PJRT integration tests (`rust/tests/pjrt_parity.rs`) self-skip
//! when the AOT artifacts are absent, so this stub never executes under
//! `cargo test` on a fresh clone.
//!
//! Swapping in the real binding is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path dependency at the real
//! crate); no source changes are required.

/// Error type for all stub operations.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built against the in-tree `xla` stub \
         (no native xla_extension in this environment); use the native \
         engine instead"
            .to_string(),
    )
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// PJRT client handle (stub).
pub struct PjRtClient;

/// Device buffer handle (stub).
pub struct PjRtBuffer;

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

/// Parsed HLO module (stub).
pub struct HloModuleProto;

/// XLA computation wrapper (stub).
pub struct XlaComputation;

/// Host-side literal (stub).
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(format!("{e:?}").contains("unavailable"));
    }
}
