//! Request/response types for the serving coordinator.

use super::error::ServeError;
use crate::util::json::Json;

/// A generation request as submitted by a client.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; > 0 = temperature sampling (seeded, reproducible).
    pub temperature: f32,
    /// Restrict temperature sampling to the k highest-logit tokens
    /// (`None` = full softmax; ignored when greedy).
    pub top_k: Option<usize>,
    /// Nucleus sampling: keep the smallest top-probability set with
    /// cumulative mass >= p (`None` = no cut; composes with `top_k`;
    /// ignored when greedy).
    pub top_p: Option<f32>,
    pub seed: u64,
    /// Speculative decoding opt-out: `false` forces vanilla one-token
    /// decode rounds even when the coordinator speculates. Speculation
    /// is lossless in every decoding mode — greedy requests verify by
    /// exact argmax matching, sampled requests (`temperature > 0`,
    /// with or without `top_k`/`top_p`) by rejection sampling against
    /// the request's own seeded sampler — so the only reason to opt
    /// out is to reclaim the verify pass's extra KV headroom or
    /// measure the vanilla baseline.
    pub speculation: bool,
    /// Stop generation at the first '.' after this many tokens (0 = off).
    pub stop_at_sentence: bool,
    /// Scheduling priority: when the KV pool runs dry the
    /// lowest-priority running sequence is preempted first (ties break
    /// toward the most recently admitted). Default 0.
    pub priority: i32,
    /// Wall-clock budget in milliseconds, measured from intake. `None`
    /// means "no client deadline"; the server's `--request-timeout-ms`
    /// (if set) still applies, and the effective deadline is whichever
    /// is tighter. Expiry mid-generation returns the partial text under
    /// `Done{reason: DeadlineExceeded}`.
    pub deadline_ms: Option<u64>,
    /// Opt into per-request trace timelines (`util/trace.rs`): the
    /// coordinator records lifecycle events and phase timings for this
    /// request, the terminal `Done` carries a `timing` breakdown, and
    /// the finished timeline becomes queryable via the `trace` op.
    /// Tracing never changes the generated tokens.
    pub trace: bool,
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            prompt: String::new(),
            max_new_tokens: 32,
            temperature: 0.0,
            top_k: None,
            top_p: None,
            seed: 0,
            speculation: true,
            stop_at_sentence: false,
            priority: 0,
            deadline_ms: None,
            trace: false,
        }
    }
}

impl GenRequest {
    pub fn from_json(j: &Json) -> Self {
        let mut r = GenRequest::default();
        if let Some(p) = j.get("prompt").and_then(|v| v.as_str()) {
            r.prompt = p.to_string();
        }
        if let Some(m) = j.get("max_tokens").and_then(|v| v.as_u64()) {
            r.max_new_tokens = m as usize;
        }
        if let Some(t) = j.get("temperature").and_then(|v| v.as_f64()) {
            r.temperature = t as f32;
        }
        if let Some(k) = j.get("top_k").and_then(|v| v.as_u64()) {
            if k > 0 {
                r.top_k = Some(k as usize);
            }
        }
        if let Some(p) = j.get("top_p").and_then(|v| v.as_f64()) {
            // p >= 1 keeps everything and p <= 0 is degenerate: both
            // mean "no nucleus cut".
            if p > 0.0 && p < 1.0 {
                r.top_p = Some(p as f32);
            }
        }
        if let Some(s) = j.get("seed").and_then(|v| v.as_u64()) {
            r.seed = s;
        }
        if let Some(s) = j.get("speculation").and_then(|v| v.as_bool()) {
            r.speculation = s;
        }
        if let Some(s) = j.get("stop_at_sentence").and_then(|v| v.as_bool()) {
            r.stop_at_sentence = s;
        }
        if let Some(p) = j.get("priority").and_then(|v| v.as_f64()) {
            r.priority = p as i32;
        }
        if let Some(d) = j.get("deadline_ms").and_then(|v| v.as_u64()) {
            // 0 (and absence) mean "no client deadline".
            if d > 0 {
                r.deadline_ms = Some(d);
            }
        }
        if let Some(t) = j.get("trace").and_then(|v| v.as_bool()) {
            r.trace = t;
        }
        r
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopCondition,
    ContextFull,
    Cancelled,
    /// The request's wall-clock deadline expired mid-generation; the
    /// `Done` event carries whatever text was produced so far.
    DeadlineExceeded,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopCondition => "stop",
            FinishReason::ContextFull => "context_full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
        }
    }
}

/// Streamed events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    /// Liveness probe: carries no data and is never serialized to the
    /// wire. The coordinator sends one to every active sequence each
    /// round — at admission, per prefill chunk, and per decode round —
    /// so a dropped receiver cancels the request within one round
    /// instead of decoding on to `max_tokens`.
    Heartbeat,
    /// One generated token (id + decoded text fragment).
    Token { token: u32, text: String },
    /// Generation finished (possibly with partial text, e.g. when the
    /// request's deadline expired mid-stream).
    Done {
        reason: FinishReason,
        text: String,
        prompt_tokens: usize,
        gen_tokens: usize,
        ttft_ms: f64,
        total_ms: f64,
        /// Phase breakdown for traced requests (`GenRequest::trace`):
        /// the `timing` object from `util/trace.rs` (`queue_ms`,
        /// `prefill_ms`, `decode_ms`, `spec_saved_tokens`,
        /// `preemptions`, per-phase round counts). `None` when the
        /// request did not opt in.
        timing: Option<Json>,
    },
    /// The request failed before producing a normal terminal: shed at
    /// admission (overloaded / shutting down), expired while still
    /// queued, or implicated in repeated engine failures. Terminal —
    /// exactly one of `Done` or `Error` ends every accepted stream.
    Error(ServeError),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_from_json() {
        let j = Json::parse(
            r#"{"prompt":"hi","max_tokens":5,"temperature":0.7,"top_k":40,"top_p":0.9,"seed":9,"priority":2,"speculation":false}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&j);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 5);
        assert!((r.temperature - 0.7).abs() < 1e-6);
        assert_eq!(r.top_k, Some(40));
        assert!((r.top_p.unwrap() - 0.9).abs() < 1e-6);
        assert_eq!(r.seed, 9);
        assert_eq!(r.priority, 2);
        assert!(!r.speculation);
    }

    #[test]
    fn defaults_applied() {
        let r = GenRequest::from_json(&Json::parse("{}").unwrap());
        assert_eq!(r.max_new_tokens, 32);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.top_k, None);
        assert_eq!(r.top_p, None);
        assert_eq!(r.priority, 0);
        assert!(r.speculation, "speculation is opt-out");
    }

    #[test]
    fn deadline_ms_parses_and_zero_means_none() {
        let r = GenRequest::from_json(&Json::parse(r#"{"deadline_ms":250}"#).unwrap());
        assert_eq!(r.deadline_ms, Some(250));
        let r = GenRequest::from_json(&Json::parse(r#"{"deadline_ms":0}"#).unwrap());
        assert_eq!(r.deadline_ms, None);
        let r = GenRequest::from_json(&Json::parse("{}").unwrap());
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn trace_parses_and_defaults_off() {
        let r = GenRequest::from_json(&Json::parse(r#"{"trace":true}"#).unwrap());
        assert!(r.trace);
        let r = GenRequest::from_json(&Json::parse("{}").unwrap());
        assert!(!r.trace, "tracing is opt-in");
    }

    #[test]
    fn top_k_zero_means_unrestricted() {
        let r = GenRequest::from_json(&Json::parse(r#"{"top_k":0}"#).unwrap());
        assert_eq!(r.top_k, None);
    }

    #[test]
    fn degenerate_top_p_means_unrestricted() {
        for raw in [r#"{"top_p":0}"#, r#"{"top_p":1.0}"#, r#"{"top_p":1.5}"#] {
            let r = GenRequest::from_json(&Json::parse(raw).unwrap());
            assert_eq!(r.top_p, None, "{raw}");
        }
    }
}
