//! Request/response types for the serving coordinator.

use crate::util::json::Json;

/// A generation request as submitted by a client.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; > 0 = temperature sampling (seeded, reproducible).
    pub temperature: f32,
    pub seed: u64,
    /// Stop generation at the first '.' after this many tokens (0 = off).
    pub stop_at_sentence: bool,
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            prompt: String::new(),
            max_new_tokens: 32,
            temperature: 0.0,
            seed: 0,
            stop_at_sentence: false,
        }
    }
}

impl GenRequest {
    pub fn from_json(j: &Json) -> Self {
        let mut r = GenRequest::default();
        if let Some(p) = j.get("prompt").and_then(|v| v.as_str()) {
            r.prompt = p.to_string();
        }
        if let Some(m) = j.get("max_tokens").and_then(|v| v.as_u64()) {
            r.max_new_tokens = m as usize;
        }
        if let Some(t) = j.get("temperature").and_then(|v| v.as_f64()) {
            r.temperature = t as f32;
        }
        if let Some(s) = j.get("seed").and_then(|v| v.as_u64()) {
            r.seed = s;
        }
        if let Some(s) = j.get("stop_at_sentence").and_then(|v| v.as_bool()) {
            r.stop_at_sentence = s;
        }
        r
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopCondition,
    ContextFull,
    Cancelled,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopCondition => "stop",
            FinishReason::ContextFull => "context_full",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Streamed events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    /// One generated token (id + decoded text fragment).
    Token { token: u32, text: String },
    /// Generation finished.
    Done {
        reason: FinishReason,
        text: String,
        prompt_tokens: usize,
        gen_tokens: usize,
        ttft_ms: f64,
        total_ms: f64,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_from_json() {
        let j = Json::parse(r#"{"prompt":"hi","max_tokens":5,"temperature":0.7,"seed":9}"#)
            .unwrap();
        let r = GenRequest::from_json(&j);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 5);
        assert!((r.temperature - 0.7).abs() < 1e-6);
        assert_eq!(r.seed, 9);
    }

    #[test]
    fn defaults_applied() {
        let r = GenRequest::from_json(&Json::parse("{}").unwrap());
        assert_eq!(r.max_new_tokens, 32);
        assert_eq!(r.temperature, 0.0);
    }
}
