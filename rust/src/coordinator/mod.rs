//! The serving coordinator: request router, continuous batcher, KV-cache
//! manager (the vLLM-router-shaped L3 of DESIGN.md §2).
//!
//! One worker thread owns the inference [`Engine`] (native or PJRT) and
//! runs the scheduling loop:
//!
//! 1. **Admission** — waiting requests are admitted while the batch has
//!    room *and* the [`kvpool::KvPool`] can reserve their worst-case KV
//!    footprint (the §7.3 memory economics as policy).
//! 2. **Chunked prefill** — admitted prompts are ingested
//!    `prefill_chunk` tokens per round, interleaved with decode so a
//!    long prompt cannot starve running generations (continuous
//!    batching).
//! 3. **Decode round** — every running sequence advances one token
//!    (the MMVQ path), streams it to its client, and is retired on its
//!    stop condition, releasing budget immediately.
//!
//! Clients talk to the worker over channels; each request gets an
//! unbounded event stream so a slow client never blocks the batch.

pub mod kvpool;
pub mod metrics;
pub mod request;
pub mod sampler;

use crate::eval::{perplexity, PplReport};
use crate::model::native::Engine;
use crate::model::{tokenizer, KvCache};
use crate::util::json::Json;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

pub use request::{Event, FinishReason, GenRequest};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max concurrently decoding sequences.
    pub max_batch: usize,
    /// KV budget in bytes (admission control).
    pub kv_budget_bytes: usize,
    /// Prompt tokens ingested per scheduling round per sequence.
    pub prefill_chunk: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: 256 << 20,
            prefill_chunk: 32,
        }
    }
}

enum Cmd {
    Generate(GenRequest, Sender<Event>),
    Score(String, Sender<PplReport>),
    Stats(Sender<Json>),
    Shutdown,
}

/// Handle to the coordinator worker.
pub struct Coordinator {
    tx: Sender<Cmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct ActiveSeq {
    req: GenRequest,
    events: Sender<Event>,
    cache: KvCache,
    kv_bytes: usize,
    sampler: sampler::Sampler,
    prompt: Vec<u32>,
    prefilled: usize,
    /// Next token to feed to decode (sampled but not yet consumed).
    pending: Option<u32>,
    generated: Vec<u32>,
    submitted: Instant,
    ttft_ms: Option<f64>,
}

impl Coordinator {
    pub fn new(engine: Box<dyn Engine>, cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = channel::<Cmd>();
        let handle = std::thread::Builder::new()
            .name("itq3s-coordinator".into())
            .spawn(move || worker(engine, cfg, rx))
            .expect("spawn coordinator");
        Coordinator { tx, handle: Some(handle) }
    }

    /// Submit a generation request; events stream on the receiver.
    pub fn generate(&self, req: GenRequest) -> Receiver<Event> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Cmd::Generate(req, tx));
        rx
    }

    /// Convenience: run a request to completion, returning (text, done).
    pub fn generate_collect(&self, req: GenRequest) -> (String, Option<Event>) {
        let rx = self.generate(req);
        let mut text = String::new();
        let mut done = None;
        for ev in rx.iter() {
            match ev {
                Event::Token { text: ref t, .. } => text.push_str(t),
                Event::Done { .. } => {
                    done = Some(ev);
                    break;
                }
            }
        }
        (text, done)
    }

    /// Synchronous perplexity scoring through the worker's engine.
    pub fn score(&self, text: String) -> Result<PplReport> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Score(text, tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Stats(tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(engine: Box<dyn Engine>, cfg: CoordinatorConfig, rx: Receiver<Cmd>) {
    let model_cfg = engine.config().clone();
    let mut pool = kvpool::KvPool::new(model_cfg.clone(), cfg.kv_budget_bytes);
    let mut metrics = metrics::Metrics::new();
    let mut waiting: std::collections::VecDeque<(GenRequest, Sender<Event>)> =
        std::collections::VecDeque::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // ---- 0. intake ----------------------------------------------
        loop {
            let cmd = if active.is_empty() && waiting.is_empty() {
                // Idle: block (with timeout so shutdown-by-drop works).
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(c) => c,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match cmd {
                Cmd::Generate(req, tx) => {
                    metrics.requests_submitted += 1;
                    waiting.push_back((req, tx));
                }
                Cmd::Score(text, tx) => {
                    let _ = tx.send(perplexity(engine.as_ref(), &text));
                }
                Cmd::Stats(tx) => {
                    metrics.kv_peak_bytes = pool.peak_bytes;
                    let _ = tx.send(metrics.snapshot());
                }
                Cmd::Shutdown => {
                    shutdown = true;
                }
            }
        }
        if shutdown {
            break;
        }

        // ---- 1. admission -------------------------------------------
        while active.len() < cfg.max_batch {
            let Some((req, tx)) = waiting.pop_front() else { break };
            let mut prompt = tokenizer::encode(&req.prompt);
            // Truncate over-long prompts from the front, keeping BOS.
            let ctx_cap = model_cfg.max_seq.saturating_sub(2);
            if prompt.len() > ctx_cap {
                let keep = ctx_cap - 1;
                let tail = prompt.split_off(prompt.len() - keep);
                prompt = std::iter::once(tokenizer::BOS).chain(tail).collect();
            }
            let worst = (prompt.len() + req.max_new_tokens).min(model_cfg.max_seq);
            match pool.admit(worst) {
                Some((cache, kv_bytes)) => {
                    let sampler = sampler::Sampler::new(req.temperature, req.seed);
                    active.push(ActiveSeq {
                        req,
                        events: tx,
                        cache,
                        kv_bytes,
                        sampler,
                        prompt,
                        prefilled: 0,
                        pending: None,
                        generated: Vec::new(),
                        submitted: Instant::now(),
                        ttft_ms: None,
                    });
                }
                None => {
                    // No budget: requeue and stop admitting this round.
                    waiting.push_front((req, tx));
                    break;
                }
            }
        }
        if active.is_empty() {
            continue;
        }
        metrics.batch_occupancy.push(active.len() as f64);

        // ---- 2. chunked prefill --------------------------------------
        for seq in active.iter_mut() {
            if seq.prefilled < seq.prompt.len() {
                let end = (seq.prefilled + cfg.prefill_chunk).min(seq.prompt.len());
                let chunk = &seq.prompt[seq.prefilled..end];
                let logits = engine.prefill(&mut seq.cache, chunk);
                metrics.prompt_tokens += chunk.len() as u64;
                metrics.prefill_tokens_per_round.push(chunk.len() as f64);
                seq.prefilled = end;
                if seq.prefilled == seq.prompt.len() {
                    // Prompt complete: sample the first token.
                    let tok = seq.sampler.sample(logits.row(chunk.len() - 1));
                    seq.ttft_ms =
                        Some(seq.submitted.elapsed().as_secs_f64() * 1000.0);
                    metrics.ttft_ms.push(seq.ttft_ms.unwrap());
                    seq.pending = Some(tok);
                }
            }
        }

        // ---- 3. decode round -----------------------------------------
        let mut finished: Vec<usize> = Vec::new();
        for (i, seq) in active.iter_mut().enumerate() {
            let Some(tok) = seq.pending else { continue };
            // Deliver the sampled token.
            seq.generated.push(tok);
            metrics.gen_tokens += 1;
            let frag = tokenizer::decode(&[tok]);
            let delivered =
                seq.events.send(Event::Token { token: tok, text: frag.clone() }).is_ok();
            // Stop conditions.
            let stop_hit = seq.req.stop_at_sentence && frag == ".";
            let reason = if !delivered {
                Some(FinishReason::Cancelled)
            } else if seq.generated.len() >= seq.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if seq.cache.len() + 1 >= seq.cache.max_seq {
                Some(FinishReason::ContextFull)
            } else if stop_hit {
                Some(FinishReason::StopCondition)
            } else {
                None
            };
            if let Some(reason) = reason {
                let text = tokenizer::decode(&seq.generated);
                let _ = seq.events.send(Event::Done {
                    reason,
                    text,
                    prompt_tokens: seq.prompt.len(),
                    gen_tokens: seq.generated.len(),
                    ttft_ms: seq.ttft_ms.unwrap_or(0.0),
                    total_ms: seq.submitted.elapsed().as_secs_f64() * 1000.0,
                });
                metrics.requests_finished += 1;
                finished.push(i);
                continue;
            }
            // Advance one decode step.
            let t0 = Instant::now();
            let logits = engine.decode_step(&mut seq.cache, tok);
            metrics.decode_step_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
            seq.pending = Some(seq.sampler.sample(&logits));
        }

        // ---- 4. retire finished --------------------------------------
        for &i in finished.iter().rev() {
            let seq = active.swap_remove(i);
            pool.release(seq.cache, seq.kv_bytes);
        }
    }

    // Drain: cancel anything still queued or running.
    for seq in active {
        let _ = seq.events.send(Event::Done {
            reason: FinishReason::Cancelled,
            text: tokenizer::decode(&seq.generated),
            prompt_tokens: seq.prompt.len(),
            gen_tokens: seq.generated.len(),
            ttft_ms: seq.ttft_ms.unwrap_or(0.0),
            total_ms: seq.submitted.elapsed().as_secs_f64() * 1000.0,
        });
    }
    for (_, tx) in waiting {
        let _ = tx.send(Event::Done {
            reason: FinishReason::Cancelled,
            text: String::new(),
            prompt_tokens: 0,
            gen_tokens: 0,
            ttft_ms: 0.0,
            total_ms: 0.0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, ModelConfig, NativeEngine};

    fn coordinator(max_batch: usize, kv_budget: usize) -> Coordinator {
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch,
                kv_budget_bytes: kv_budget,
                prefill_chunk: 8,
            },
        )
    }

    #[test]
    fn single_request_completes() {
        let c = coordinator(4, 64 << 20);
        let (text, done) = c.generate_collect(GenRequest {
            prompt: "hello".into(),
            max_new_tokens: 6,
            ..Default::default()
        });
        let Some(Event::Done { reason, gen_tokens, prompt_tokens, .. }) = done else {
            panic!("no done event");
        };
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(gen_tokens, 6);
        assert_eq!(prompt_tokens, 6); // BOS + 5 bytes
        // A random model emits arbitrary bytes; decode is lossy, so only
        // the token count is meaningful here.
        assert_eq!(text.chars().count(), 6);
        c.shutdown();
    }

    #[test]
    fn greedy_is_deterministic_across_batching() {
        // The same greedy request must yield identical text whether it
        // runs alone or concurrently with others — batching must not
        // change results (core continuous-batching invariant).
        let solo = coordinator(1, 64 << 20);
        let req = GenRequest { prompt: "the ".into(), max_new_tokens: 8, ..Default::default() };
        let (text_solo, _) = solo.generate_collect(req.clone());
        solo.shutdown();

        let busy = coordinator(4, 64 << 20);
        let rx1 = busy.generate(GenRequest {
            prompt: "other prompt entirely".into(),
            max_new_tokens: 8,
            ..Default::default()
        });
        let (text_busy, _) = busy.generate_collect(req);
        for _ in rx1.iter() {} // drain
        busy.shutdown();
        assert_eq!(text_solo, text_busy);
    }

    #[test]
    fn many_concurrent_requests_all_finish() {
        let c = coordinator(4, 64 << 20);
        let rxs: Vec<_> = (0..10)
            .map(|i| {
                c.generate(GenRequest {
                    prompt: format!("prompt number {i}"),
                    max_new_tokens: 4 + (i % 3),
                    ..Default::default()
                })
            })
            .collect();
        let mut finished = 0;
        for rx in rxs {
            for ev in rx.iter() {
                if let Event::Done { reason, gen_tokens, .. } = ev {
                    assert_eq!(reason, FinishReason::MaxTokens);
                    assert!(gen_tokens >= 4);
                    finished += 1;
                    break;
                }
            }
        }
        assert_eq!(finished, 10);
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(10));
        assert!(stats.get("gen_tokens").unwrap().as_u64().unwrap() >= 40);
        c.shutdown();
    }

    #[test]
    fn tiny_kv_budget_serializes_but_completes() {
        // Budget for ~1 sequence: requests queue and run one at a time.
        let cfg = ModelConfig::test();
        let one_seq = kvpool::seq_bytes(&cfg, 64);
        let c = coordinator(8, one_seq + 1024);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                c.generate(GenRequest {
                    prompt: "x".into(),
                    max_new_tokens: 3,
                    ..Default::default()
                })
            })
            .collect();
        for rx in rxs {
            let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
            assert!(matches!(
                done,
                Some(Event::Done { reason: FinishReason::MaxTokens, .. })
            ));
        }
        c.shutdown();
    }

    #[test]
    fn dropped_receiver_cancels_sequence() {
        let c = coordinator(2, 64 << 20);
        {
            let _rx = c.generate(GenRequest {
                prompt: "will be cancelled".into(),
                max_new_tokens: 1000, // would run long
                ..Default::default()
            });
            // _rx dropped here
        }
        // A subsequent request still completes promptly.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ok".into(),
            max_new_tokens: 3,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        c.shutdown();
    }

    #[test]
    fn score_through_worker() {
        let c = coordinator(2, 64 << 20);
        let r = c.score("some text to score".into()).unwrap();
        assert!(r.ppl.is_finite() && r.tokens > 0);
        c.shutdown();
    }

    #[test]
    fn context_full_finishes_gracefully() {
        let c = coordinator(1, 64 << 20);
        // max_seq for test config is 64; ask for more than fits.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "abcdefghij".into(),
            max_new_tokens: 500,
            ..Default::default()
        });
        let Some(Event::Done { reason, .. }) = done else { panic!() };
        assert_eq!(reason, FinishReason::ContextFull);
        c.shutdown();
    }
}
