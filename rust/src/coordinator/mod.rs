//! The serving coordinator: request router, continuous batcher, KV-cache
//! manager (the vLLM-router-shaped L3 of DESIGN.md §2).
//!
//! One worker thread owns the inference [`Engine`] (native or PJRT) and
//! runs the scheduling loop:
//!
//! 1. **Admission** — waiting requests are admitted while the batch has
//!    room *and* the paged [`kvpool::KvPool`] has blocks for their
//!    *uncached* prompt span (cached prefixes map shared physical
//!    blocks and skip re-prefill — the §7.3 memory economics as policy).
//! 2. **Capacity / preemption** — each runnable sequence's next chunk is
//!    guaranteed blocks up front; when the pool runs dry (after prefix-
//!    cache LRU eviction) the lowest-priority running sequence is
//!    preempted back to the waiting queue, its prefix retained in the
//!    cache so re-admission skips the re-prefill.
//! 3. **Chunked prefill** — admitted prompts are ingested
//!    `prefill_chunk` tokens per round, interleaved with decode so a
//!    long prompt cannot starve running generations. A heartbeat probes
//!    the client first, so a dropped receiver cancels *before* the next
//!    prefill round is burned.
//! 4. **Decode round** — all running sequences advance one token in a
//!    single fused [`Engine::decode_batch`] pass (each weight block
//!    unpacked once for the whole batch — the batched-MMQ scheduling
//!    that turns occupancy into per-token latency), stream to their
//!    clients, and are retired on their stop conditions, releasing
//!    blocks immediately (whole-block prefixes stay cached for reuse).
//!    Sequences may instead take a **speculative** round
//!    (`spec_draft_len > 0`): a [`crate::spec::Drafter`] guesses the
//!    next tokens, one multi-position verify pass scores them all
//!    through the same fused GEMMs, the accepted run streams out in a
//!    single round, and the rejected suffix's KV is rolled back
//!    ([`kvpool::KvPool::truncate`]). Acceptance runs the
//!    rejection-sampling loop of [`crate::spec::spec_step_sampled`]
//!    against the sequence's own seeded sampler, so speculation is
//!    lossless for greedy *and* sampled (temperature/top-k/top-p)
//!    requests alike — for the point-mass drafters it is same-seed
//!    token-identical to vanilla rounds, not merely
//!    distribution-preserving.
//!
//! Clients talk to the worker over channels; each request gets an
//! unbounded event stream so a slow client never blocks the batch.
//!
//! **Data-parallel replicas.** The coordinator can drive several
//! engine replicas ([`Coordinator::new_replicated`], `serve
//! --replicas N`). One dispatcher thread owns intake, the shared
//! admission queue, and placement: a new request lands on the replica
//! whose prefix cache already holds the longest prefix of its prompt
//! (a read-only probe — no LRU bump, no stats), falling back to the
//! least-loaded replica. Each replica owns an equal share of the KV
//! byte budget and runs its own scheduling round — concurrently under
//! `std::thread::scope` when N > 1, inline on the dispatcher thread
//! when N = 1 (exactly the single-engine behavior, token-identically).
//! The round stays the panic isolation domain *per replica*: one
//! replica's engine panic restarts only that replica, and its
//! surviving sequences requeue through the shared queue, free to land
//! on a healthy replica. Per-round prefill ingestion is bounded by
//! [`CoordinatorConfig::prefill_round_budget`] so a flood of long
//! fresh prompts cannot stretch a replica's round wall-clock and
//! starve the decode latency of sequences already running.
//!
//! **Fault tolerance.** The scheduling round runs under `catch_unwind`:
//! an engine panic fails only the sequences implicated in the poisoned
//! state (after [`MAX_SEQ_FAULTS`] consecutive panics they get a typed
//! [`ServeError::EngineFailure`]), the engine scratch and KV pool are
//! rebuilt, and the survivors are requeued from the same snapshots
//! preemption uses. Requests carry wall-clock deadlines (per-request
//! `deadline_ms`, tightened by the server-wide
//! [`CoordinatorConfig::request_timeout_ms`]) checked while queued, per
//! prefill chunk, and per decode round; the admission queue is bounded
//! at [`CoordinatorConfig::max_queue_depth`], shedding new work with a
//! typed `Overloaded` + `retry_after_ms` hint; and shutdown drains
//! in-flight requests instead of cancelling them. Each mechanism is
//! exercised deterministically by the failpoint chaos suite
//! (`rust/tests/chaos.rs`; see `docs/ARCHITECTURE.md` § "Failure
//! domains & recovery").
//!
//! **Observability.** Requests can opt into per-request trace
//! timelines (`"trace": true` → [`crate::util::trace`]; terminal
//! `done` lines then carry a `timing` phase breakdown and finished
//! timelines are served by the `trace` op). Every round feeds a
//! process-global flight recorder ([`crate::util::flight`]) that is
//! dumped through the structured logger when a round panics and is
//! queryable via the `dump` op. Metrics are exposed both as the JSON
//! `stats` snapshot and as Prometheus text (`metrics` op), and the
//! engine's internal phases can be profiled under
//! `--features profiling` ([`crate::util::profile`]). See
//! `docs/ARCHITECTURE.md` § "Observability".
//!
//! **Numerics audit.** The `audit` op runs a static weight audit
//! (per-tensor reconstruction error vs the Theorem-2 bound —
//! [`crate::quant::audit`]), and `audit_sample_rate > 0` shadow-scores
//! a sampled fraction of decode rounds against the f32 activation
//! reference ([`Engine::audit_probe`]), feeding the `audit_*` stats
//! keys, Prometheus `itq3s_audit_*` families, and — past
//! `audit_drift_warn` — flight-recorder `audit` events. Both paths are
//! read-only over serving state: enabling them never changes tokens.

pub mod error;
pub mod kvpool;
pub mod metrics;
pub mod request;
pub mod sampler;

use crate::eval::{perplexity, PplReport};
use crate::kvpaged::{KvQuant, SeqId};
use crate::model::native::Engine;
use crate::model::tokenizer;
use crate::model::ModelConfig;
use crate::spec;
use crate::util::json::Json;
use crate::util::trace::{RequestTrace, Span, TraceEventKind, TraceStore};
use crate::util::{flight, log, profile};
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

pub use error::ServeError;
pub use request::{Event, FinishReason, GenRequest};

/// Consecutive engine panics a sequence may be implicated in before it
/// is failed with a typed [`ServeError::EngineFailure`] instead of
/// being requeued — bounds the damage of a poison-pill request that
/// deterministically crashes the engine.
const MAX_SEQ_FAULTS: u32 = 3;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max concurrently decoding sequences.
    pub max_batch: usize,
    /// KV budget in bytes (sized in blocks; admission + growth control).
    pub kv_budget_bytes: usize,
    /// Prompt tokens ingested per scheduling round per sequence.
    pub prefill_chunk: usize,
    /// Tokens per paged KV block.
    pub kv_block_tokens: usize,
    /// KV block precision (f32 = bit-identical to dense; q8 = ~3.9x
    /// denser).
    pub kv_quant: KvQuant,
    /// Max draft tokens per speculative verify pass (0 disables
    /// speculative decoding). Greedy and sampled requests both
    /// speculate — verification replays the sequence's own sampler, so
    /// it is lossless in every decoding mode. The budget is per
    /// *round*, shared across the decode-ready sequences (each gets
    /// `spec_draft_len / ready`),
    /// so single streams get the full verify-pass win while wide
    /// batches keep the fused vanilla GEMM instead of running one
    /// verify pass per sequence.
    pub spec_draft_len: usize,
    /// Which zero-artifact drafter speculating sequences use.
    pub spec_drafter: spec::DrafterKind,
    /// Server-wide default deadline in milliseconds, measured from
    /// intake (`None` = none). A request's own `deadline_ms` can only
    /// tighten it: the effective deadline is the minimum of the two.
    pub request_timeout_ms: Option<u64>,
    /// Admission-queue bound: a new request arriving while this many
    /// are already waiting is shed with a typed
    /// [`ServeError::Overloaded`] carrying a `retry_after_ms` hint
    /// derived from the observed decode p50. Internal requeues
    /// (preemption, panic recovery) re-enter at the queue front and
    /// are exempt — shedding admitted work would lose streamed tokens.
    pub max_queue_depth: usize,
    /// Prompt tokens one replica's batch may ingest per scheduling
    /// round, summed across its sequences (0 = unbounded). Chunked
    /// prefill already interleaves with decode round-by-round; this
    /// additionally bounds the *sum* of a round's chunks, so a flood
    /// of long fresh prompts cannot stretch the round's wall clock and
    /// starve decode latency on sequences already running. Shares are
    /// handed out greedily in batch order and replanned every round,
    /// so ingestion stays monotone even when the budget is smaller
    /// than one `prefill_chunk` per waiting sequence.
    pub prefill_round_budget: usize,
    /// Probability that a decode round is shadow-scored for numerics
    /// drift (`serve --audit-sample-rate`). On a sampled round one
    /// decoding sequence's full token history is replayed twice
    /// through the engine on fresh scratch KV — once on the serving
    /// path, once with activation quantization off — and
    /// KL(quantized‖reference), top-1 agreement, the max logit delta,
    /// and per-layer residual drift land in the `audit_*` stats keys.
    /// The probe reads nothing but the engine weights and perturbs
    /// neither the live KV pool nor the sampler RNG (its schedule has
    /// its own per-replica RNG), so serving stays same-seed
    /// token-identical at any rate. 0.0 (default) disables sampling
    /// and skips even the schedule draw.
    pub audit_sample_rate: f64,
    /// Shadow-probe drift threshold (`serve --audit-drift-warn`, in
    /// nats of KL): a sampled round whose KL(quantized‖reference)
    /// exceeds this bumps `audit_drift_events` and drops an `audit`
    /// event naming the request and worst layer into the flight
    /// recorder.
    pub audit_drift_warn: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: 256 << 20,
            prefill_chunk: 32,
            kv_block_tokens: 16,
            kv_quant: KvQuant::F32,
            spec_draft_len: 0,
            spec_drafter: spec::DrafterKind::Ngram,
            request_timeout_ms: None,
            max_queue_depth: 256,
            prefill_round_budget: 0,
            audit_sample_rate: 0.0,
            audit_drift_warn: 0.05,
        }
    }
}

enum Cmd {
    Generate(GenRequest, Sender<Event>),
    Score(String, Sender<PplReport>),
    Stats(Sender<Json>),
    /// Drop all cached (unreferenced) prefix blocks — admin/testing
    /// hook, used by leak audits to reduce the pool to live state only.
    ClearPrefixCache(Sender<()>),
    /// A server connection handler exited with an error (counted under
    /// `conn_errors`; the handler already logged the detail).
    ConnError,
    /// The `n` most recent completed trace timelines, newest first
    /// (requests that opted in with `GenRequest::trace`).
    Trace(usize, Sender<Json>),
    /// Prometheus text exposition of the serving metrics.
    Prometheus(Sender<String>),
    /// Static weight audit: walk every quantized tensor of replica 0's
    /// engine and report per-tensor reconstruction error against the
    /// Theorem-2 bound (all replicas serve the same weights, so one
    /// engine's verdict covers the fleet).
    Audit(Sender<Json>),
    Shutdown,
}

/// Handle to the coordinator worker.
pub struct Coordinator {
    tx: Sender<Cmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Per-sequence scheduling state, built once at the first admission
/// attempt and carried back to the queue on requeue or preemption so
/// the sequence *resumes* rather than restarts: everything already
/// streamed stays streamed, the sampler RNG keeps its position, and the
/// consumed token history re-prefills (mostly from the prefix cache).
struct SeqState {
    /// Tokens to ingest before decoding (prompt, plus the consumed
    /// history when resuming after preemption).
    prefill: Vec<u32>,
    /// Original prompt token count (for client-facing accounting).
    prompt_tokens: usize,
    generated: Vec<u32>,
    /// Next token to feed to decode (sampled but not yet consumed).
    pending: Option<u32>,
    sampler: sampler::Sampler,
    /// Speculative drafter, `None` when this sequence never speculates
    /// (coordinator speculation off or per-request opt-out). Carried
    /// across preemption like the rest of the state.
    drafter: Option<Box<dyn spec::Drafter>>,
    /// Draft proposals planned for this round's verify pass (refilled
    /// each round *before* capacity planning so the round's block
    /// demand covers the verify writes; cleared when capacity is
    /// tight).
    round_drafts: Vec<spec::DraftDist>,
    /// Intake time (when the request entered the queue), so
    /// `ttft_ms`/`total_ms` include queue wait — the latency the client
    /// actually experienced.
    submitted: Instant,
    ttft_ms: Option<f64>,
    /// High-water mark of prompt tokens counted into
    /// `metrics.prompt_tokens`, so post-preemption re-prefill of the
    /// same tokens (and of regenerated decode history) is not
    /// double-counted as client prompt input.
    counted_prompt: usize,
    /// Effective wall-clock deadline (per-request `deadline_ms` min
    /// server `request_timeout_ms`, both from intake), `None` = none.
    deadline: Option<Instant>,
    /// Consecutive engine panics this sequence was implicated in;
    /// cleared by any cleanly completed round, failed typed at
    /// [`MAX_SEQ_FAULTS`].
    faults: u32,
    /// The terminal event was already sent. Guards the window between
    /// `finish()` and retirement: a panic there must not requeue the
    /// sequence and produce a second terminal.
    done: bool,
    /// Coordinator-assigned request id (1-based submission order).
    /// Flight-recorder entries and log lines refer to requests by it.
    id: u64,
    /// Trace timeline for requests that opted in
    /// (`GenRequest::trace`); carried across preemption and restart
    /// like the rest of the state.
    trace: Option<Box<RequestTrace>>,
}

struct WaitingReq {
    req: GenRequest,
    events: Sender<Event>,
    /// Intake time — deadlines are measured from here, and requeues
    /// carry the original so a preempted/restarted request's clock
    /// never resets.
    enqueued: Instant,
    /// `None` until the first admission attempt tokenizes the prompt.
    state: Option<SeqState>,
    /// Coordinator-assigned request id (also in `state` once built).
    id: u64,
    /// Trace timeline carried only until the first admission builds
    /// `state` (which then owns it); requeues leave this `None`.
    trace: Option<Box<RequestTrace>>,
}

struct ActiveSeq {
    req: GenRequest,
    events: Sender<Event>,
    seq: SeqId,
    state: SeqState,
    /// Prefill tokens already resident (mapped from cache or ingested).
    prefilled: usize,
    /// Prompt tokens this sequence ingests *this round* — its share of
    /// [`CoordinatorConfig::prefill_round_budget`], replanned at the
    /// top of every capacity pass (0 = the round's budget went to
    /// sequences ahead of it, or nothing is left to ingest).
    round_prefill: usize,
    /// Monotone admission stamp; preemption evicts the lowest priority,
    /// breaking ties toward the most recently admitted.
    admitted_order: u64,
}

impl ActiveSeq {
    /// Send the terminal `Done` (with the `timing` breakdown when the
    /// request is traced) and return the completed timeline, if any,
    /// for the caller to retire into the [`TraceStore`].
    fn send_done(&mut self, reason: FinishReason) -> Option<Json> {
        let timing = self.state.trace.as_mut().map(|t| {
            t.record(TraceEventKind::Terminal);
            t.timing_json()
        });
        let s = &self.state;
        let _ = self.events.send(Event::Done {
            reason,
            text: tokenizer::decode(&s.generated),
            prompt_tokens: s.prompt_tokens,
            gen_tokens: s.generated.len(),
            ttft_ms: s.ttft_ms.unwrap_or(0.0),
            total_ms: s.submitted.elapsed().as_secs_f64() * 1000.0,
            timing,
        });
        s.trace.as_ref().map(|t| t.timeline_json(reason.as_str()))
    }

    /// Tokens this sequence wants to append in the coming round. A
    /// pending token whose delivery finishes the request (max tokens
    /// reached) is never fed to decode, so it claims no block — else a
    /// dry pool would spuriously ContextFull/preempt for storage the
    /// round will not use. A speculative round additionally writes one
    /// KV position per planned draft before rollback, so those are
    /// demanded up front (rollback returns the rejected share within
    /// the same round).
    fn round_demand(&self) -> usize {
        let s = &self.state;
        let decode_writes = if s.generated.len() + 1 >= self.req.max_new_tokens {
            0
        } else {
            1 + s.round_drafts.len()
        };
        if self.prefilled < s.prefill.len() {
            // The planned budget share, not a flat chunk: 0 means the
            // round's prefill budget went to sequences ahead of this
            // one, so it neither ingests nor decodes this round.
            let chunk = self.round_prefill;
            if chunk == 0 {
                return 0;
            }
            // A chunk that completes the prompt also feeds the first
            // sampled token to decode within this same round.
            if self.prefilled + chunk == s.prefill.len() {
                chunk + decode_writes
            } else {
                chunk
            }
        } else if s.pending.is_some() {
            decode_writes
        } else {
            0
        }
    }
}

impl Coordinator {
    pub fn new(engine: Box<dyn Engine>, cfg: CoordinatorConfig) -> Self {
        Self::new_replicated(vec![engine], cfg)
    }

    /// Drive `engines.len()` data-parallel replicas behind one shared
    /// admission queue. Every engine must serve the same model (same
    /// weights for token-identical results across placements); each
    /// gets an equal share of `cfg.kv_budget_bytes` and its own
    /// scheduling loop. One engine reproduces [`Coordinator::new`]
    /// exactly — same thread layout, same token streams, same stats.
    pub fn new_replicated(engines: Vec<Box<dyn Engine>>, cfg: CoordinatorConfig) -> Self {
        assert!(!engines.is_empty(), "coordinator needs at least one engine replica");
        let (tx, rx) = channel::<Cmd>();
        let handle = std::thread::Builder::new()
            .name("itq3s-coordinator".into())
            .spawn(move || worker(engines, cfg, rx))
            .expect("spawn coordinator");
        Coordinator { tx, handle: Some(handle) }
    }

    /// Submit a generation request; events stream on the receiver.
    pub fn generate(&self, req: GenRequest) -> Receiver<Event> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Cmd::Generate(req, tx));
        rx
    }

    /// Convenience: run a request to completion, returning (text, done).
    pub fn generate_collect(&self, req: GenRequest) -> (String, Option<Event>) {
        let rx = self.generate(req);
        let mut text = String::new();
        let mut done = None;
        for ev in rx.iter() {
            match ev {
                Event::Heartbeat => {}
                Event::Token { text: ref t, .. } => text.push_str(t),
                Event::Done { .. } | Event::Error(_) => {
                    done = Some(ev);
                    break;
                }
            }
        }
        (text, done)
    }

    /// Synchronous perplexity scoring through the worker's engine.
    pub fn score(&self, text: String) -> Result<PplReport> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Score(text, tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Stats(tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// The `n` most recent completed trace timelines, newest first —
    /// requests that opted in with `GenRequest::trace` (the `trace` op).
    pub fn trace(&self, n: usize) -> Result<Json> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Trace(n, tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// Prometheus text exposition of the serving metrics (the
    /// `metrics` op). The JSON `stats` snapshot is unchanged by this.
    pub fn prometheus(&self) -> Result<String> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Prometheus(tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// Static weight audit of the serving engine (the `audit` op):
    /// per-tensor reconstruction error vs the Theorem-2 bound, as a
    /// JSON [`crate::quant::audit::AuditReport`]. Synchronous through
    /// the worker so it never races a scheduling round's scratch use.
    pub fn audit(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Audit(tx)).map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// Snapshot the process-global flight recorder (the `dump` op).
    /// Reads the ring directly rather than round-tripping through the
    /// worker: the black box must stay readable even when the worker
    /// is wedged mid-round — which is exactly when it matters.
    pub fn dump(&self) -> Json {
        flight::dump_json()
    }

    /// Drop all cached (unreferenced) prefix blocks. Live sequences are
    /// unaffected; used by leak audits to assert `in_use == 0` after a
    /// workload fully drains.
    pub fn clear_prefix_cache(&self) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::ClearPrefixCache(tx))
            .map_err(|_| anyhow::anyhow!("worker gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// Record a connection-handler failure (fire-and-forget; called by
    /// the server accept loop after logging the error).
    pub fn note_conn_error(&self) {
        let _ = self.tx.send(Cmd::ConnError);
    }

    /// Stop accepting work and wait for in-flight requests to drain.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Deliver one sampled token to `seq`'s client and resolve the stop
/// ladder at pre-feed context length `ctx`. This is the single source
/// of truth for finish conditions in BOTH vanilla and speculative
/// rounds — the speculative path replays it per accepted token with
/// the virtual round's `ctx`, which is what keeps speculation
/// token-identical to vanilla. Returns the finish reason, if any.
fn deliver_and_resolve(
    seq: &mut ActiveSeq,
    metrics: &mut metrics::Metrics,
    tok: u32,
    ctx: usize,
    max_seq: usize,
) -> Option<FinishReason> {
    seq.state.generated.push(tok);
    metrics.gen_tokens += 1;
    let frag = tokenizer::decode(&[tok]);
    let delivered = seq.events.send(Event::Token { token: tok, text: frag.clone() }).is_ok();
    let stop_hit = seq.req.stop_at_sentence && frag == ".";
    if !delivered {
        Some(FinishReason::Cancelled)
    } else if seq.state.generated.len() >= seq.req.max_new_tokens {
        Some(FinishReason::MaxTokens)
    } else if ctx + 1 >= max_seq {
        Some(FinishReason::ContextFull)
    } else if stop_hit {
        Some(FinishReason::StopCondition)
    } else if seq.state.deadline.is_some_and(|d| Instant::now() >= d) {
        // Lowest-priority branch: a request that finished anyway keeps
        // its real reason. `now()` is only evaluated when a deadline is
        // actually set, so deadline-free serving takes no clock reads.
        Some(FinishReason::DeadlineExceeded)
    } else {
        None
    }
}

/// Finish bookkeeping shared by every retirement site. Marks the
/// sequence `done` so a panic between here and retirement cannot
/// requeue it for a second terminal.
fn finish(
    seq: &mut ActiveSeq,
    metrics: &mut metrics::Metrics,
    traces: &Mutex<TraceStore>,
    reason: FinishReason,
) {
    if let Some(timeline) = seq.send_done(reason) {
        lock(traces).push(timeline);
    }
    seq.state.done = true;
    metrics.requests_finished += 1;
    if reason == FinishReason::Cancelled {
        metrics.requests_cancelled += 1;
    }
    if reason == FinishReason::DeadlineExceeded {
        metrics.deadline_expired += 1;
    }
}

/// The request's effective deadline: per-request `deadline_ms` min the
/// server-wide `request_timeout_ms`, both measured from intake.
fn effective_deadline(
    req: &GenRequest,
    cfg: &CoordinatorConfig,
    from: Instant,
) -> Option<Instant> {
    let ms = match (req.deadline_ms, cfg.request_timeout_ms) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    }?;
    Some(from + Duration::from_millis(ms))
}

/// Backoff hint for shed requests: queue depth × observed decode p50,
/// clamped to [1 ms, 60 s]. Crude but honest — it scales with how much
/// work is ahead of the client at current service speed. With several
/// replicas the *slowest* replica's p50 is used, so the hint stays
/// honest even when the retry lands on the busiest engine.
fn retry_after_hint(replicas: &[Replica], depth: usize) -> u64 {
    let per_slot_ms = replicas
        .iter()
        .map(|r| r.metrics.decode_step_ms.p50())
        .fold(0.0f64, f64::max)
        .max(1.0);
    (per_slot_ms * depth.max(1) as f64).clamp(1.0, 60_000.0) as u64
}

/// Dispatcher-owned observability state: the completed-timeline ring
/// the `trace` op serves (shared with replica rounds, which retire
/// timelines into it), and a monotone round counter stamped into the
/// flight recorder's per-round summaries — one tick per dispatcher
/// iteration, shared by every replica's round of that iteration.
struct Obs {
    traces: Mutex<TraceStore>,
    round: u64,
}

/// One data-parallel engine replica behind the shared admission queue:
/// its own engine, paged KV pool (an equal share of the byte budget —
/// KV is engine-local state, so a cached prefix lives on whichever
/// replica ingested it), running batch, and metrics shard. Between
/// rounds the dispatcher owns the whole struct; during rounds each
/// replica is mutated only by its own round thread, so no lock guards
/// the fields — only the waiting queue and trace store are shared.
struct Replica {
    id: usize,
    engine: Box<dyn Engine>,
    pool: kvpool::KvPool,
    active: Vec<ActiveSeq>,
    metrics: metrics::Metrics,
    /// Dedicated RNG for the shadow-audit sampling schedule, seeded
    /// from the replica id. Deliberately separate from every
    /// sequence's sampler RNG: drawing the schedule must never shift
    /// a sampler's stream, or enabling audit would change tokens.
    audit_rng: crate::util::XorShift,
}

/// Poison-tolerant lock: a replica round that panics while holding the
/// queue or trace lock must not wedge the dispatcher — both structures
/// are valid after any interrupted operation, and panic recovery
/// (`restart_after_panic`) requeues whatever the round half-scheduled.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Refresh each replica's pool-derived gauges and merge every metrics
/// shard (dispatcher intake + all replicas) into one [`Metrics`].
/// Each counter has exactly one writer, so the merge is exact — and
/// with a single replica it reproduces the pre-replica single-struct
/// snapshot byte for byte.
///
/// [`Metrics`]: metrics::Metrics
fn merged_metrics(replicas: &mut [Replica], intake: &metrics::Metrics) -> metrics::Metrics {
    let mut merged = intake.clone();
    for rep in replicas.iter_mut() {
        // Max-accumulate: the pool is rebuilt (peak reset) on panic
        // recovery, but the serving-lifetime peak must survive.
        rep.metrics.kv_peak_bytes = rep.metrics.kv_peak_bytes.max(rep.pool.peak_bytes());
        rep.metrics.kv_pool = rep.pool.stats_json();
        merged.merge_from(&rep.metrics);
    }
    merged.replicas = replicas.len();
    merged
}

/// The `stats` snapshot: the merged shards plus a `per_replica`
/// breakdown (placement / load-balance visibility — the aggregate keys
/// stay exactly what single-replica serving reports).
fn stats_snapshot(replicas: &mut [Replica], intake: &metrics::Metrics) -> Json {
    let mut snap = merged_metrics(replicas, intake).snapshot();
    if let Json::Obj(m) = &mut snap {
        let per: Vec<Json> = replicas
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("replica", Json::num(r.id as f64)),
                    ("active", Json::num(r.active.len() as f64)),
                    ("requests_finished", Json::num(r.metrics.requests_finished as f64)),
                    ("gen_tokens", Json::num(r.metrics.gen_tokens as f64)),
                    ("prompt_tokens", Json::num(r.metrics.prompt_tokens as f64)),
                    ("preemptions", Json::num(r.metrics.preemptions as f64)),
                    ("worker_restarts", Json::num(r.metrics.worker_restarts as f64)),
                    (
                        "kv_blocks_in_use",
                        r.metrics
                            .kv_pool
                            .get("kv_blocks_in_use")
                            .cloned()
                            .unwrap_or(Json::num(0.0)),
                    ),
                ])
            })
            .collect();
        m.insert("per_replica".into(), Json::Arr(per));
    }
    snap
}

fn worker(engines: Vec<Box<dyn Engine>>, cfg: CoordinatorConfig, rx: Receiver<Cmd>) {
    let model_cfg = engines[0].config().clone();
    let n = engines.len();
    let per_replica_budget = (cfg.kv_budget_bytes / n).max(1);
    let mut replicas: Vec<Replica> = engines
        .into_iter()
        .enumerate()
        .map(|(id, engine)| Replica {
            id,
            pool: kvpool::KvPool::new(
                &model_cfg,
                per_replica_budget,
                cfg.kv_block_tokens,
                cfg.kv_quant,
            ),
            active: Vec::new(),
            metrics: metrics::Metrics::new(),
            // Fixed per-replica seed: the audit schedule is
            // deterministic for a given replica count and round
            // sequence, so audit-overhead runs are reproducible.
            audit_rng: crate::util::XorShift::new(0x5EED_A0D1 ^ id as u64),
            engine,
        })
        .collect();
    // Dispatcher-owned metrics shard: intake, shedding, queue-side
    // accounting, and the request-id source. `Cmd::Stats` merges it
    // with every replica's shard; each counter has one writer.
    let mut intake = metrics::Metrics::new();
    let waiting: Mutex<VecDeque<WaitingReq>> = Mutex::new(VecDeque::new());
    // Drain-then-stop: once set, new work is shed with `ShuttingDown`
    // and the worker exits only when everything in flight has resolved
    // (bounded by `max_new_tokens`; dead clients fall to the heartbeat
    // probe), so shutdown never truncates an accepted stream.
    let mut draining = false;
    let mut admit_counter: u64 = 0;
    let mut obs = Obs { traces: Mutex::new(TraceStore::new(64)), round: 0 };

    loop {
        // ---- 0. intake ----------------------------------------------
        loop {
            let idle = replicas.iter().all(|r| r.active.is_empty())
                && lock(&waiting).is_empty();
            if draining && idle {
                return;
            }
            let cmd = if idle {
                // Idle: block (with timeout so shutdown-by-drop works).
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(c) => c,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(_) => {
                        draining = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        draining = true;
                        break;
                    }
                }
            };
            match cmd {
                Cmd::Generate(req, tx) => {
                    intake.requests_submitted += 1;
                    // Request ids are 1-based submission order — the
                    // handle the flight recorder and log lines use.
                    let id = intake.requests_submitted;
                    let depth = lock(&waiting).len();
                    if draining {
                        flight::record("shed", format!("req={id} reason=shutting_down"));
                        let _ = tx.send(Event::Error(ServeError::ShuttingDown));
                    } else if depth >= cfg.max_queue_depth {
                        // Bounded admission: the round's own shed order
                        // (drop drafts, then preempt) happens in the
                        // capacity loop; rejecting *new* work is the
                        // last resort and the only shed clients see.
                        intake.rejected_overload += 1;
                        let hint = retry_after_hint(&replicas, depth);
                        flight::record(
                            "shed",
                            format!("req={id} reason=overloaded retry_after_ms={hint}"),
                        );
                        log::warn(
                            "coordinator",
                            "queue full; shedding request",
                            &[("req", id.to_string()), ("retry_after_ms", hint.to_string())],
                        );
                        let _ = tx.send(Event::Error(ServeError::Overloaded {
                            retry_after_ms: hint,
                        }));
                    } else {
                        let trace = req.trace.then(|| Box::new(RequestTrace::new(id)));
                        lock(&waiting).push_back(WaitingReq {
                            req,
                            events: tx,
                            enqueued: Instant::now(),
                            state: None,
                            id,
                            trace,
                        });
                    }
                }
                Cmd::Score(text, tx) => {
                    let _ = tx.send(perplexity(replicas[0].engine.as_ref(), &text));
                }
                Cmd::Stats(tx) => {
                    let _ = tx.send(stats_snapshot(&mut replicas, &intake));
                }
                Cmd::ClearPrefixCache(tx) => {
                    for rep in replicas.iter_mut() {
                        rep.pool.clear_prefix_cache();
                    }
                    let _ = tx.send(());
                }
                Cmd::ConnError => {
                    intake.conn_errors += 1;
                }
                Cmd::Trace(n, tx) => {
                    let _ = tx.send(lock(&obs.traces).recent(n));
                }
                Cmd::Prometheus(tx) => {
                    let _ = tx.send(merged_metrics(&mut replicas, &intake).prometheus());
                }
                Cmd::Audit(tx) => {
                    let _ = tx.send(replicas[0].engine.audit_weights().to_json());
                }
                Cmd::Shutdown => {
                    draining = true;
                }
            }
        }
        if replicas.iter().all(|r| r.active.is_empty()) && lock(&waiting).is_empty() {
            if draining {
                return;
            }
            continue;
        }
        intake.queue_depth.push(lock(&waiting).len() as f64);
        obs.round += 1;

        // ---- 0.5 queued-deadline sweep ------------------------------
        sweep_queued_deadlines(&cfg, &waiting, &mut intake, &obs.traces);

        // ---- 1. admission & placement -------------------------------
        admit_waiting(
            &cfg,
            &model_cfg,
            &mut replicas,
            &waiting,
            &mut intake,
            &obs.traces,
            &mut admit_counter,
        );

        // ---- 2..5 replica rounds ------------------------------------
        // With one replica the round runs inline on the dispatcher
        // thread — no scope, no spawn, exactly the single-engine
        // scheduling loop this refactor grew out of. With several,
        // replica 0 still runs inline while the rest round on scoped
        // threads, so N replicas cost N-1 spawns per iteration.
        let round_no = obs.round;
        let traces = &obs.traces;
        let (first, rest) = replicas.split_at_mut(1);
        if rest.is_empty() {
            round_on(&mut first[0], &cfg, &model_cfg, &waiting, traces, round_no);
        } else {
            std::thread::scope(|s| {
                for rep in rest.iter_mut() {
                    if rep.active.is_empty() {
                        continue;
                    }
                    let (cfg, model_cfg, waiting) = (&cfg, &model_cfg, &waiting);
                    std::thread::Builder::new()
                        .name(format!("itq3s-replica-{}", rep.id))
                        .spawn_scoped(s, move || {
                            round_on(rep, cfg, model_cfg, waiting, traces, round_no)
                        })
                        .expect("spawn replica round");
                }
                round_on(&mut first[0], &cfg, &model_cfg, &waiting, traces, round_no);
            });
        }
    }
}

/// Expire waiting requests before spending admission work on them (the
/// pre-replica round's phase 0.5, now dispatcher-side so one sweep
/// covers the shared queue for every replica). A requeued sequence
/// keeps its partial text; a request that never ran reports empty
/// counters. Both get the same partial-result `Done{DeadlineExceeded}`
/// terminal that mid-generation expiry produces.
fn sweep_queued_deadlines(
    cfg: &CoordinatorConfig,
    waiting: &Mutex<VecDeque<WaitingReq>>,
    intake: &mut metrics::Metrics,
    traces: &Mutex<TraceStore>,
) {
    let now = Instant::now();
    lock(waiting).retain_mut(|w| {
        let deadline = match &w.state {
            Some(s) => s.deadline,
            None => effective_deadline(&w.req, cfg, w.enqueued),
        };
        if !deadline.is_some_and(|d| now >= d) {
            return true;
        }
        intake.deadline_expired += 1;
        intake.requests_finished += 1;
        flight::record("deadline", format!("req={} expired while queued", w.id));
        // The request is terminal: consume its trace (held by `w`
        // before the first admission, by `state` after).
        let mut tr = w.trace.take();
        if tr.is_none() {
            tr = w.state.as_mut().and_then(|s| s.trace.take());
        }
        let timing = tr.as_mut().map(|t| {
            t.record(TraceEventKind::Terminal);
            t.timing_json()
        });
        if let Some(t) = &tr {
            lock(traces).push(t.timeline_json(FinishReason::DeadlineExceeded.as_str()));
        }
        let (text, prompt_tokens, gen_tokens, ttft_ms) = match &w.state {
            Some(s) => (
                tokenizer::decode(&s.generated),
                s.prompt_tokens,
                s.generated.len(),
                s.ttft_ms.unwrap_or(0.0),
            ),
            None => (String::new(), 0, 0, 0.0),
        };
        let _ = w.events.send(Event::Done {
            reason: FinishReason::DeadlineExceeded,
            text,
            prompt_tokens,
            gen_tokens,
            ttft_ms,
            total_ms: w.enqueued.elapsed().as_secs_f64() * 1000.0,
            timing,
        });
        false
    });
}

/// Pull waiting requests into replica batches until every replica is
/// full or the queue is empty (the pre-replica round's phase 1, now
/// dispatcher-side with a placement step). Placement probes every
/// replica's prefix cache read-only and tries candidates best-first:
/// longest cached prefix, then lightest load, then lowest id.
/// Admission can still fail on the preferred replica (its blocks are
/// exhausted until its next round reclaims), so the candidate list is
/// walked before giving up; when no replica can hold the request it
/// returns to the queue front and admission stops for this iteration.
#[allow(clippy::too_many_arguments)]
fn admit_waiting(
    cfg: &CoordinatorConfig,
    model_cfg: &ModelConfig,
    replicas: &mut [Replica],
    waiting: &Mutex<VecDeque<WaitingReq>>,
    intake: &mut metrics::Metrics,
    traces: &Mutex<TraceStore>,
    admit_counter: &mut u64,
) {
    loop {
        if !replicas.iter().any(|r| r.active.len() < cfg.max_batch) {
            break;
        }
        let Some(mut w) = lock(waiting).pop_front() else { break };
        // Probe the client before paying for tokenize/map/prefill.
        if w.events.send(Event::Heartbeat).is_err() {
            intake.requests_cancelled += 1;
            intake.requests_finished += 1;
            continue;
        }
        // First attempt tokenizes; requeues and preemptions carry
        // their state back so nothing is recomputed or restarted.
        let mut state = match w.state.take() {
            Some(s) => s,
            None => {
                let mut prompt = tokenizer::encode(&w.req.prompt);
                // Truncate over-long prompts from the front, keeping BOS.
                let ctx_cap = model_cfg.max_seq.saturating_sub(2);
                if prompt.len() > ctx_cap {
                    let keep = ctx_cap - 1;
                    let tail = prompt.split_off(prompt.len() - keep);
                    prompt = std::iter::once(tokenizer::BOS).chain(tail).collect();
                }
                // Speculation is lossless in every decoding mode
                // (the verify pass replays the sequence's own
                // sampler), so only the coordinator switch and the
                // per-request opt-out gate it.
                let speculative = cfg.spec_draft_len > 0 && w.req.speculation;
                SeqState {
                    prompt_tokens: prompt.len(),
                    prefill: prompt,
                    generated: Vec::new(),
                    pending: None,
                    sampler: sampler::Sampler::new(w.req.temperature, w.req.seed)
                        .with_top_k(w.req.top_k)
                        .with_top_p(w.req.top_p),
                    drafter: speculative.then(|| cfg.spec_drafter.build()),
                    round_drafts: Vec::new(),
                    submitted: w.enqueued,
                    ttft_ms: None,
                    counted_prompt: 0,
                    deadline: effective_deadline(&w.req, cfg, w.enqueued),
                    faults: 0,
                    done: false,
                    id: w.id,
                    trace: w.trace.take(),
                }
            }
        };
        // A prompt whose span exceeds a whole pool can never be
        // admitted; queueing it would head-of-line-block and spin
        // forever. Reject it outright. (All pools share geometry, so
        // with the even budget split they agree; `any` stays correct
        // if the split ever becomes uneven.)
        if !replicas.iter().any(|r| r.pool.fits_ever(state.prefill.len())) {
            intake.requests_rejected += 1;
            flight::record(
                "reject",
                format!("req={} span={} can never fit the pool", state.id, state.prefill.len()),
            );
            let timing = state.trace.as_mut().map(|t| {
                t.record(TraceEventKind::Terminal);
                t.timing_json()
            });
            if let Some(t) = &state.trace {
                lock(traces).push(t.timeline_json(FinishReason::ContextFull.as_str()));
            }
            let _ = w.events.send(Event::Done {
                reason: FinishReason::ContextFull,
                text: tokenizer::decode(&state.generated),
                prompt_tokens: state.prompt_tokens,
                gen_tokens: state.generated.len(),
                ttft_ms: state.ttft_ms.unwrap_or(0.0),
                total_ms: state.submitted.elapsed().as_secs_f64() * 1000.0,
                timing,
            });
            continue;
        }
        // ---- placement ---------------------------------------------
        let mut cands: Vec<(usize, usize, usize)> = replicas
            .iter()
            .filter(|r| r.active.len() < cfg.max_batch)
            .map(|r| (r.pool.cached_prefix_tokens(&state.prefill), r.active.len(), r.id))
            .collect();
        cands.sort_by_key(|&(hit, load, id)| (std::cmp::Reverse(hit), load, id));
        let mut placed: Option<(usize, SeqId, usize)> = None;
        for &(_, _, rid) in &cands {
            if let Some((seq, mapped)) = replicas[rid].pool.admit(&state.prefill) {
                placed = Some((rid, seq, mapped));
                break;
            }
        }
        let Some((rid, seq, mapped)) = placed else {
            // No replica has blocks free right now: requeue and stop
            // admitting this iteration.
            lock(waiting).push_front(WaitingReq {
                req: w.req,
                events: w.events,
                enqueued: w.enqueued,
                id: w.id,
                trace: None, // `state` owns the trace now
                state: Some(state),
            });
            break;
        };
        intake.prefix_reused_tokens += mapped as u64;
        *admit_counter += 1;
        if let Some(t) = state.trace.as_mut() {
            t.record(TraceEventKind::Admitted { prefix_reused: mapped, replica: rid });
        }
        let rep = &mut replicas[rid];
        flight::record(
            "admit",
            format!(
                "req={} r={} mapped={} batch={}",
                state.id,
                rid,
                mapped,
                rep.active.len() + 1
            ),
        );
        // Cache-mapped prompt tokens are accounted as prefix
        // reuse, not as ingested prompt input.
        state.counted_prompt = state.counted_prompt.max(mapped.min(state.prompt_tokens));
        rep.active.push(ActiveSeq {
            req: w.req,
            events: w.events,
            seq,
            state,
            prefilled: mapped,
            round_prefill: 0,
            admitted_order: *admit_counter,
        });
    }
}

/// Run one replica's scheduling round under `catch_unwind` — the
/// per-replica panic isolation domain. An engine panic (poisoned
/// scratch, failpoint, kernel bug) unwinds to here, and recovery
/// rebuilds *this replica's* engine scratch and KV pool and requeues
/// its survivors through the shared queue; other replicas round on
/// undisturbed. The `AssertUnwindSafe` is justified by that recovery:
/// everything the closure mutates is either rebuilt wholesale (pool,
/// engine scratch) or restored from per-sequence snapshots designed to
/// survive interruption at any point (the same ones preemption uses).
fn round_on(
    rep: &mut Replica,
    cfg: &CoordinatorConfig,
    model_cfg: &ModelConfig,
    waiting: &Mutex<VecDeque<WaitingReq>>,
    traces: &Mutex<TraceStore>,
    round_no: u64,
) {
    if rep.active.is_empty() {
        return;
    }
    let round = catch_unwind(AssertUnwindSafe(|| {
        run_round(rep, cfg, model_cfg, waiting, traces, round_no)
    }));
    if round.is_err() {
        flight::record(
            "panic",
            format!("round={} r={} scheduling round panicked", round_no, rep.id),
        );
        restart_after_panic(rep, cfg, model_cfg, waiting, traces);
        // Dump the black box *after* the restart record so the
        // post-mortem shows the rounds leading up to the crash and
        // which requests the recovery implicated.
        flight::dump_to_log();
    }
}

/// One replica's scheduling round: liveness probe, draft planning,
/// prefill-budget planning, capacity/preemption, chunked prefill,
/// decode, and retirement. (Queued-deadline sweeping and admission
/// live on the dispatcher now — see `sweep_queued_deadlines` and
/// `admit_waiting`.) Runs under `round_on`'s `catch_unwind`; see
/// `restart_after_panic` for what happens when it unwinds.
fn run_round(
    rep: &mut Replica,
    cfg: &CoordinatorConfig,
    model_cfg: &ModelConfig,
    waiting: &Mutex<VecDeque<WaitingReq>>,
    traces: &Mutex<TraceStore>,
    round_no: u64,
) {
    let rid = rep.id;
    let engine: &dyn Engine = rep.engine.as_ref();
    let pool = &mut rep.pool;
    let metrics = &mut rep.metrics;
    let active = &mut rep.active;
    let audit_rng = &mut rep.audit_rng;

    // ---- 1.5 liveness & deadline sweep --------------------------
    // Probe every active client before spending the round — a
    // dropped receiver cancels within one round whether the
    // sequence is mid-prefill or mid-decode (an abandoned stream
    // must not decode on to max_tokens). Then expire deadlines:
    // checking here (once per round, before the engine calls)
    // bounds how far past its deadline a request can run by one
    // round, for prefill-only rounds too.
    let now = Instant::now();
    let mut i = 0;
    while i < active.len() {
        if active[i].events.send(Event::Heartbeat).is_err() {
            let mut seq = active.swap_remove(i);
            seq.state.done = true; // receiver gone; no terminal to send
            if let Some(t) = seq.state.trace.as_mut() {
                t.record(TraceEventKind::Terminal);
                lock(traces).push(t.timeline_json(FinishReason::Cancelled.as_str()));
            }
            pool.release(seq.seq);
            metrics.requests_cancelled += 1;
            metrics.requests_finished += 1;
            continue;
        }
        if active[i].state.deadline.is_some_and(|d| now >= d) {
            let mut seq = active.swap_remove(i);
            flight::record(
                "deadline",
                format!("req={} r={} expired while active", seq.state.id, rid),
            );
            finish(&mut seq, metrics, traces, FinishReason::DeadlineExceeded);
            pool.release(seq.seq);
            continue;
        }
        i += 1;
    }
    if active.is_empty() {
        return;
    }

    // ---- 1.75 speculative draft planning ------------------------
    // Drafts are chosen *before* capacity planning so the round's
    // block demand covers the verify pass's KV writes (the rejected
    // share is rolled back within the same round). Only
    // fully-prefilled, speculation-enabled sequences with a pending
    // token and room for at least two more tokens speculate;
    // everything else takes the fused vanilla round.
    //
    // A speculative round trades the fused multi-sequence GEMM for
    // one verify pass *per* sequence, so the draft budget is shared
    // across the round's decode-ready set: a single stream gets the
    // full `spec_draft_len`, while wide batches scale the per-
    // sequence draft length down (to 0 — i.e. back to the single
    // fused vanilla pass) rather than paying one weight-unpack
    // sweep per sequence.
    // Eligibility mirrors the per-sequence checks below (budget
    // room for >= 2 more tokens, context room for >= 1 draft), so
    // sequences that cannot speculate anyway don't shrink the
    // shared budget.
    let spec_ready = active
        .iter()
        .filter(|a| {
            a.state.drafter.is_some()
                && a.state.pending.is_some()
                && a.prefilled >= a.state.prefill.len()
                && a.state.generated.len() + 3 <= a.req.max_new_tokens
                && pool.seq_len(a.seq) + 2 <= model_cfg.max_seq
        })
        .count()
        .max(1);
    let round_draft_len = cfg.spec_draft_len / spec_ready;
    for seq in active.iter_mut() {
        seq.state.round_drafts.clear();
        let s = &mut seq.state;
        if s.drafter.is_none() || seq.prefilled < s.prefill.len() {
            continue;
        }
        let Some(pending) = s.pending else { continue };
        // Delivery of `pending` happens this round; if it finishes
        // the request (budget or context) nothing is fed at all.
        let g_after = s.generated.len() + 1;
        if g_after >= seq.req.max_new_tokens {
            continue;
        }
        let ctx = pool.seq_len(seq.seq);
        if ctx + 1 >= model_cfg.max_seq {
            continue;
        }
        // Useful draft count: the request's remaining budget after
        // this delivery, minus the never-fed final token; and the
        // context must hold the whole verify span (ctx + 1 + k
        // positions) before rollback.
        let room = seq.req.max_new_tokens - g_after;
        let k = round_draft_len
            .min(room.saturating_sub(1))
            .min(model_cfg.max_seq - ctx - 1);
        if k == 0 {
            continue;
        }
        // Full token stream: prompt + everything generated + the
        // pending token about to be fed (prefill holds prompt +
        // pre-preemption history, so slice the prompt part only).
        let mut history =
            Vec::with_capacity(s.prompt_tokens + s.generated.len() + 1);
        history.extend_from_slice(&s.prefill[..s.prompt_tokens]);
        history.extend_from_slice(&s.generated);
        history.push(pending);
        let mut drafts = s.drafter.as_mut().expect("checked above").draft_dist(&history, k);
        drafts.truncate(k);
        s.round_drafts = drafts;
    }

    // ---- 2. capacity & preemption -------------------------------
    // Sum the whole round's block demand into one reclaim target so
    // engine calls later this round cannot fail mid-forward (the
    // pool takes no reservations; the worker is the only writer).
    // When the pool stays dry after prefix-cache eviction, first
    // drop the round's speculative drafts (speculation is strictly
    // optional — shedding it is the cheapest reclaim), then preempt-
    // and-requeue the lowest-priority sequence (ties: most recently
    // admitted first) and replan from scratch.
    'capacity: loop {
        // Plan the round's prefill shares before sizing block demand:
        // each mid-prefill sequence gets up to `prefill_chunk` tokens
        // from the round's shared `prefill_round_budget` (0 config =
        // unbounded, which hands every sequence its full chunk — the
        // pre-budget behavior). Greedy in batch order; replanned after
        // every preemption so a victim's share flows to the survivors.
        let mut budget = if cfg.prefill_round_budget == 0 {
            usize::MAX
        } else {
            cfg.prefill_round_budget
        };
        for seq in active.iter_mut() {
            let want =
                seq.state.prefill.len().saturating_sub(seq.prefilled).min(cfg.prefill_chunk);
            let planned = want.min(budget);
            budget -= planned;
            seq.round_prefill = planned;
        }
        let mut planned = 0usize;
        let mut satisfied = true;
        for i in 0..active.len() {
            let demand = active[i].round_demand();
            if demand == 0 {
                continue;
            }
            let need = pool.blocks_needed(active[i].seq, demand);
            if pool.reclaim(planned + need) {
                planned += need;
                continue;
            }
            satisfied = false;
            if active.iter().any(|a| !a.state.round_drafts.is_empty()) {
                for a in active.iter_mut() {
                    a.state.round_drafts.clear();
                }
                break; // replan without speculation before preempting
            }
            if active.len() == 1 {
                // Nothing to preempt and the pool cannot hold this
                // sequence's next step: finish it, not livelock.
                let mut seq = active.swap_remove(0);
                finish(&mut seq, metrics, traces, FinishReason::ContextFull);
                pool.release(seq.seq);
                break;
            }
            // Choose the victim across the whole batch.
            let mut victim = 0;
            for j in 1..active.len() {
                let a =
                    (active[j].req.priority, std::cmp::Reverse(active[j].admitted_order));
                let b = (
                    active[victim].req.priority,
                    std::cmp::Reverse(active[victim].admitted_order),
                );
                if a < b {
                    victim = j;
                }
            }
            // Retain the victim's prefix in the cache (inside
            // `release`), free its blocks, and send it back to the
            // front of the queue with its scheduling state so it
            // resumes rather than restarts. The resumed prefill is
            // rebuilt as prompt + all generated tokens (truncate
            // first — repeated preemptions must not re-append).
            let v = active.swap_remove(victim);
            pool.release(v.seq);
            metrics.preemptions += 1;
            flight::record(
                "preempt",
                format!(
                    "req={} r={} prio={} generated={}",
                    v.state.id,
                    rid,
                    v.req.priority,
                    v.state.generated.len()
                ),
            );
            let mut state = v.state;
            if let Some(t) = state.trace.as_mut() {
                t.record(TraceEventKind::Preempted);
                t.record(TraceEventKind::Queued); // queue wait resumes accruing
            }
            state.prefill.truncate(state.prompt_tokens);
            state.prefill.extend_from_slice(&state.generated);
            lock(waiting).push_front(WaitingReq {
                req: v.req,
                events: v.events,
                enqueued: state.submitted,
                id: state.id,
                trace: None, // `state` owns the trace
                state: Some(state),
            });
            break; // replan with the survivor set
        }
        if satisfied || active.is_empty() {
            break 'capacity;
        }
    }
    if active.is_empty() {
        return;
    }
    // Occupancy counts sequences that actually compute this round
    // (post-preemption), so the §7.3 acceptance comparison is honest.
    metrics.batch_occupancy.push(active.len() as f64);

    // Flight-recorder round summary: who computes this round. Recorded
    // *before* the engine calls so a panicked round's participants are
    // already in the black box when the post-mortem dump fires.
    {
        let ids: Vec<String> = active.iter().map(|a| a.state.id.to_string()).collect();
        let depth = lock(waiting).len();
        flight::record(
            "round",
            format!("n={} r={} active=[{}] waiting={}", round_no, rid, ids.join(","), depth),
        );
    }

    // ---- 3. chunked prefill -------------------------------------
    // Each sequence ingests exactly its planned share of the round's
    // prefill-token budget (its full chunk when the budget is
    // unbounded); a zero share skips the round entirely.
    for seq in active.iter_mut() {
        if seq.prefilled < seq.state.prefill.len() && seq.round_prefill > 0 {
            let end = (seq.prefilled + seq.round_prefill).min(seq.state.prefill.len());
            let chunk = &seq.state.prefill[seq.prefilled..end];
            // Chaos site: an engine failure mid-prefill (the round
            // is the isolation domain — see `restart_after_panic`).
            if crate::util::failpoint::should_fail("engine.prefill") {
                panic!("failpoint 'engine.prefill': injected engine failure");
            }
            let span = Span::begin();
            let logits = engine.prefill(&mut pool.seq_view(seq.seq), chunk);
            if let Some(t) = seq.state.trace.as_mut() {
                t.add_prefill_ms(span.ms());
                t.record(TraceEventKind::PrefillChunk { tokens: chunk.len() });
            }
            // Count only first-time ingestion of *client prompt*
            // tokens — re-prefill after preemption (including the
            // regenerated decode history) is work, not prompt input.
            let fresh = end
                .min(seq.state.prompt_tokens)
                .saturating_sub(seq.state.counted_prompt);
            metrics.prompt_tokens += fresh as u64;
            seq.state.counted_prompt += fresh;
            metrics.prefill_tokens_per_round.push(chunk.len() as f64);
            seq.prefilled = end;
            if seq.prefilled == seq.state.prefill.len() {
                // Prompt resident and final: publish its whole-block
                // prefix for sharing, then sample the first token
                // (unless resuming with one already sampled).
                pool.cache_prefix(seq.seq);
                if seq.state.pending.is_none() {
                    let _p = profile::scope(profile::Phase::Sampler);
                    let tok = seq.state.sampler.sample(logits.row(chunk.len() - 1));
                    seq.state.pending = Some(tok);
                }
                if seq.state.ttft_ms.is_none() {
                    let ttft = seq.state.submitted.elapsed().as_secs_f64() * 1000.0;
                    seq.state.ttft_ms = Some(ttft);
                    metrics.ttft_ms.push(ttft);
                    metrics.ttft_hist.push(ttft);
                }
            }
        }
    }

    // ---- 4. decode round (fused batch + speculative passes) -----
    // Token delivery and stop conditions are resolved per sequence
    // first; survivors without drafts then advance through a single
    // `decode_batch` call (each weight block unpacked once for the
    // whole batch), while sequences with planned drafts each run
    // one multi-position verify pass over the same fused GEMMs —
    // accepting a whole run of tokens per pass and rolling the
    // rejected suffix's KV back.
    let round_span = Span::begin(); // true decode-round wall time
    let mut finished: Vec<usize> = Vec::new();
    let mut spec_idx: Vec<usize> = Vec::new();
    let mut step_idx: Vec<usize> = Vec::new();
    let mut step_toks: Vec<u32> = Vec::new();
    for (i, seq) in active.iter_mut().enumerate() {
        // A sequence resumed after preemption/restart carries its
        // already-sampled pending token *through* re-admission, while
        // its consumed history re-prefills over several rounds. That
        // token must not be delivered (or fed to decode) until the
        // history is resident again — feeding it against a partial KV
        // prefix would diverge from the pre-preemption stream. The
        // same guard covers sequences whose prefill share was deferred
        // by the round's prefill-token budget.
        if seq.prefilled < seq.state.prefill.len() {
            continue;
        }
        let Some(tok) = seq.state.pending else { continue };
        // Consume the pending token at delivery: a panic later this
        // round then cannot re-deliver it after restart (the token
        // is already in `generated`, so the requeued prefill covers
        // it; survivors get a fresh pending from their next pass).
        seq.state.pending = None;
        // Deliver the sampled token and resolve stop conditions.
        let ctx = pool.seq_len(seq.seq);
        if let Some(reason) =
            deliver_and_resolve(seq, metrics, tok, ctx, model_cfg.max_seq)
        {
            finish(seq, metrics, traces, reason);
            finished.push(i);
            continue;
        }
        if seq.state.round_drafts.is_empty() {
            step_idx.push(i);
            step_toks.push(tok);
        } else {
            spec_idx.push(i);
        }
    }

    // ---- 4a. speculative verify rounds --------------------------
    // One multi-position pass per speculating sequence: feed the
    // pending token plus the drafts, run the rejection-sampling
    // accept loop against the sequence's own seeded sampler (greedy
    // sequences degenerate to the argmax-prefix rule and consume no
    // randomness), roll back the rest. The accepted run streams out
    // with exactly the per-token stop checks the vanilla rounds
    // would have applied (same token stream, same finish reason,
    // same KV state, same sampler RNG position — only fewer engine
    // passes).
    for &i in &spec_idx {
        let seq = &mut active[i];
        let drafts = std::mem::take(&mut seq.state.round_drafts);
        let draft_toks: Vec<u32> = drafts.iter().map(|d| d.token).collect();
        let pending = *seq.state.generated.last().expect("pending was delivered");
        // Chaos site: an engine failure mid-decode, on the
        // speculative verify path.
        if crate::util::failpoint::should_fail("engine.decode") {
            panic!("failpoint 'engine.decode': injected engine failure");
        }
        let span = Span::begin();
        let outcome = spec::spec_step_sampled(
            engine,
            &mut pool.seq_view(seq.seq),
            pending,
            &drafts,
            &mut seq.state.sampler,
        );
        let verify_ms = span.ms();
        if let Some(t) = seq.state.trace.as_mut() {
            t.add_decode_ms(verify_ms);
            t.record(TraceEventKind::SpecVerify {
                drafted: drafts.len(),
                accepted: outcome.accepted,
            });
        }
        // The pass produced `accepted` verified tokens plus the
        // next pending one; amortize its wall time over those.
        let produced = outcome.accepted + 1;
        let per_tok_ms = verify_ms / produced as f64;
        for _ in 0..produced {
            metrics.decode_step_ms.push(per_tok_ms);
        }
        metrics.spec_drafted += drafts.len() as u64;
        metrics.spec_accepted += outcome.accepted as u64;
        metrics.spec_resampled += outcome.resampled as u64;
        // `spec_idx` only holds sequences with planned drafts, so the
        // denominator is nonzero today — but a 0/0 here would push NaN
        // into the acceptance rings and poison every percentile
        // downstream, so the ratio is gated, not trusted.
        if !drafts.is_empty() {
            let rate = outcome.accepted as f64 / drafts.len() as f64;
            metrics.spec_accept_rate.push(rate);
            // Per-mode acceptance: sampled drafts face a stochastic
            // accept rule, greedy ones an exact match — aggregating
            // them hides drafter regressions in either mode.
            if seq.req.temperature > 0.0 {
                metrics.spec_accept_rate_sampled.push(rate);
            } else {
                metrics.spec_accept_rate_greedy.push(rate);
            }
        }
        metrics.spec_run_len.push(outcome.accepted as f64);
        if let Some(d) = seq.state.drafter.as_mut() {
            d.observe(&draft_toks, outcome.accepted, &outcome.verify_argmax);
        }
        // Stream the accepted run. Accepted token `jj` corresponds
        // to a virtual vanilla round whose pre-feed context length
        // is `base + jj + 1`, so `deliver_and_resolve` replays the
        // exact vanilla ladder at that state — the run finishes at
        // exactly the token sequential rounds would have finished
        // at.
        let mut reason: Option<FinishReason> = None;
        for (jj, &g) in draft_toks[..outcome.accepted].iter().enumerate() {
            let ctx = outcome.base + jj + 1;
            if let Some(r) = deliver_and_resolve(seq, metrics, g, ctx, model_cfg.max_seq) {
                // Vanilla never feeds a finishing token: roll the
                // cache back to the fed prefix (pending + the
                // earlier accepted tokens).
                pool.truncate(seq.seq, ctx);
                reason = Some(r);
                break;
            }
        }
        if let Some(r) = reason {
            finish(seq, metrics, traces, r);
            finished.push(i);
        } else {
            seq.state.pending = Some(outcome.next);
        }
    }

    // ---- 4b. fused vanilla batch --------------------------------
    if !step_idx.is_empty() {
        let ids: Vec<SeqId> = step_idx.iter().map(|&i| active[i].seq).collect();
        // Chaos site: an engine failure mid-decode, on the fused
        // vanilla path (same site name as the verify path — hit
        // counts script "the n-th decode" across both).
        if crate::util::failpoint::should_fail("engine.decode") {
            panic!("failpoint 'engine.decode': injected engine failure");
        }
        let span = Span::begin();
        let logits = engine.decode_batch(&mut pool.batch_view(&ids), &step_toks);
        let wall_ms = span.ms();
        // `step_idx` is non-empty here (guarded above), so the
        // per-token amortization cannot divide by zero.
        let per_tok_ms = wall_ms / step_idx.len() as f64;
        metrics.decode_batch_size.push(step_idx.len() as f64);
        for (j, &i) in step_idx.iter().enumerate() {
            metrics.decode_step_ms.push(per_tok_ms);
            let seq = &mut active[i];
            if let Some(t) = seq.state.trace.as_mut() {
                // Traced participants are attributed the fused pass's
                // whole wall time — it is the latency they experienced.
                t.add_decode_ms(wall_ms);
                t.record(TraceEventKind::DecodeRound { batch: step_idx.len() });
            }
            let _p = profile::scope(profile::Phase::Sampler);
            seq.state.pending = Some(seq.state.sampler.sample(&logits[j]));
        }
    }

    // True per-round decode wall time, alongside the amortized
    // `decode_step_ms`: everything from token delivery through the
    // verify passes and the fused batch.
    if !spec_idx.is_empty() || !step_idx.is_empty() {
        let round_ms = round_span.ms();
        metrics.decode_round_ms.push(round_ms);
        metrics.decode_round_hist.push(round_ms);
    }

    // ---- 4c. sampled logit-drift shadow probe -------------------
    // On a sampled fraction of decode rounds, replay one
    // still-running sequence's full consumed history through the
    // engine twice on fresh scratch KV — serving path vs the f32
    // activation reference — and fold KL(quantized‖reference),
    // top-1 agreement, the max logit delta, and the per-layer
    // residual drift profile into the `audit_*` metrics. The probe
    // is strictly read-only with respect to serving state: it
    // touches neither the live KV pool nor any sampler, and its
    // schedule draws from the replica's own `audit_rng`, so
    // enabling audit never changes tokens (`audit_serving_is_token_
    // identical_and_records_drift` pins this). Rate 0.0 skips even
    // the schedule draw — audit-off rounds are byte-identical.
    if cfg.audit_sample_rate > 0.0
        && !step_idx.is_empty()
        && audit_rng.next_f64() < cfg.audit_sample_rate
    {
        let i = step_idx[0];
        let history = {
            let s = &active[i].state;
            let mut h = Vec::with_capacity(s.prompt_tokens + s.generated.len());
            h.extend_from_slice(&s.prefill[..s.prompt_tokens]);
            h.extend_from_slice(&s.generated);
            h
        };
        if let Some(probe) = engine.audit_probe(&history) {
            let kl = probe.kl_divergence();
            let top1 = probe.top1_agree();
            let delta = probe.max_logit_delta();
            metrics.record_audit(kl, top1, delta, &probe.layer_rel_l2);
            let seq = &mut active[i];
            if let Some(t) = seq.state.trace.as_mut() {
                t.note_audit(kl, top1, delta);
            }
            if kl > cfg.audit_drift_warn {
                metrics.audit_drift_events += 1;
                let worst = probe
                    .layer_rel_l2
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(li, _)| li)
                    .unwrap_or(0);
                flight::record(
                    "audit",
                    format!(
                        "req={} r={} kl={kl:.4} top1={top1} max_delta={delta:.4} \
                         worst_layer={worst} drift exceeds warn threshold",
                        seq.state.id, rid
                    ),
                );
            }
        }
    }

    // ---- 5. retire finished -------------------------------------
    // Indices must drop highest-first for swap_remove to stay
    // valid; the speculative pass can append out of order.
    finished.sort_unstable();
    for &i in finished.iter().rev() {
        let seq = active.swap_remove(i);
        pool.release(seq.seq);
    }

    // A cleanly completed round exonerates the survivors: `faults`
    // only accumulates across *consecutive* panicked rounds, so a
    // long-running sequence that merely shared batches with a
    // poison-pill request is not failed for it.
    for seq in active.iter_mut() {
        seq.state.faults = 0;
    }

    // Drain the phase profiler into per-round distributions. Compiles
    // to nothing without `--features profiling` (`ENABLED` is a
    // compile-time constant and `take()` is an inlined no-op). The
    // accumulators are process-global: with several replicas, rounds
    // that overlap in time may attribute a phase slice to whichever
    // replica drains first. Every slice is drained exactly once, so
    // the *merged* phase totals stay exact; only the per-replica split
    // is approximate under N > 1 (and exact at N = 1).
    if profile::ENABLED {
        let ms = profile::take();
        for (i, v) in ms.into_iter().enumerate() {
            if v > 0.0 {
                metrics.phase_ms[i].push(v);
            }
        }
    }
}

/// Recover a replica from a panicked round: rebuild everything the
/// panic may have poisoned and requeue the surviving sequences.
///
/// The engine's interior-mutable scratch is restored via
/// [`Engine::reset`], and the replica's KV pool is rebuilt wholesale —
/// zero leaked blocks by construction, at the cost of its prefix cache
/// (survivors re-prefill their history, exactly as after preemption).
/// Sequences whose terminal already went out (`state.done`) are
/// dropped; the rest are snapshotted like preemption victims and
/// pushed back at the front of the *shared* queue in admission order —
/// placement is free to re-admit them on a healthy replica. A sequence
/// implicated in [`MAX_SEQ_FAULTS`] consecutive panics is failed with
/// a typed [`ServeError::EngineFailure`] instead of being requeued, so
/// a poison-pill request cannot crash-loop a replica forever.
fn restart_after_panic(
    rep: &mut Replica,
    cfg: &CoordinatorConfig,
    model_cfg: &ModelConfig,
    waiting: &Mutex<VecDeque<WaitingReq>>,
    traces: &Mutex<TraceStore>,
) {
    let metrics = &mut rep.metrics;
    metrics.worker_restarts += 1;
    let implicated: Vec<String> = rep
        .active
        .iter()
        .filter(|a| !a.state.done)
        .map(|a| a.state.id.to_string())
        .collect();
    flight::record(
        "restart",
        format!(
            "worker restart {} r={} implicated=[{}]",
            metrics.worker_restarts,
            rep.id,
            implicated.join(",")
        ),
    );
    log::error(
        "coordinator",
        "engine panic: rebuilding engine scratch and KV pool",
        &[
            ("replica", rep.id.to_string()),
            ("restarts", metrics.worker_restarts.to_string()),
            ("implicated", format!("[{}]", implicated.join(","))),
        ],
    );
    // The old pool's high-water mark would vanish with it.
    metrics.kv_peak_bytes = metrics.kv_peak_bytes.max(rep.pool.peak_bytes());
    rep.engine.reset();
    let budget = rep.pool.budget();
    rep.pool = kvpool::KvPool::new(model_cfg, budget, cfg.kv_block_tokens, cfg.kv_quant);
    // drain(..).rev() + push_front re-enters survivors in admission
    // order at the head of the queue, ahead of never-admitted work.
    // The lock is held across the drain so the whole survivor block
    // lands contiguously even if another replica requeues concurrently.
    rep.active.sort_by_key(|a| a.admitted_order);
    let mut waiting = lock(waiting);
    for v in rep.active.drain(..).rev() {
        if v.state.done {
            // Terminal already sent (the panic hit between finish()
            // and retirement) — dropping the sender is all that's left.
            continue;
        }
        let mut state = v.state;
        state.faults += 1;
        if let Some(t) = state.trace.as_mut() {
            t.record(TraceEventKind::RestartImplicated);
        }
        if state.faults >= MAX_SEQ_FAULTS {
            metrics.requests_finished += 1;
            if let Some(t) = state.trace.as_mut() {
                t.record(TraceEventKind::Terminal);
                lock(traces).push(t.timeline_json("engine_failure"));
            }
            let _ = v.events.send(Event::Error(ServeError::EngineFailure(format!(
                "request implicated in {} consecutive engine panics",
                state.faults
            ))));
            continue;
        }
        // The preemption snapshot: everything delivered stays
        // delivered, the sampler keeps its RNG position, and the
        // consumed history re-prefills (the fresh pool has no cached
        // prefixes, so this is a full re-ingest).
        state.round_drafts.clear();
        state.prefill.truncate(state.prompt_tokens);
        state.prefill.extend_from_slice(&state.generated);
        if let Some(t) = state.trace.as_mut() {
            t.record(TraceEventKind::Queued); // queue wait resumes accruing
        }
        waiting.push_front(WaitingReq {
            req: v.req,
            events: v.events,
            enqueued: state.submitted,
            id: state.id,
            trace: None, // `state` owns the trace
            state: Some(state),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, ModelConfig, NativeEngine};

    fn coordinator(max_batch: usize, kv_budget: usize) -> Coordinator {
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch,
                kv_budget_bytes: kv_budget,
                prefill_chunk: 8,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_request_completes() {
        let c = coordinator(4, 64 << 20);
        let (text, done) = c.generate_collect(GenRequest {
            prompt: "hello".into(),
            max_new_tokens: 6,
            ..Default::default()
        });
        let Some(Event::Done { reason, gen_tokens, prompt_tokens, .. }) = done else {
            panic!("no done event");
        };
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(gen_tokens, 6);
        assert_eq!(prompt_tokens, 6); // BOS + 5 bytes
        // A random model emits arbitrary bytes; decode is lossy (invalid
        // UTF-8 merges into replacement chars, a generated 0x00 is
        // dropped as BOS/pad), so the char count is only bounded by the
        // token count — `gen_tokens` above is the exact invariant.
        // (Triage: the seed `== 6` form was coupled to one seed's greedy
        // output surviving decode byte-for-byte; even emptiness is not
        // an invariant — all six tokens could decode to dropped bytes.)
        assert!(text.chars().count() <= 6, "text: {text:?}");
        c.shutdown();
    }

    #[test]
    fn greedy_is_deterministic_across_batching() {
        // The same greedy request must yield identical text whether it
        // runs alone or concurrently with others — batching must not
        // change results (core continuous-batching invariant).
        let solo = coordinator(1, 64 << 20);
        let req = GenRequest { prompt: "the ".into(), max_new_tokens: 8, ..Default::default() };
        let (text_solo, _) = solo.generate_collect(req.clone());
        solo.shutdown();

        let busy = coordinator(4, 64 << 20);
        let rx1 = busy.generate(GenRequest {
            prompt: "other prompt entirely".into(),
            max_new_tokens: 8,
            ..Default::default()
        });
        let (text_busy, _) = busy.generate_collect(req);
        for _ in rx1.iter() {} // drain
        busy.shutdown();
        assert_eq!(text_solo, text_busy);
    }

    #[test]
    fn many_concurrent_requests_all_finish() {
        let c = coordinator(4, 64 << 20);
        let rxs: Vec<_> = (0..10)
            .map(|i| {
                c.generate(GenRequest {
                    prompt: format!("prompt number {i}"),
                    max_new_tokens: 4 + (i % 3),
                    ..Default::default()
                })
            })
            .collect();
        let mut finished = 0;
        for rx in rxs {
            for ev in rx.iter() {
                if let Event::Done { reason, gen_tokens, .. } = ev {
                    assert_eq!(reason, FinishReason::MaxTokens);
                    assert!(gen_tokens >= 4);
                    finished += 1;
                    break;
                }
            }
        }
        assert_eq!(finished, 10);
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(10));
        assert!(stats.get("gen_tokens").unwrap().as_u64().unwrap() >= 40);
        c.shutdown();
    }

    #[test]
    fn tiny_kv_budget_serializes_but_completes() {
        // Budget for ~2 blocks: requests queue and run a few at a time.
        let cfg = ModelConfig::test();
        let one_seq = kvpool::seq_bytes(&cfg, 64);
        let c = coordinator(8, one_seq + 1024);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                c.generate(GenRequest {
                    prompt: "x".into(),
                    max_new_tokens: 3,
                    ..Default::default()
                })
            })
            .collect();
        for rx in rxs {
            let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
            assert!(matches!(
                done,
                Some(Event::Done { reason: FinishReason::MaxTokens, .. })
            ));
        }
        c.shutdown();
    }

    #[test]
    fn dropped_receiver_cancels_sequence() {
        let c = coordinator(2, 64 << 20);
        {
            let _rx = c.generate(GenRequest {
                prompt: "will be cancelled".into(),
                max_new_tokens: 1000, // would run long
                ..Default::default()
            });
            // _rx dropped here
        }
        // A subsequent request still completes promptly.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ok".into(),
            max_new_tokens: 3,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let stats = c.stats().unwrap();
        assert!(stats.get("requests_cancelled").unwrap().as_u64().unwrap() >= 1);
        c.shutdown();
    }

    #[test]
    fn dead_client_cancels_before_prefill_completes() {
        // A long prompt with a dropped receiver must be cancelled by the
        // heartbeat probe without ingesting the whole prompt.
        let c = coordinator(2, 64 << 20);
        {
            let _rx = c.generate(GenRequest {
                prompt: "y".repeat(400), // truncated to ~62 tokens, 8/round
                max_new_tokens: 500,
                ..Default::default()
            });
        }
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ok".into(),
            max_new_tokens: 2,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let stats = c.stats().unwrap();
        assert!(stats.get("requests_cancelled").unwrap().as_u64().unwrap() >= 1);
        // The probe kills it at admission or after at most a chunk or
        // two — never the full 62-token prompt (plus 3 for "ok").
        assert!(
            stats.get("prompt_tokens").unwrap().as_u64().unwrap() <= 27,
            "cancelled prompt must not be fully prefilled"
        );
        c.shutdown();
    }

    #[test]
    fn top_k_sampling_is_deterministic_under_seed() {
        let run = || {
            let c = coordinator(2, 64 << 20);
            let (text, _) = c.generate_collect(GenRequest {
                prompt: "sample me".into(),
                max_new_tokens: 12,
                temperature: 0.9,
                top_k: Some(8),
                seed: 1234,
                ..Default::default()
            });
            c.shutdown();
            text
        };
        assert_eq!(run(), run());
    }

    fn replicated_coordinator(n: usize, max_batch: usize) -> Coordinator {
        let cfg = ModelConfig::test();
        let engines: Vec<Box<dyn Engine>> = (0..n)
            .map(|_| {
                Box::new(NativeEngine::dense(DenseModel::random(&cfg, 3, None)))
                    as Box<dyn Engine>
            })
            .collect();
        Coordinator::new_replicated(
            engines,
            CoordinatorConfig {
                max_batch,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                ..Default::default()
            },
        )
    }

    #[test]
    fn two_replicas_serve_and_aggregate_stats() {
        let c = replicated_coordinator(2, 2);
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                c.generate(GenRequest {
                    prompt: format!("replica spread {i}"),
                    max_new_tokens: 4,
                    ..Default::default()
                })
            })
            .collect();
        for rx in rxs {
            let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
            let Some(Event::Done { reason, gen_tokens, .. }) = done else {
                panic!("no done event")
            };
            assert_eq!(reason, FinishReason::MaxTokens);
            assert_eq!(gen_tokens, 4);
        }
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("replicas").unwrap().as_u64(), Some(2));
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(6));
        assert_eq!(stats.get("gen_tokens").unwrap().as_u64(), Some(24));
        let per = stats.get("per_replica").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 2);
        let finished: u64 = per
            .iter()
            .map(|p| p.get("requests_finished").unwrap().as_u64().unwrap())
            .sum();
        assert_eq!(finished, 6, "per-replica finishes must sum to the aggregate");
        for (i, p) in per.iter().enumerate() {
            assert_eq!(p.get("replica").unwrap().as_u64(), Some(i as u64));
        }
        c.shutdown();
    }

    #[test]
    fn single_replica_stats_report_replicas_one_and_per_replica() {
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "one replica".into(),
            max_new_tokens: 3,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("replicas").unwrap().as_u64(), Some(1));
        let per = stats.get("per_replica").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 1);
        assert_eq!(per[0].get("requests_finished").unwrap().as_u64(), Some(1));
        c.shutdown();
    }

    fn spec_coordinator(draft_len: usize, drafter: spec::DrafterKind) -> Coordinator {
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                spec_draft_len: draft_len,
                spec_drafter: drafter,
                ..Default::default()
            },
        )
    }

    #[test]
    fn speculative_decode_is_token_identical_to_vanilla() {
        // A repetitive prompt so the ngram drafter proposes every round
        // (whatever the acceptance): the streamed text must equal the
        // vanilla coordinator's byte for byte, and the full round-trip
        // accounting must agree.
        let req = GenRequest {
            prompt: "abcabcabcabc".into(),
            max_new_tokens: 16,
            ..Default::default()
        };
        let vanilla = coordinator(4, 64 << 20);
        let (want, done_v) = vanilla.generate_collect(req.clone());
        vanilla.shutdown();
        for kind in [spec::DrafterKind::Ngram, spec::DrafterKind::SelfDraft] {
            for draft_len in [1usize, 3, 8] {
                let c = spec_coordinator(draft_len, kind);
                let (got, done_s) = c.generate_collect(req.clone());
                let Some(Event::Done { reason, gen_tokens, .. }) = done_s else {
                    panic!("no done event")
                };
                assert_eq!(got, want, "{kind:?} k={draft_len} diverged from vanilla");
                assert_eq!(gen_tokens, 16);
                assert_eq!(reason, FinishReason::MaxTokens);
                // SelfDraft always proposes (bootstrap repeats the last
                // token), so its verify passes provably ran; the ngram
                // drafter only fires when the stream actually repeats,
                // which a random model does not guarantee.
                if kind == spec::DrafterKind::SelfDraft {
                    let stats = c.stats().unwrap();
                    assert!(
                        stats.get("spec_drafted_total").unwrap().as_u64().unwrap() > 0,
                        "k={draft_len}: no verify pass ever ran"
                    );
                }
                c.shutdown();
            }
        }
        let Some(Event::Done { gen_tokens, .. }) = done_v else { panic!() };
        assert_eq!(gen_tokens, 16);
    }

    #[test]
    fn wide_batch_sheds_draft_budget_to_zero_without_nan_stats() {
        // draft_len 1 across a batch of four: once all four decode
        // together the per-sequence share floors to 0 and the rounds
        // fall back to the fused vanilla pass. Everything must still
        // complete, and any acceptance-rate stats from the narrow early
        // rounds must be finite — a 0/0 rate would poison the
        // percentile rings.
        let c = spec_coordinator(1, spec::DrafterKind::SelfDraft);
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                c.generate(GenRequest {
                    prompt: "abcabcabcabc".into(),
                    max_new_tokens: 8,
                    ..Default::default()
                })
            })
            .collect();
        for rx in rxs {
            let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
            let Some(Event::Done { reason, gen_tokens, .. }) = done else {
                panic!("no done event")
            };
            assert_eq!(reason, FinishReason::MaxTokens);
            assert_eq!(gen_tokens, 8);
        }
        let stats = c.stats().unwrap();
        for k in [
            "spec_accept_rate_mean",
            "spec_accept_rate_p50",
            "spec_accept_rate_greedy_mean",
            "spec_run_len_mean",
        ] {
            if let Some(v) = stats.get(k).and_then(|v| v.as_f64()) {
                assert!(v.is_finite(), "{k} must stay finite, got {v}");
            }
        }
        c.shutdown();
    }

    #[test]
    fn speculation_respects_opt_out() {
        let c = spec_coordinator(4, spec::DrafterKind::Ngram);
        // Per-request opt-out: vanilla rounds only.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "abcabcabc".into(),
            max_new_tokens: 8,
            speculation: false,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("spec_drafted_total").unwrap().as_u64(), Some(0));
        c.shutdown();
    }

    #[test]
    fn sampled_requests_speculate_and_match_vanilla_token_for_token() {
        // Same-seed sampled requests must stream identical text whether
        // the coordinator speculates or not — the rejection-sampling
        // verify loop replays the request's own sampler, so for the
        // point-mass drafters speculation is sample-path identical, not
        // merely distribution-preserving. Sweep the filter
        // configurations so the truncated-support compositions are
        // covered end-to-end.
        let configs: [(f32, Option<u64>, Option<f64>); 3] =
            [(0.8, None, None), (0.9, Some(16), None), (0.7, Some(24), Some(0.9))];
        for (temperature, top_k, top_p) in configs {
            let req = GenRequest {
                prompt: "abcabcabcabc".into(),
                max_new_tokens: 14,
                temperature,
                top_k: top_k.map(|k| k as usize),
                top_p: top_p.map(|p| p as f32),
                seed: 42,
                ..Default::default()
            };
            let vanilla = coordinator(4, 64 << 20); // spec_draft_len = 0
            let (want, done_v) = vanilla.generate_collect(req.clone());
            vanilla.shutdown();
            assert!(matches!(done_v, Some(Event::Done { .. })));
            for kind in [spec::DrafterKind::Ngram, spec::DrafterKind::SelfDraft] {
                for draft_len in [2usize, 4] {
                    let c = spec_coordinator(draft_len, kind);
                    let (got, done_s) = c.generate_collect(req.clone());
                    let Some(Event::Done { reason, gen_tokens, .. }) = done_s else {
                        panic!("no done event")
                    };
                    assert_eq!(
                        got, want,
                        "t={temperature} k={top_k:?} p={top_p:?} {kind:?} \
                         draft_len={draft_len}: sampled speculation diverged"
                    );
                    assert_eq!(gen_tokens, 14);
                    assert_eq!(reason, FinishReason::MaxTokens);
                    // SelfDraft always proposes (bootstrap repeats the
                    // last token), so sampled verify passes provably
                    // ran — no silent fallback to vanilla rounds.
                    if kind == spec::DrafterKind::SelfDraft {
                        let stats = c.stats().unwrap();
                        assert!(
                            stats.get("spec_drafted_total").unwrap().as_u64().unwrap() > 0,
                            "sampled request never entered a verify pass"
                        );
                        assert!(
                            stats.get("spec_accept_rate_sampled_mean").is_some(),
                            "per-mode accept ring missing"
                        );
                    }
                    c.shutdown();
                }
            }
        }
    }

    #[test]
    fn speculation_under_tiny_kv_budget_still_completes() {
        // A pool near exhaustion sheds drafts instead of failing or
        // preempting for speculative storage; results are unchanged.
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let unit = crate::kvpaged::BlockPool::new(&cfg, 4, KvQuant::F32, 1).block_bytes();
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 3 * unit,
                prefill_chunk: 8,
                kv_block_tokens: 4,
                spec_draft_len: 8,
                ..Default::default()
            },
        );
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ababab".into(),
            max_new_tokens: 4,
            ..Default::default()
        });
        let Some(Event::Done { reason, gen_tokens, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(gen_tokens, 4);
        c.shutdown();
    }

    #[test]
    fn score_through_worker() {
        let c = coordinator(2, 64 << 20);
        let r = c.score("some text to score".into()).unwrap();
        assert!(r.ppl.is_finite() && r.tokens > 0);
        c.shutdown();
    }

    #[test]
    fn context_full_finishes_gracefully() {
        let c = coordinator(1, 64 << 20);
        // max_seq for test config is 64; ask for more than fits.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "abcdefghij".into(),
            max_new_tokens: 500,
            ..Default::default()
        });
        let Some(Event::Done { reason, .. }) = done else { panic!() };
        assert_eq!(reason, FinishReason::ContextFull);
        c.shutdown();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_queued() {
        // A prompt span larger than the whole pool can never be
        // admitted; it must get a ContextFull Done immediately instead
        // of head-of-line blocking the queue forever.
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let unit = crate::kvpaged::BlockPool::new(&cfg, 4, KvQuant::F32, 1).block_bytes();
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: unit, // one 4-token block total
                prefill_chunk: 8,
                kv_block_tokens: 4,
                kv_quant: KvQuant::F32,
                ..Default::default()
            },
        );
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "this prompt cannot fit".into(),
            max_new_tokens: 4,
            ..Default::default()
        });
        let Some(Event::Done { reason, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::ContextFull);
        // The pool still serves requests that do fit.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ab".into(),
            max_new_tokens: 1,
            ..Default::default()
        });
        assert!(matches!(
            done,
            Some(Event::Done { reason: FinishReason::MaxTokens, .. })
        ));
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("requests_rejected").unwrap().as_u64(), Some(1));
        c.shutdown();
    }

    #[test]
    fn final_token_needs_no_block_headroom() {
        // Pool sized to exactly the tokens the engine will write: the
        // last generated token is delivered but never fed to decode, so
        // it must not claim a block (a spurious claim would turn this
        // into ContextFull and drop the already-sampled token).
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let unit = crate::kvpaged::BlockPool::new(&cfg, 4, KvQuant::F32, 1).block_bytes();
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 1,
                kv_budget_bytes: 2 * unit, // 8 token slots = BOS+2 prompt + 5 fed
                prefill_chunk: 8,
                kv_block_tokens: 4,
                kv_quant: KvQuant::F32,
                ..Default::default()
            },
        );
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "xy".into(),
            max_new_tokens: 6,
            ..Default::default()
        });
        let Some(Event::Done { reason, gen_tokens, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(gen_tokens, 6);
        c.shutdown();
    }

    #[test]
    fn preemption_requeues_and_completes() {
        // Two long sequences into a pool that holds only one: the
        // low-priority one is preempted, requeued, and still finishes
        // with its full token count.
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let unit = crate::kvpaged::BlockPool::new(&cfg, 4, KvQuant::F32, 1).block_bytes();
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 14 * unit, // < 2 full sequences
                prefill_chunk: 8,
                kv_block_tokens: 4,
                kv_quant: KvQuant::F32,
                ..Default::default()
            },
        );
        let hi = c.generate(GenRequest {
            prompt: "a".repeat(30),
            max_new_tokens: 20,
            priority: 1,
            ..Default::default()
        });
        let lo = c.generate(GenRequest {
            prompt: "b".repeat(30),
            max_new_tokens: 20,
            priority: 0,
            ..Default::default()
        });
        for rx in [hi, lo] {
            let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
            let Some(Event::Done { reason, gen_tokens, .. }) = done else { panic!() };
            assert_eq!(reason, FinishReason::MaxTokens);
            assert_eq!(gen_tokens, 20);
        }
        let stats = c.stats().unwrap();
        assert!(
            stats.get("preemptions").unwrap().as_u64().unwrap() >= 1,
            "pool pressure must have preempted"
        );
        c.shutdown();
    }

    #[test]
    fn deadline_expires_to_partial_done() {
        // A 1 ms deadline against a ~62-token prompt (8 per round, two
        // transformer layers per chunk) cannot be met; the request must
        // end in a partial-result Done{DeadlineExceeded}, not hang and
        // not surface an opaque error.
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "z".repeat(400),
            max_new_tokens: 500,
            deadline_ms: Some(1),
            ..Default::default()
        });
        let Some(Event::Done { reason, gen_tokens, .. }) = done else {
            panic!("deadline expiry must still yield a Done terminal, got {done:?}")
        };
        assert_eq!(reason, FinishReason::DeadlineExceeded);
        assert!(gen_tokens < 500);
        let stats = c.stats().unwrap();
        assert!(stats.get("deadline_expired").unwrap().as_u64().unwrap() >= 1);
        // The coordinator still serves after an expiry.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "ok".into(),
            max_new_tokens: 2,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { reason: FinishReason::MaxTokens, .. })));
        c.shutdown();
    }

    #[test]
    fn server_default_timeout_applies_and_client_can_only_tighten() {
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                request_timeout_ms: Some(1),
                ..Default::default()
            },
        );
        // No per-request deadline: the server-wide default still expires it.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "z".repeat(400),
            max_new_tokens: 500,
            ..Default::default()
        });
        let Some(Event::Done { reason, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::DeadlineExceeded);
        // A *looser* client deadline must not widen the server bound.
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "z".repeat(400),
            max_new_tokens: 500,
            deadline_ms: Some(120_000),
            ..Default::default()
        });
        let Some(Event::Done { reason, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::DeadlineExceeded);
        c.shutdown();
    }

    #[test]
    fn overload_sheds_with_retry_hint() {
        // One slot and a queue bound of one: of six concurrent
        // requests, the head of the line completes and at least four of
        // the rest are shed with a typed Overloaded carrying a backoff
        // hint (how many shed exactly depends on whether a round runs
        // between intakes — both interleavings are correct).
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 3, None));
        let c = Coordinator::new(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 1,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                max_queue_depth: 1,
                ..Default::default()
            },
        );
        let first = c.generate(GenRequest {
            prompt: "a".repeat(200),
            max_new_tokens: 12,
            ..Default::default()
        });
        let rest: Vec<_> = (0..5)
            .map(|i| {
                c.generate(GenRequest {
                    prompt: format!("later {i}"),
                    max_new_tokens: 4,
                    ..Default::default()
                })
            })
            .collect();
        let done = first.iter().find(|e| matches!(e, Event::Done { .. }));
        assert!(
            matches!(done, Some(Event::Done { reason: FinishReason::MaxTokens, .. })),
            "head-of-line request must complete"
        );
        let mut shed = 0;
        for rx in rest {
            let mut terminals = 0;
            for ev in rx.iter() {
                match ev {
                    Event::Heartbeat | Event::Token { .. } => {}
                    Event::Done { .. } => terminals += 1,
                    Event::Error(e) => {
                        terminals += 1;
                        assert_eq!(e.code(), "overloaded");
                        assert!(e.retry_after_ms().unwrap() >= 1);
                        shed += 1;
                    }
                }
            }
            assert_eq!(terminals, 1, "exactly one terminal event per request");
        }
        assert!(shed >= 4, "queue bound of 1 must shed most of the burst, shed {shed}");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("rejected_overload").unwrap().as_u64(), Some(shed));
        assert!(stats.get("queue_depth_p50").is_some());
        assert!(stats.get("queue_depth_p99").is_some());
        c.shutdown();
    }

    #[test]
    fn untraced_done_carries_no_timing() {
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "plain".into(),
            max_new_tokens: 3,
            ..Default::default()
        });
        let Some(Event::Done { timing, .. }) = done else { panic!("no done") };
        assert!(timing.is_none(), "timing is opt-in");
        c.shutdown();
    }

    #[test]
    fn traced_request_timing_sums_to_total_within_slack() {
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "trace me please".into(),
            max_new_tokens: 8,
            trace: true,
            ..Default::default()
        });
        let Some(Event::Done { timing: Some(t), total_ms, gen_tokens, .. }) = done else {
            panic!("traced request must carry a timing object")
        };
        assert_eq!(gen_tokens, 8);
        let phase =
            |k: &str| t.get(k).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("missing {k}"));
        let sum = phase("queue_ms") + phase("prefill_ms") + phase("decode_ms");
        // The three phases partition disjoint wall-time intervals of
        // the request's life, so their sum is bounded by the
        // end-to-end latency (small slack for clock-read skew) and —
        // engine calls dominating scheduler bookkeeping — covers most
        // of it.
        assert!(
            sum <= total_ms + 2.0,
            "phase sum {sum:.3} ms must not exceed end-to-end {total_ms:.3} ms"
        );
        assert!(
            sum >= 0.2 * total_ms,
            "phases must cover most of the latency: {sum:.3} of {total_ms:.3} ms"
        );
        assert!(phase("prefill_rounds") >= 1.0, "prefill rounds counted");
        assert!(phase("decode_rounds") >= 1.0, "decode rounds counted");
        c.shutdown();
    }

    #[test]
    fn trace_op_returns_completed_timelines_newest_first() {
        let c = coordinator(2, 64 << 20);
        for i in 0..3 {
            let (_, done) = c.generate_collect(GenRequest {
                prompt: format!("traced {i}"),
                max_new_tokens: 2,
                trace: true,
                ..Default::default()
            });
            assert!(matches!(done, Some(Event::Done { .. })));
        }
        let timelines = c.trace(2).unwrap();
        let arr = timelines.as_arr().unwrap();
        assert_eq!(arr.len(), 2, "n bounds the response");
        let newest = &arr[0];
        // ids are 1-based submission order; newest first.
        assert_eq!(newest.get("id").unwrap().as_u64(), Some(3));
        assert_eq!(arr[1].get("id").unwrap().as_u64(), Some(2));
        assert_eq!(newest.get("reason").unwrap().as_str(), Some("max_tokens"));
        let events = newest.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events[0].get("what").unwrap().as_str(), Some("queued"));
        assert!(
            events.iter().any(|e| e.get("what").unwrap().as_str() == Some("admitted")),
            "lifecycle must include admission"
        );
        assert_eq!(
            events.last().unwrap().get("what").unwrap().as_str(),
            Some("terminal")
        );
        assert!(newest.get("timing").unwrap().get("queue_ms").is_some());
        c.shutdown();
    }

    #[test]
    fn tracing_does_not_change_tokens() {
        // Bit-identity with observability on: a traced sampled request
        // must stream the same text as the identical untraced one.
        let run = |trace: bool| {
            let c = coordinator(2, 64 << 20);
            let (text, _) = c.generate_collect(GenRequest {
                prompt: "identical either way".into(),
                max_new_tokens: 10,
                temperature: 0.8,
                top_k: Some(12),
                seed: 99,
                trace,
                ..Default::default()
            });
            c.shutdown();
            text
        };
        assert_eq!(run(false), run(true));
    }

    /// An itq3_s coordinator with the numerics-audit knobs exposed —
    /// the shadow-probe tests need a quantized engine so the
    /// quantized-vs-reference drift is real, not identically zero.
    fn quant_coordinator(audit_sample_rate: f64, audit_drift_warn: f64) -> Coordinator {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 3, None);
        let q = crate::model::QuantizedModel::quantize(
            &dense,
            crate::quant::format_by_name("itq3_s").unwrap(),
        );
        Coordinator::new(
            Box::new(NativeEngine::quantized(q)),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                audit_sample_rate,
                audit_drift_warn,
                ..Default::default()
            },
        )
    }

    #[test]
    fn audit_op_reports_through_the_worker() {
        let c = quant_coordinator(0.0, 0.05);
        let rep = c.audit().unwrap();
        assert_eq!(rep.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(rep.get("fmt").unwrap().as_str(), Some("itq3_s"));
        let expected = ModelConfig::test().n_layers * 7;
        assert_eq!(rep.get("tensors").unwrap().as_arr().unwrap().len(), expected);
        c.shutdown();

        // A dense engine has no quantized tensors: trivially ok, empty.
        let d = coordinator(2, 64 << 20);
        let rep = d.audit().unwrap();
        assert_eq!(rep.get("ok").unwrap().as_bool(), Some(true));
        assert!(rep.get("tensors").unwrap().as_arr().unwrap().is_empty());
        d.shutdown();
    }

    #[test]
    fn audit_serving_is_token_identical_and_records_drift() {
        // The audit-on/audit-off byte-identity contract: the same
        // seeded sampled request streams the same text at rate 0.0
        // (no probes), rate 1.0 (every decode round probed), and with
        // the drift warning forced on every probe — while the audited
        // runs actually record probe stats.
        let run = |rate: f64, warn: f64| {
            let c = quant_coordinator(rate, warn);
            let (text, _) = c.generate_collect(GenRequest {
                prompt: "identical either way".into(),
                max_new_tokens: 10,
                temperature: 0.8,
                top_k: Some(12),
                seed: 99,
                ..Default::default()
            });
            let stats = c.stats().unwrap();
            c.shutdown();
            (text, stats)
        };
        let (off_text, off_stats) = run(0.0, 0.05);
        let (on_text, on_stats) = run(1.0, 0.05);
        let (warn_text, warn_stats) = run(1.0, -1.0);
        assert_eq!(off_text, on_text, "audit probes must not change tokens");
        assert_eq!(off_text, warn_text, "drift warnings must not change tokens");

        assert_eq!(off_stats.get("audit_rounds").unwrap().as_u64(), Some(0));
        let on_rounds = on_stats.get("audit_rounds").unwrap().as_u64().unwrap();
        assert!(on_rounds >= 1, "rate 1.0 must probe every decode round");
        let kl = on_stats.get("audit_logit_kl_mean").unwrap().as_f64().unwrap();
        assert!(kl.is_finite() && kl >= 0.0);
        let layers = on_stats.get("audit_layer_rel_l2").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), ModelConfig::test().n_layers);
        for l in layers {
            assert!(l.as_f64().unwrap().is_finite());
        }

        // KL >= 0 always exceeds a -1.0 threshold: every probe warns.
        let events = warn_stats.get("audit_drift_events").unwrap().as_u64().unwrap();
        assert!(events >= 1, "forced threshold must record drift events");
    }

    #[test]
    fn audit_drift_warning_reaches_the_flight_recorder() {
        let _x = crate::util::failpoint::exclusive();
        flight::clear();
        let c = quant_coordinator(1.0, -1.0);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "drift into the black box".into(),
            max_new_tokens: 4,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let dump = c.dump();
        let evs = dump.as_arr().unwrap();
        let audit = evs
            .iter()
            .find(|e| e.get("kind").and_then(|k| k.as_str()) == Some("audit"))
            .expect("audit drift event in the flight recorder");
        let detail = audit.get("detail").unwrap().as_str().unwrap();
        assert!(detail.contains("req=1"), "event names the request: {detail}");
        assert!(detail.contains("worst_layer="), "event names the layer: {detail}");
        c.shutdown();
    }

    #[test]
    fn decode_round_wall_time_is_recorded() {
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "round times".into(),
            max_new_tokens: 6,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let stats = c.stats().unwrap();
        for k in ["decode_round_ms_mean", "decode_round_ms_p50", "decode_round_ms_p99"] {
            assert!(stats.get(k).is_some(), "missing {k}");
        }
        assert!(stats.get("decode_round_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
        c.shutdown();
    }

    #[test]
    fn prometheus_op_round_trips_through_the_worker() {
        let c = coordinator(2, 64 << 20);
        let (_, done) = c.generate_collect(GenRequest {
            prompt: "expose me".into(),
            max_new_tokens: 3,
            ..Default::default()
        });
        assert!(matches!(done, Some(Event::Done { .. })));
        let text = c.prometheus().unwrap();
        assert!(text.contains("itq3s_requests_finished_total 1"));
        assert!(text.contains("# TYPE itq3s_ttft_ms_hist histogram"));
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        // Shutdown must complete accepted work, not cancel it: the
        // request is submitted strictly before the shutdown command on
        // the same channel, so the worker drains it to MaxTokens.
        let c = coordinator(2, 64 << 20);
        let rx = c.generate(GenRequest {
            prompt: "drain me".into(),
            max_new_tokens: 12,
            ..Default::default()
        });
        c.shutdown(); // blocks until the worker exits
        let events: Vec<Event> = rx.try_iter().collect();
        let done = events.iter().find_map(|e| match e {
            Event::Done { reason, gen_tokens, .. } => Some((*reason, *gen_tokens)),
            _ => None,
        });
        assert_eq!(
            done,
            Some((FinishReason::MaxTokens, 12)),
            "in-flight request must drain to completion through shutdown"
        );
    }
}
