//! KV-cache pool: budget accounting, admission control, and cache reuse.
//!
//! The paper's §7.3 economics (quantized weights leave VRAM headroom for
//! KV state) become an explicit admission policy here: a sequence is
//! admitted only if its worst-case KV footprint (prompt + max new
//! tokens) fits the configured budget. Finished sequences return their
//! `KvCache` allocation to a free list so steady-state serving does no
//! large allocations (see EXPERIMENTS.md §Perf).

use crate::model::{KvCache, ModelConfig};

pub struct KvPool {
    cfg: ModelConfig,
    budget_bytes: usize,
    reserved_bytes: usize,
    free_list: Vec<KvCache>,
    /// High-water mark of reserved bytes (for metrics).
    pub peak_bytes: usize,
}

/// Worst-case KV bytes for a sequence of `tokens` (f32 native cache).
pub fn seq_bytes(cfg: &ModelConfig, tokens: usize) -> usize {
    2 * cfg.n_layers * tokens.min(cfg.max_seq) * cfg.dim * 4
}

impl KvPool {
    pub fn new(cfg: ModelConfig, budget_bytes: usize) -> Self {
        KvPool { cfg, budget_bytes, reserved_bytes: 0, free_list: Vec::new(), peak_bytes: 0 }
    }

    pub fn reserved(&self) -> usize {
        self.reserved_bytes
    }

    pub fn budget(&self) -> usize {
        self.budget_bytes
    }

    /// Can a sequence with this worst-case length be admitted now?
    pub fn can_admit(&self, max_tokens: usize) -> bool {
        self.reserved_bytes + seq_bytes(&self.cfg, max_tokens) <= self.budget_bytes
    }

    /// Reserve budget and hand out a (recycled) cache. Returns `None`
    /// when over budget — the caller keeps the request queued.
    pub fn admit(&mut self, max_tokens: usize) -> Option<(KvCache, usize)> {
        let bytes = seq_bytes(&self.cfg, max_tokens);
        if self.reserved_bytes + bytes > self.budget_bytes {
            return None;
        }
        self.reserved_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.reserved_bytes);
        let cache = self.free_list.pop().unwrap_or_else(|| KvCache::new(&self.cfg));
        Some((cache, bytes))
    }

    /// Return a finished sequence's cache and release its reservation.
    pub fn release(&mut self, mut cache: KvCache, bytes: usize) {
        debug_assert!(bytes <= self.reserved_bytes);
        self.reserved_bytes = self.reserved_bytes.saturating_sub(bytes);
        cache.reset();
        // Cap the free list so a burst doesn't pin memory forever.
        if self.free_list.len() < 16 {
            self.free_list.push(cache);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn pool(budget_seqs: usize, max_tokens: usize) -> KvPool {
        let cfg = ModelConfig::test();
        let budget = budget_seqs * seq_bytes(&cfg, max_tokens);
        KvPool::new(cfg, budget)
    }

    #[test]
    fn admission_respects_budget() {
        let mut p = pool(2, 64);
        let a = p.admit(64).expect("first fits");
        let b = p.admit(64).expect("second fits");
        assert!(p.admit(64).is_none(), "third must not fit");
        p.release(a.0, a.1);
        assert!(p.admit(64).is_some(), "released budget is reusable");
        drop(b);
    }

    #[test]
    fn release_recycles_allocation() {
        let mut p = pool(1, 64);
        let (c, b) = p.admit(64).unwrap();
        p.release(c, b);
        assert_eq!(p.reserved(), 0);
        let (c2, _) = p.admit(64).unwrap();
        assert!(c2.is_empty(), "recycled cache must be reset");
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut p = pool(3, 32);
        let a = p.admit(32).unwrap();
        let b = p.admit(32).unwrap();
        let peak = p.peak_bytes;
        p.release(a.0, a.1);
        p.release(b.0, b.1);
        assert_eq!(p.peak_bytes, peak);
        assert_eq!(p.reserved(), 0);
    }

    #[test]
    fn prop_reserved_never_exceeds_budget_and_never_leaks() {
        // Invariant under random admit/release interleavings.
        forall("kv pool accounting", 60, |g| {
            let cfg = ModelConfig::test();
            let budget = seq_bytes(&cfg, 64) * g.usize_in(1, 5);
            let mut p = KvPool::new(cfg, budget);
            let mut live: Vec<(KvCache, usize)> = Vec::new();
            for _ in 0..40 {
                if g.bool() || live.is_empty() {
                    let want = g.usize_in(1, 64);
                    if let Some(pair) = p.admit(want) {
                        live.push(pair);
                    }
                } else {
                    let i = g.usize_in(0, live.len() - 1);
                    let (c, b) = live.swap_remove(i);
                    p.release(c, b);
                }
                assert!(p.reserved() <= p.budget());
                let live_sum: usize = live.iter().map(|(_, b)| *b).sum();
                assert_eq!(p.reserved(), live_sum, "reservation leak");
            }
        });
    }
}
