//! KV admission policy over the paged block pool.
//!
//! The seed policy reserved each request's **worst-case** dense-f32 KV
//! footprint (prompt + max new tokens) at admission, so a 256 MiB budget
//! serialized long requests even when their prompts overlapped and most
//! reserved bytes were never written. This wrapper drives
//! [`crate::kvpaged::PagedKvPool`] instead:
//!
//! - admission maps any cached prompt prefix (shared physical blocks,
//!   re-prefill skipped) and only requires blocks for the *uncached*
//!   prompt span plus one decode token;
//! - decode/prefill growth asks for blocks on demand, evicting
//!   prefix-cache LRU entries under pressure;
//! - when the pool still runs dry the coordinator preempts the
//!   lowest-priority running sequence back to the waiting queue, with
//!   its prefix retained in the cache so re-admission skips the
//!   re-prefill.
//!
//! [`seq_bytes`] (the old worst-case formula) is kept as the reference
//! bound: `rust/tests/kv_paged.rs` demonstrates paged admission exceeds
//! it on shared-prefix workloads under the same byte budget.

use crate::kvpaged::{KvQuant, PagedBatch, PagedKvPool, PagedSeq, SeqId};
use crate::model::ModelConfig;
use crate::util::json::Json;

/// Worst-case dense-f32 KV bytes for a sequence of `tokens` — the seed
/// admission formula, kept as the comparison baseline.
pub fn seq_bytes(cfg: &ModelConfig, tokens: usize) -> usize {
    2 * cfg.n_layers * tokens.min(cfg.max_seq) * cfg.dim * 4
}

/// How many sequences the *old* worst-case policy would admit.
pub fn worst_case_bound(cfg: &ModelConfig, budget_bytes: usize, worst_tokens: usize) -> usize {
    budget_bytes / seq_bytes(cfg, worst_tokens).max(1)
}

pub struct KvPool {
    pool: PagedKvPool,
    budget_bytes: usize,
}

impl KvPool {
    pub fn new(
        cfg: &ModelConfig,
        budget_bytes: usize,
        block_tokens: usize,
        quant: KvQuant,
    ) -> Self {
        KvPool { pool: PagedKvPool::new(cfg, block_tokens, quant, budget_bytes), budget_bytes }
    }

    pub fn budget(&self) -> usize {
        self.budget_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.pool.peak_bytes
    }

    /// Drop all cached (unreferenced) prefix blocks; live sequences are
    /// unaffected. Admin/testing hook — leak audits call this so
    /// `in_use` reflects live sequences only.
    pub fn clear_prefix_cache(&mut self) {
        self.pool.clear_prefix_cache();
    }

    /// Could a sequence of `tokens` prompt tokens (plus one decode
    /// token) *ever* fit this pool, even with every other block free?
    /// `false` means the request must be rejected, not queued — waiting
    /// would spin forever.
    pub fn fits_ever(&self, tokens: usize) -> bool {
        let bt = self.pool.block_tokens();
        // ceil((tokens + 1) / bt) blocks for the whole sequence.
        (tokens + bt) / bt <= self.pool.capacity_blocks()
    }

    /// Admit a sequence that will prefill `prefill` tokens: maps the
    /// cached prefix and checks block capacity for the uncached span
    /// plus one decode token. Returns the sequence and how many tokens
    /// are already resident (skip their prefill). `None` = keep queued.
    pub fn admit(&mut self, prefill: &[u32]) -> Option<(SeqId, usize)> {
        let id = self.pool.create_seq();
        let mapped = self.pool.map_cached_prefix(id, prefill);
        let rest = prefill.len() - mapped + 1;
        if self.pool.ensure_append(id, rest) {
            Some((id, mapped))
        } else {
            self.pool.release_seq(id);
            None
        }
    }

    /// Read-only placement probe: prompt tokens this pool's prefix
    /// cache would serve at admission (no LRU bump, no stats).
    pub fn cached_prefix_tokens(&self, prefill: &[u32]) -> usize {
        self.pool.cached_prefix_tokens(prefill)
    }

    /// Fresh blocks appending `n` tokens to `id` would allocate.
    pub fn blocks_needed(&self, id: SeqId, n: usize) -> usize {
        self.pool.blocks_needed(id, n)
    }

    /// Make `total` blocks available (evicting cached prefixes LRU-first
    /// if needed). `false` = the coordinator must preempt.
    pub fn reclaim(&mut self, total: usize) -> bool {
        self.pool.reclaim(total)
    }

    /// Register the sequence's whole-block prefix for reuse (after its
    /// prefill completes, or right before preemption/retirement).
    pub fn cache_prefix(&mut self, id: SeqId) {
        self.pool.cache_prefix(id)
    }

    /// Retire a sequence, first caching its prefix for future requests.
    pub fn release(&mut self, id: SeqId) {
        self.pool.cache_prefix(id);
        self.pool.release_seq(id);
    }

    pub fn seq_len(&self, id: SeqId) -> usize {
        self.pool.seq_len(id)
    }

    /// Roll `id` back to `len` tokens (speculative rollback: released
    /// tail blocks return to the pool; cache entries over the dropped
    /// span are invalidated).
    pub fn truncate(&mut self, id: SeqId, len: usize) {
        self.pool.truncate_seq(id, len)
    }

    pub fn seq_view(&mut self, id: SeqId) -> PagedSeq<'_> {
        self.pool.seq_view(id)
    }

    /// Batched view of one decode round's sequences (see
    /// [`PagedKvPool::batch_view`]).
    pub fn batch_view<'a>(&'a mut self, ids: &'a [SeqId]) -> PagedBatch<'a> {
        self.pool.batch_view(ids)
    }

    pub fn stats_json(&self) -> Json {
        self.pool.stats_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn pool_with_blocks(blocks: usize, bt: usize) -> (KvPool, ModelConfig) {
        let cfg = ModelConfig::test();
        let unit =
            crate::kvpaged::BlockPool::new(&cfg, bt, KvQuant::F32, 1).block_bytes();
        (KvPool::new(&cfg, blocks * unit, bt, KvQuant::F32), cfg)
    }

    fn fill(pool: &mut KvPool, id: SeqId, cfg: &ModelConfig, tokens: &[u32]) {
        use crate::model::KvStore;
        let row = vec![0.5f32; cfg.dim];
        let mut view = pool.seq_view(id);
        for &t in tokens {
            let pos = view.len();
            for l in 0..cfg.n_layers {
                view.write_kv(l, pos, &row, &row);
            }
            view.push_token(t);
        }
    }

    #[test]
    fn admission_is_on_demand_not_worst_case() {
        // 3 blocks of 4 tokens each. A request with a huge max_new would
        // have been rejected by worst-case reservation; paged admission
        // only needs the prompt span + 1.
        let (mut p, _cfg) = pool_with_blocks(3, 4);
        let prompt: Vec<u32> = (0..7).collect();
        let (a, mapped) = p.admit(&prompt).expect("prompt span fits");
        assert_eq!(mapped, 0, "cold cache");
        // A second identical prompt still fits block-wise (7+1 tokens = 2
        // blocks each would not, but admission only checks capacity —
        // 1 block is still free).
        assert!(p.admit(&prompt[..3]).is_some());
        p.release(a);
    }

    #[test]
    fn admit_fails_when_blocks_run_out() {
        let (mut p, cfg) = pool_with_blocks(3, 4);
        let prompt: Vec<u32> = (0..8).collect();
        let (a, _) = p.admit(&prompt).unwrap();
        fill(&mut p, a, &cfg, &prompt);
        // `a` holds 2 of 3 blocks; another 8-token prompt needs 3
        // (prompt span + decode token) and must be rejected.
        assert!(p.admit(&prompt).is_none());
        p.release(a);
        // `a`'s blocks went to the prefix cache; an identical prompt is
        // admitted *through* the cache: one whole block is shared (the
        // last-token cap keeps one to re-prefill) and LRU eviction
        // reclaims the other for fresh writes.
        let (b, mapped) = p.admit(&prompt).expect("cache-backed admission");
        assert_eq!(mapped, 4, "one whole block reused (cap leaves last token)");
        p.release(b);
    }

    #[test]
    fn release_caches_prefix_for_reuse() {
        let (mut p, cfg) = pool_with_blocks(8, 4);
        let prompt: Vec<u32> = (0..12).collect();
        let (a, _) = p.admit(&prompt).unwrap();
        fill(&mut p, a, &cfg, &prompt);
        p.release(a);
        let (b, mapped) = p.admit(&prompt).unwrap();
        // 12 tokens, cap 11 -> 2 whole blocks (8 tokens) reused.
        assert_eq!(mapped, 8);
        assert_eq!(p.seq_len(b), 8);
        p.release(b);
    }

    #[test]
    fn prop_blocks_never_leak_across_admit_release() {
        forall("paged pool accounting", 40, |g| {
            let (mut p, cfg) = pool_with_blocks(g.usize_in(2, 6), 4);
            let mut live: Vec<SeqId> = Vec::new();
            for _ in 0..30 {
                if g.bool() || live.is_empty() {
                    let n = g.usize_in(1, 10);
                    let prompt: Vec<u32> = (0..n as u32).map(|i| i % 7).collect();
                    if let Some((id, mapped)) = p.admit(&prompt) {
                        fill(&mut p, id, &cfg, &prompt[mapped..]);
                        live.push(id);
                    }
                } else {
                    let i = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(i);
                    p.release(id);
                }
            }
            for id in live {
                p.release(id);
            }
            // All remaining references belong to the prefix cache, so
            // clearing it must drain the pool completely.
            p.pool.clear_prefix_cache();
            assert_eq!(p.pool.in_use_blocks(), 0, "block leak");
        });
    }
}
