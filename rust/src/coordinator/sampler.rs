//! Token sampling: greedy argmax or seeded temperature sampling, with
//! optional top-k and top-p (nucleus) truncation of the candidate set.
//!
//! Top-k and top-p compose the standard way: the candidate set is first
//! restricted to the `k` highest logits (if set), the temperature
//! softmax is taken over that set, and the nucleus cut then keeps the
//! smallest probability-sorted prefix whose cumulative mass reaches
//! `p`. Greedy decoding (`temperature <= 0`) ignores both.
//!
//! Sampling is split into two halves so the speculative verifier can
//! replay it exactly: [`Sampler::dist`] resolves the logits into the
//! post-filter distribution (a [`Dist`]) without touching the RNG, and
//! [`Sampler::draw`] consumes one uniform to pick from it (none when
//! greedy). [`Sampler::sample`] is literally `draw(dist(logits))`, so a
//! verify pass that calls the two halves on bit-identical logits
//! advances the RNG stream exactly as vanilla decoding would — the
//! foundation of lossless *sampled* speculative decoding
//! ([`crate::spec::spec_step_sampled`]).

use crate::util::XorShift;

/// A fully-resolved sampling distribution at one position: the
/// candidate support after temperature/top-k/top-p filtering, with
/// normalized probabilities, in the exact order [`Sampler::draw`] walks
/// its CDF. Produced by [`Sampler::dist`].
#[derive(Clone, Debug)]
pub struct Dist {
    /// `(token, probability)` pairs; probabilities sum to 1 over the
    /// support. Full-softmax distributions are in vocabulary order,
    /// truncated ones in (logit desc, index asc) candidate order.
    cand: Vec<(u32, f64)>,
    /// Greedy point mass: [`Sampler::draw`] returns the single
    /// candidate without consuming randomness (`temperature <= 0`
    /// never touches the RNG).
    greedy: bool,
}

impl Dist {
    /// The post-filter support with normalized probabilities, in CDF
    /// walk order.
    pub fn support(&self) -> &[(u32, f64)] {
        &self.cand
    }

    /// True when this is the greedy point mass (drawing from it
    /// consumes no randomness).
    pub fn is_greedy(&self) -> bool {
        self.greedy
    }

    /// Probability of `token` under this distribution (0 outside the
    /// post-filter support).
    pub fn prob_of(&self, token: u32) -> f64 {
        self.cand
            .iter()
            .find(|&&(t, _)| t == token)
            .map(|&(_, p)| p)
            .unwrap_or(0.0)
    }
}

pub struct Sampler {
    temperature: f32,
    top_k: Option<usize>,
    top_p: Option<f32>,
    rng: XorShift,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Self {
        Sampler { temperature, top_k: None, top_p: None, rng: XorShift::new(seed) }
    }

    /// Restrict temperature sampling to the `k` highest logits. `None`
    /// (the default) samples the full distribution; greedy decoding is
    /// unaffected.
    pub fn with_top_k(mut self, k: Option<usize>) -> Self {
        self.top_k = k;
        self
    }

    /// Nucleus sampling: keep the smallest set of highest-probability
    /// tokens whose cumulative probability reaches `p`. `None` or
    /// `p >= 1.0` disables the cut; `p <= 0` degenerates to the single
    /// most probable candidate. Composes with [`Sampler::with_top_k`]
    /// (the nucleus is taken over the top-k-restricted distribution).
    pub fn with_top_p(mut self, p: Option<f32>) -> Self {
        self.top_p = p;
        self
    }

    /// Pick the next token from logits. Exactly equivalent to
    /// `self.draw(&self.dist(logits))` — the two-phase form the
    /// speculative verifier uses.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        let d = self.dist(logits);
        self.draw(&d)
    }

    /// Resolve `logits` into the post-filter sampling distribution.
    /// Pure: never touches the RNG, so the verify pass can inspect the
    /// distribution (acceptance tests, residuals) and only pay a
    /// uniform when it actually draws.
    pub fn dist(&self, logits: &[f32]) -> Dist {
        if self.temperature <= 0.0 {
            return Dist { cand: vec![(argmax(logits), 1.0)], greedy: true };
        }
        let k_active = matches!(self.top_k, Some(k) if k < logits.len());
        let p_active = matches!(self.top_p, Some(p) if p < 1.0);
        if !k_active && !p_active {
            return self.dist_full(logits);
        }
        self.dist_truncated(logits, k_active, p_active)
    }

    /// One inverse-CDF draw from a resolved distribution. Consumes
    /// exactly one uniform — except for the greedy point mass, which
    /// (like greedy [`Sampler::sample`] always did) consumes none.
    pub fn draw(&mut self, d: &Dist) -> u32 {
        if d.greedy {
            return d.cand[0].0;
        }
        self.draw_from(&d.cand)
    }

    /// Inverse-CDF draw from an explicit `(token, probability)` list
    /// (probabilities must be normalized) using this sampler's RNG
    /// stream — the residual-resampling primitive of the speculative
    /// accept loop. Consumes exactly one uniform.
    pub fn draw_from(&mut self, probs: &[(u32, f64)]) -> u32 {
        let mut u = self.rng.next_f64();
        for &(t, p) in probs {
            if u < p {
                return t;
            }
            u -= p;
        }
        probs.last().map(|&(t, _)| t).unwrap_or(0)
    }

    /// One raw uniform in `[0, 1)` from the sampler's RNG — the
    /// accept-test coin of generalized (non-point-mass) rejection
    /// sampling.
    pub fn next_uniform(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Softmax with temperature over all logits, in vocabulary order.
    /// Built directly as `(token, prob)` pairs — one allocation, like
    /// the pre-`Dist` sampler — with the exact same f64 operations in
    /// the same order, so draws stay bit-identical.
    fn dist_full(&self, logits: &[f32]) -> Dist {
        let inv_t = 1.0 / self.temperature;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut cand: Vec<(u32, f64)> = logits
            .iter()
            .enumerate()
            .map(|(i, &x)| (i as u32, (((x - m) * inv_t) as f64).exp()))
            .collect();
        let sum: f64 = cand.iter().map(|&(_, p)| p).sum();
        for (_, p) in cand.iter_mut() {
            *p /= sum;
        }
        Dist { cand, greedy: false }
    }

    /// Temperature distribution over a truncated candidate set: top-k
    /// first (partition, O(V + k log k) — only the k survivors are
    /// sorted), then the nucleus cut over the candidate distribution. A
    /// pure top-p cut (no top-k) sorts the full distribution once per
    /// sampled token, which is fine at this vocabulary scale; compose
    /// with top-k to bound it. Candidates are ordered by (logit desc,
    /// index asc) so ties break deterministically.
    fn dist_truncated(&self, logits: &[f32], k_active: bool, p_active: bool) -> Dist {
        let desc = |a: &(f32, u32), b: &(f32, u32)| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        };
        let mut cand: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        if k_active {
            let k = self.top_k.expect("k_active").max(1);
            cand.select_nth_unstable_by(k - 1, desc);
            cand.truncate(k);
        }
        cand.sort_by(desc);
        let inv_t = 1.0 / self.temperature;
        let m = cand[0].0;
        // From here on work in (token, prob) pairs directly — same f64
        // operations in the same order as the probs-vector form, so
        // draws stay bit-identical, without a second support-sized
        // allocation on the sampling hot path.
        let mut pairs: Vec<(u32, f64)> =
            cand.iter().map(|&(x, t)| (t, (((x - m) * inv_t) as f64).exp())).collect();
        let sum: f64 = pairs.iter().map(|&(_, p)| p).sum();
        for (_, p) in pairs.iter_mut() {
            *p /= sum;
        }
        if p_active {
            // Smallest probability-sorted prefix with cumulative mass
            // >= p (always at least one candidate), then renormalize.
            let target = self.top_p.expect("p_active") as f64;
            let mut cum = 0.0f64;
            let mut keep = pairs.len();
            for (i, &(_, pr)) in pairs.iter().enumerate() {
                cum += pr;
                if cum >= target {
                    keep = i + 1;
                    break;
                }
            }
            pairs.truncate(keep);
            let nsum: f64 = pairs.iter().map(|&(_, p)| p).sum();
            for (_, p) in pairs.iter_mut() {
                *p /= nsum;
            }
        }
        Dist { cand: pairs, greedy: false }
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn temperature_zero_matches_argmax_everywhere() {
        let logits = [1.0f32, 1.0, 2.0, 0.5];
        assert_eq!(argmax(&logits), 2);
    }

    #[test]
    fn sampling_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        let b: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn high_temperature_explores() {
        let logits = [0.0f32, 0.1, 0.05, 0.02];
        let mut s = Sampler::new(2.0, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn top_k_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.61).cos()).collect();
        let draw = || -> Vec<u32> {
            let mut s = Sampler::new(0.9, 13).with_top_k(Some(5));
            (0..30).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(), draw());
    }

    #[test]
    fn top_k_never_leaves_the_top_set() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut top: Vec<(f32, usize)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let allowed: std::collections::HashSet<u32> =
            top[..4].iter().map(|&(_, i)| i as u32).collect();
        let mut s = Sampler::new(1.5, 21).with_top_k(Some(4));
        for _ in 0..300 {
            assert!(allowed.contains(&s.sample(&logits)));
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.3).sin()).collect();
        let mut s = Sampler::new(1.0, 5).with_top_k(Some(1));
        for _ in 0..20 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
    }

    #[test]
    fn top_k_at_vocab_matches_full_sampling() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let a: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits)))
            .collect();
        let b: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7).with_top_k(Some(16)), |s, _| Some(s.sample(&logits)))
            .collect();
        assert_eq!(a, b, "k >= vocab must take the full-softmax path");
    }

    /// The minimal nucleus of `logits` at temperature `t`: smallest
    /// probability-sorted (desc, ties by index asc) prefix with
    /// cumulative probability >= p — computed independently of the
    /// sampler's implementation.
    fn nucleus(logits: &[f32], t: f32, p: f64) -> std::collections::HashSet<u32> {
        let mut cand: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        cand.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
        });
        let m = cand[0].0;
        // Mirror the sampler's exact float ops ((x - m) * inv_t in f32)
        // so a 1-ulp difference cannot shift the nucleus boundary.
        let inv_t = 1.0 / t;
        let w: Vec<f64> =
            cand.iter().map(|&(x, _)| (((x - m) * inv_t) as f64).exp()).collect();
        let sum: f64 = w.iter().sum();
        let mut cum = 0.0;
        let mut keep = std::collections::HashSet::new();
        for (i, &wi) in w.iter().enumerate() {
            cum += wi / sum;
            keep.insert(cand[i].1);
            if cum >= p {
                break;
            }
        }
        keep
    }

    #[test]
    fn top_p_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.53).sin()).collect();
        let draw = || -> Vec<u32> {
            let mut s = Sampler::new(0.9, 17).with_top_p(Some(0.7));
            (0..30).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(), draw());
    }

    #[test]
    fn top_p_never_leaves_the_nucleus() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.41).sin() * 2.0).collect();
        for &p in &[0.3f32, 0.6, 0.9] {
            let allowed = nucleus(&logits, 1.2, p as f64);
            let mut s = Sampler::new(1.2, 29).with_top_p(Some(p));
            for _ in 0..300 {
                let tok = s.sample(&logits);
                assert!(allowed.contains(&tok), "p={p}: token {tok} outside the nucleus");
            }
        }
    }

    #[test]
    fn top_p_one_matches_full_sampling() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let a: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits)))
            .collect();
        let b: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7).with_top_p(Some(1.0)), |s, _| Some(s.sample(&logits)))
            .collect();
        assert_eq!(a, b, "p >= 1 must take the full-softmax path");
    }

    #[test]
    fn tiny_top_p_is_greedy() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.1).cos()).collect();
        let mut s = Sampler::new(1.0, 9).with_top_p(Some(1e-6));
        for _ in 0..20 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
    }

    #[test]
    fn top_k_and_top_p_compose() {
        // The nucleus is taken over the top-k-restricted distribution:
        // draws must satisfy BOTH constraints.
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let k = 8;
        let mut top: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let topk: Vec<f32> = top[..k].iter().map(|&(x, _)| x).collect();
        let idx: Vec<u32> = top[..k].iter().map(|&(_, i)| i).collect();
        // Nucleus over the k retained logits, mapped back to vocab ids.
        let local = nucleus(&topk, 1.0, 0.6);
        let allowed: std::collections::HashSet<u32> =
            local.iter().map(|&li| idx[li as usize]).collect();
        let mut s = Sampler::new(1.0, 31).with_top_k(Some(k)).with_top_p(Some(0.6));
        for _ in 0..300 {
            let tok = s.sample(&logits);
            assert!(allowed.contains(&tok), "token {tok} violates top-k+top-p");
        }
    }

    #[test]
    fn dist_plus_draw_replays_sample_exactly() {
        // The two-phase form (dist then draw) must reproduce sample()
        // bit for bit — same tokens, same RNG stream — in every filter
        // configuration. This is the property sampled speculative
        // verification stands on.
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.43).sin() * 2.0).collect();
        let configs: [(f32, Option<usize>, Option<f32>); 5] = [
            (0.0, None, None),
            (0.8, None, None),
            (0.9, Some(8), None),
            (1.1, None, Some(0.7)),
            (0.7, Some(12), Some(0.8)),
        ];
        for &(t, k, p) in &configs {
            let mut a = Sampler::new(t, 99).with_top_k(k).with_top_p(p);
            let mut b = Sampler::new(t, 99).with_top_k(k).with_top_p(p);
            for _ in 0..40 {
                let d = b.dist(&logits);
                assert_eq!(a.sample(&logits), b.draw(&d), "t={t} k={k:?} p={p:?}");
            }
        }
    }

    #[test]
    fn dist_probs_are_normalized_over_the_support() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).cos()).collect();
        for s in [
            Sampler::new(0.8, 1),
            Sampler::new(0.8, 1).with_top_k(Some(5)),
            Sampler::new(1.2, 1).with_top_p(Some(0.6)),
        ] {
            let d = s.dist(&logits);
            let sum: f64 = d.support().iter().map(|&(_, p)| p).sum();
            assert!((sum - 1.0).abs() < 1e-9, "support mass {sum}");
            for &(t, p) in d.support() {
                assert!(p > 0.0);
                assert_eq!(d.prob_of(t), p);
            }
            assert_eq!(d.prob_of(9999), 0.0, "outside the support");
        }
    }

    #[test]
    fn greedy_dist_is_a_point_mass_and_never_draws() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.3).sin()).collect();
        let mut s = Sampler::new(0.0, 7);
        let d = s.dist(&logits);
        assert!(d.is_greedy());
        assert_eq!(d.support().len(), 1);
        assert_eq!(s.draw(&d), argmax(&logits));
        // Drawing from the greedy dist consumed no randomness: the next
        // uniform equals a fresh same-seed sampler's first uniform.
        let mut fresh = Sampler::new(0.0, 7);
        assert_eq!(s.next_uniform(), fresh.next_uniform());
    }

    #[test]
    fn draw_from_follows_the_explicit_distribution() {
        let mut s = Sampler::new(1.0, 3);
        let probs = [(5u32, 0.25f64), (9, 0.5), (30, 0.25)];
        let mut counts = std::collections::HashMap::new();
        for _ in 0..4000 {
            *counts.entry(s.draw_from(&probs)).or_insert(0usize) += 1;
        }
        assert_eq!(counts.len(), 3);
        let c9 = counts[&9] as f64 / 4000.0;
        assert!((c9 - 0.5).abs() < 0.05, "p(9)≈{c9}");
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut s = Sampler::new(0.1, 4);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
