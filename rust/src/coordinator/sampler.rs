//! Token sampling: greedy argmax or seeded temperature sampling, with
//! optional top-k truncation of the candidate set.

use crate::util::XorShift;

pub struct Sampler {
    temperature: f32,
    top_k: Option<usize>,
    rng: XorShift,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Self {
        Sampler { temperature, top_k: None, rng: XorShift::new(seed) }
    }

    /// Restrict temperature sampling to the `k` highest logits. `None`
    /// (the default) samples the full distribution; greedy decoding is
    /// unaffected.
    pub fn with_top_k(mut self, k: Option<usize>) -> Self {
        self.top_k = k;
        self
    }

    /// Pick the next token from logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        match self.top_k {
            Some(k) if k < logits.len() => self.sample_top_k(logits, k.max(1)),
            _ => self.sample_full(logits),
        }
    }

    /// Softmax with temperature over all logits, inverse-CDF draw.
    fn sample_full(&mut self, logits: &[f32]) -> u32 {
        let inv_t = 1.0 / self.temperature;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - m) * inv_t) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        let mut u = self.rng.next_f64();
        for (i, &p) in probs.iter().enumerate() {
            if u < p {
                return i as u32;
            }
            u -= p;
        }
        (probs.len() - 1) as u32
    }

    /// Temperature draw over the `k` highest logits only. Candidates are
    /// ordered by (logit desc, index asc) so ties break deterministically;
    /// the top set is found by partitioning (O(V + k log k), not a full
    /// vocabulary sort — this runs once per sampled token).
    fn sample_top_k(&mut self, logits: &[f32], k: usize) -> u32 {
        let desc = |a: &(f32, u32), b: &(f32, u32)| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        };
        let mut cand: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        cand.select_nth_unstable_by(k - 1, desc);
        cand.truncate(k);
        cand.sort_by(desc);
        let inv_t = 1.0 / self.temperature;
        let m = cand[0].0;
        let mut probs: Vec<f64> =
            cand.iter().map(|&(x, _)| (((x - m) * inv_t) as f64).exp()).collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        let mut u = self.rng.next_f64();
        for (i, &p) in probs.iter().enumerate() {
            if u < p {
                return cand[i].1;
            }
            u -= p;
        }
        cand[cand.len() - 1].1
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn temperature_zero_matches_argmax_everywhere() {
        let logits = [1.0f32, 1.0, 2.0, 0.5];
        assert_eq!(argmax(&logits), 2);
    }

    #[test]
    fn sampling_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        let b: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn high_temperature_explores() {
        let logits = [0.0f32, 0.1, 0.05, 0.02];
        let mut s = Sampler::new(2.0, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn top_k_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.61).cos()).collect();
        let draw = || -> Vec<u32> {
            let mut s = Sampler::new(0.9, 13).with_top_k(Some(5));
            (0..30).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(), draw());
    }

    #[test]
    fn top_k_never_leaves_the_top_set() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut top: Vec<(f32, usize)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let allowed: std::collections::HashSet<u32> =
            top[..4].iter().map(|&(_, i)| i as u32).collect();
        let mut s = Sampler::new(1.5, 21).with_top_k(Some(4));
        for _ in 0..300 {
            assert!(allowed.contains(&s.sample(&logits)));
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.3).sin()).collect();
        let mut s = Sampler::new(1.0, 5).with_top_k(Some(1));
        for _ in 0..20 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
    }

    #[test]
    fn top_k_at_vocab_matches_full_sampling() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let a: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits)))
            .collect();
        let b: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7).with_top_k(Some(16)), |s, _| Some(s.sample(&logits)))
            .collect();
        assert_eq!(a, b, "k >= vocab must take the full-softmax path");
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut s = Sampler::new(0.1, 4);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
