//! Token sampling: greedy argmax or seeded temperature sampling, with
//! optional top-k and top-p (nucleus) truncation of the candidate set.
//!
//! Top-k and top-p compose the standard way: the candidate set is first
//! restricted to the `k` highest logits (if set), the temperature
//! softmax is taken over that set, and the nucleus cut then keeps the
//! smallest probability-sorted prefix whose cumulative mass reaches
//! `p`. Greedy decoding (`temperature <= 0`) ignores both. Besides
//! serving sampled requests, deterministic nucleus truncation is the
//! prerequisite for lossless *sampled* speculative verification later
//! (the verifier must be able to replay the exact truncated
//! distribution at every drafted position).

use crate::util::XorShift;

pub struct Sampler {
    temperature: f32,
    top_k: Option<usize>,
    top_p: Option<f32>,
    rng: XorShift,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Self {
        Sampler { temperature, top_k: None, top_p: None, rng: XorShift::new(seed) }
    }

    /// Restrict temperature sampling to the `k` highest logits. `None`
    /// (the default) samples the full distribution; greedy decoding is
    /// unaffected.
    pub fn with_top_k(mut self, k: Option<usize>) -> Self {
        self.top_k = k;
        self
    }

    /// Nucleus sampling: keep the smallest set of highest-probability
    /// tokens whose cumulative probability reaches `p`. `None` or
    /// `p >= 1.0` disables the cut; `p <= 0` degenerates to the single
    /// most probable candidate. Composes with [`Sampler::with_top_k`]
    /// (the nucleus is taken over the top-k-restricted distribution).
    pub fn with_top_p(mut self, p: Option<f32>) -> Self {
        self.top_p = p;
        self
    }

    /// Pick the next token from logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        let k_active = matches!(self.top_k, Some(k) if k < logits.len());
        let p_active = matches!(self.top_p, Some(p) if p < 1.0);
        if !k_active && !p_active {
            return self.sample_full(logits);
        }
        self.sample_truncated(logits, k_active, p_active)
    }

    /// Softmax with temperature over all logits, inverse-CDF draw.
    fn sample_full(&mut self, logits: &[f32]) -> u32 {
        let inv_t = 1.0 / self.temperature;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - m) * inv_t) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        let mut u = self.rng.next_f64();
        for (i, &p) in probs.iter().enumerate() {
            if u < p {
                return i as u32;
            }
            u -= p;
        }
        (probs.len() - 1) as u32
    }

    /// Temperature draw over a truncated candidate set: top-k first
    /// (partition, O(V + k log k) — only the k survivors are sorted),
    /// then the nucleus cut over the candidate distribution. A pure
    /// top-p cut (no top-k) sorts the full distribution once per
    /// sampled token, which is fine at this vocabulary scale; compose
    /// with top-k to bound it. Candidates are ordered by (logit desc,
    /// index asc) so ties break deterministically.
    fn sample_truncated(&mut self, logits: &[f32], k_active: bool, p_active: bool) -> u32 {
        let desc = |a: &(f32, u32), b: &(f32, u32)| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        };
        let mut cand: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        if k_active {
            let k = self.top_k.expect("k_active").max(1);
            cand.select_nth_unstable_by(k - 1, desc);
            cand.truncate(k);
        }
        cand.sort_by(desc);
        let inv_t = 1.0 / self.temperature;
        let m = cand[0].0;
        let mut probs: Vec<f64> =
            cand.iter().map(|&(x, _)| (((x - m) * inv_t) as f64).exp()).collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        if p_active {
            // Smallest probability-sorted prefix with cumulative mass
            // >= p (always at least one candidate), then renormalize.
            let target = self.top_p.expect("p_active") as f64;
            let mut cum = 0.0f64;
            let mut keep = probs.len();
            for (i, &pr) in probs.iter().enumerate() {
                cum += pr;
                if cum >= target {
                    keep = i + 1;
                    break;
                }
            }
            cand.truncate(keep);
            probs.truncate(keep);
            let nsum: f64 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= nsum;
            }
        }
        let mut u = self.rng.next_f64();
        for (i, &p) in probs.iter().enumerate() {
            if u < p {
                return cand[i].1;
            }
            u -= p;
        }
        cand[cand.len() - 1].1
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn temperature_zero_matches_argmax_everywhere() {
        let logits = [1.0f32, 1.0, 2.0, 0.5];
        assert_eq!(argmax(&logits), 2);
    }

    #[test]
    fn sampling_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        let b: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn high_temperature_explores() {
        let logits = [0.0f32, 0.1, 0.05, 0.02];
        let mut s = Sampler::new(2.0, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn top_k_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.61).cos()).collect();
        let draw = || -> Vec<u32> {
            let mut s = Sampler::new(0.9, 13).with_top_k(Some(5));
            (0..30).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(), draw());
    }

    #[test]
    fn top_k_never_leaves_the_top_set() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut top: Vec<(f32, usize)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let allowed: std::collections::HashSet<u32> =
            top[..4].iter().map(|&(_, i)| i as u32).collect();
        let mut s = Sampler::new(1.5, 21).with_top_k(Some(4));
        for _ in 0..300 {
            assert!(allowed.contains(&s.sample(&logits)));
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.3).sin()).collect();
        let mut s = Sampler::new(1.0, 5).with_top_k(Some(1));
        for _ in 0..20 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
    }

    #[test]
    fn top_k_at_vocab_matches_full_sampling() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let a: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits)))
            .collect();
        let b: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7).with_top_k(Some(16)), |s, _| Some(s.sample(&logits)))
            .collect();
        assert_eq!(a, b, "k >= vocab must take the full-softmax path");
    }

    /// The minimal nucleus of `logits` at temperature `t`: smallest
    /// probability-sorted (desc, ties by index asc) prefix with
    /// cumulative probability >= p — computed independently of the
    /// sampler's implementation.
    fn nucleus(logits: &[f32], t: f32, p: f64) -> std::collections::HashSet<u32> {
        let mut cand: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        cand.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
        });
        let m = cand[0].0;
        // Mirror the sampler's exact float ops ((x - m) * inv_t in f32)
        // so a 1-ulp difference cannot shift the nucleus boundary.
        let inv_t = 1.0 / t;
        let w: Vec<f64> =
            cand.iter().map(|&(x, _)| (((x - m) * inv_t) as f64).exp()).collect();
        let sum: f64 = w.iter().sum();
        let mut cum = 0.0;
        let mut keep = std::collections::HashSet::new();
        for (i, &wi) in w.iter().enumerate() {
            cum += wi / sum;
            keep.insert(cand[i].1);
            if cum >= p {
                break;
            }
        }
        keep
    }

    #[test]
    fn top_p_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.53).sin()).collect();
        let draw = || -> Vec<u32> {
            let mut s = Sampler::new(0.9, 17).with_top_p(Some(0.7));
            (0..30).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(), draw());
    }

    #[test]
    fn top_p_never_leaves_the_nucleus() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.41).sin() * 2.0).collect();
        for &p in &[0.3f32, 0.6, 0.9] {
            let allowed = nucleus(&logits, 1.2, p as f64);
            let mut s = Sampler::new(1.2, 29).with_top_p(Some(p));
            for _ in 0..300 {
                let tok = s.sample(&logits);
                assert!(allowed.contains(&tok), "p={p}: token {tok} outside the nucleus");
            }
        }
    }

    #[test]
    fn top_p_one_matches_full_sampling() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let a: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits)))
            .collect();
        let b: Vec<u32> = (0..20)
            .scan(Sampler::new(0.8, 7).with_top_p(Some(1.0)), |s, _| Some(s.sample(&logits)))
            .collect();
        assert_eq!(a, b, "p >= 1 must take the full-softmax path");
    }

    #[test]
    fn tiny_top_p_is_greedy() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 1.1).cos()).collect();
        let mut s = Sampler::new(1.0, 9).with_top_p(Some(1e-6));
        for _ in 0..20 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
    }

    #[test]
    fn top_k_and_top_p_compose() {
        // The nucleus is taken over the top-k-restricted distribution:
        // draws must satisfy BOTH constraints.
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let k = 8;
        let mut top: Vec<(f32, u32)> =
            logits.iter().enumerate().map(|(i, &x)| (x, i as u32)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let topk: Vec<f32> = top[..k].iter().map(|&(x, _)| x).collect();
        let idx: Vec<u32> = top[..k].iter().map(|&(_, i)| i).collect();
        // Nucleus over the k retained logits, mapped back to vocab ids.
        let local = nucleus(&topk, 1.0, 0.6);
        let allowed: std::collections::HashSet<u32> =
            local.iter().map(|&li| idx[li as usize]).collect();
        let mut s = Sampler::new(1.0, 31).with_top_k(Some(k)).with_top_p(Some(0.6));
        for _ in 0..300 {
            let tok = s.sample(&logits);
            assert!(allowed.contains(&tok), "token {tok} violates top-k+top-p");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut s = Sampler::new(0.1, 4);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
