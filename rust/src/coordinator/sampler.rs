//! Token sampling: greedy argmax or seeded temperature sampling.

use crate::util::XorShift;

pub struct Sampler {
    temperature: f32,
    rng: XorShift,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Self {
        Sampler { temperature, rng: XorShift::new(seed) }
    }

    /// Pick the next token from logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        // Softmax with temperature, inverse-CDF draw.
        let inv_t = 1.0 / self.temperature;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - m) * inv_t) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        let mut u = self.rng.next_f64();
        for (i, &p) in probs.iter().enumerate() {
            if u < p {
                return i as u32;
            }
            u -= p;
        }
        (probs.len() - 1) as u32
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn temperature_zero_matches_argmax_everywhere() {
        let logits = [1.0f32, 1.0, 2.0, 0.5];
        assert_eq!(argmax(&logits), 2);
    }

    #[test]
    fn sampling_is_seeded_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        let b: Vec<u32> =
            (0..20).scan(Sampler::new(0.8, 7), |s, _| Some(s.sample(&logits))).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn high_temperature_explores() {
        let logits = [0.0f32, 0.1, 0.05, 0.02];
        let mut s = Sampler::new(2.0, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut s = Sampler::new(0.1, 4);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
