//! Serving metrics: request counters, latency distributions, throughput.

use crate::util::json::Json;
use crate::util::stats::Welford;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    pub ttft_ms: Welford,
    pub decode_step_ms: Welford,
    pub prefill_tokens_per_round: Welford,
    pub batch_occupancy: Welford,
    pub kv_peak_bytes: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            prompt_tokens: 0,
            gen_tokens: 0,
            ttft_ms: Welford::new(),
            decode_step_ms: Welford::new(),
            prefill_tokens_per_round: Welford::new(),
            batch_occupancy: Welford::new(),
            kv_peak_bytes: 0,
        }
    }

    /// Aggregate decode throughput since start (tokens/sec).
    pub fn decode_tps(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el > 0.0 {
            self.gen_tokens as f64 / el
        } else {
            0.0
        }
    }

    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("uptime_s", Json::num(self.started.elapsed().as_secs_f64())),
            ("requests_submitted", Json::num(self.requests_submitted as f64)),
            ("requests_finished", Json::num(self.requests_finished as f64)),
            ("requests_rejected", Json::num(self.requests_rejected as f64)),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("gen_tokens", Json::num(self.gen_tokens as f64)),
            ("decode_tps", Json::num(self.decode_tps())),
            ("ttft_ms_mean", Json::num(self.ttft_ms.mean())),
            ("ttft_ms_max", Json::num(self.ttft_ms.max())),
            ("decode_step_ms_mean", Json::num(self.decode_step_ms.mean())),
            ("batch_occupancy_mean", Json::num(self.batch_occupancy.mean())),
            ("kv_peak_bytes", Json::num(self.kv_peak_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_core_fields() {
        let mut m = Metrics::new();
        m.requests_submitted = 3;
        m.gen_tokens = 42;
        m.ttft_ms.push(12.5);
        let s = m.snapshot();
        assert_eq!(s.get("requests_submitted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("gen_tokens").unwrap().as_u64(), Some(42));
        assert!(s.get("ttft_ms_mean").unwrap().as_f64().unwrap() > 12.0);
    }
}
