//! Serving metrics: request counters, latency distributions, throughput.
//!
//! Distributions use [`RingStats`] — exact streaming mean/max plus
//! p50/p99 over a fixed-capacity recent window — so memory stays flat
//! under sustained load (no unbounded per-request vectors).

use crate::util::json::Json;
use crate::util::profile::{NUM_PHASES, PHASE_NAMES};
use crate::util::stats::{LogHistogram, RingStats};
use std::time::Instant;

/// Retained samples per distribution (percentile window).
const WINDOW: usize = 1024;

#[derive(Clone, Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub requests_cancelled: u64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    /// Prompt tokens skipped because the prefix cache already held them.
    pub prefix_reused_tokens: u64,
    /// Sequences preempted back to the waiting queue under KV pressure.
    pub preemptions: u64,
    pub ttft_ms: RingStats,
    /// Per-token decode latency. Since the fused batched decode round
    /// (PR 3) this is the round's wall time divided by its batch size,
    /// pushed once per sequence — amortized-per-token, so within one
    /// round all samples are equal and p50/p99 reflect across-round
    /// variance only (per-sequence variance inside a fused call is not
    /// attributable). Keys are unchanged; semantics shifted from
    /// measured-per-step.
    pub decode_step_ms: RingStats,
    pub prefill_tokens_per_round: RingStats,
    pub batch_occupancy: RingStats,
    /// Sequences per fused `decode_batch` call (how much GEMM batching
    /// each decode round actually got, vs `batch_occupancy` which also
    /// counts prefill-only sequences).
    pub decode_batch_size: RingStats,
    /// Draft tokens proposed to speculative verify passes.
    pub spec_drafted: u64,
    /// Draft tokens accepted (each one saved a full decode pass).
    pub spec_accepted: u64,
    /// Per-verify-round acceptance rate (accepted / drafted), all
    /// modes pooled.
    pub spec_accept_rate: RingStats,
    /// Acceptance rate of greedy-mode verify rounds only (exact argmax
    /// matching).
    pub spec_accept_rate_greedy: RingStats,
    /// Acceptance rate of sampled-mode verify rounds only (stochastic
    /// rejection-sampling acceptance).
    pub spec_accept_rate_sampled: RingStats,
    /// Sampled-mode verify rounds whose correction token came from
    /// residual resampling after a rejected draft.
    pub spec_resampled: u64,
    /// Per-verify-round accepted-run length (0..=draft_len).
    pub spec_run_len: RingStats,
    pub kv_peak_bytes: usize,
    /// Paged-pool snapshot fragment (block/prefix stats), refreshed on
    /// each stats request.
    pub kv_pool: Json,
    /// Connection handlers that exited with an IO/protocol error
    /// (logged once per connection by the server accept loop).
    pub conn_errors: u64,
    /// Requests shed at admission because the queue was at
    /// `--max-queue-depth` (each received a typed `Overloaded` error
    /// with a `retry_after_ms` hint).
    pub rejected_overload: u64,
    /// Requests whose deadline expired — queued or mid-generation.
    pub deadline_expired: u64,
    /// Times the worker caught an engine panic and rebuilt the engine
    /// scratch + KV pool, requeuing the surviving sequences.
    pub worker_restarts: u64,
    /// Admission-queue depth sampled once per scheduling round.
    pub queue_depth: RingStats,
    /// True wall time of each decode stage (one fused round: spec
    /// verify passes plus the batched decode call plus sampling) —
    /// complements the batch-amortized `decode_step_ms`, whose samples
    /// divide away the batch size. Round-level variance (a slow round
    /// among fast ones) is directly visible here.
    pub decode_round_ms: RingStats,
    /// Per-round engine-phase wall time (`util/profile.rs` order:
    /// rot_quant, gemm, attention, sampler). Only fed when built with
    /// `--features profiling`; empty rings otherwise, and the
    /// `phase_*_ms` snapshot keys are omitted so the default-feature
    /// snapshot stays byte-identical.
    pub phase_ms: [RingStats; NUM_PHASES],
    /// Process-lifetime TTFT histogram backing the Prometheus
    /// exposition (exact bounded-memory bucket counts, unlike the
    /// windowed ring above).
    pub ttft_hist: LogHistogram,
    /// Process-lifetime decode-round-time histogram (Prometheus).
    pub decode_round_hist: LogHistogram,
    /// Number of data-parallel engine replicas behind this snapshot
    /// (PR 8). 1 for a single-engine coordinator; [`Metrics::merge_from`]
    /// never sums it — the dispatcher stamps the true count after
    /// merging the per-replica accumulators.
    pub replicas: usize,
    /// Logit-drift shadow probes run (`--audit-sample-rate` decode
    /// rounds re-scored through the f32 reference path).
    pub audit_rounds: u64,
    /// Probes whose KL divergence exceeded `--audit-drift-warn` (each
    /// also lands a flight-recorder event naming the request).
    pub audit_drift_events: u64,
    /// KL(quantized ‖ reference) per probe, in nats.
    pub audit_logit_kl: RingStats,
    /// Greedy top-1 agreement per probe (1.0 agree / 0.0 disagree, so
    /// the windowed mean is the agreement rate).
    pub audit_top1_agree: RingStats,
    /// Largest absolute per-logit deviation per probe.
    pub audit_max_logit_delta: RingStats,
    /// Per-layer residual-stream rel-L2 per probe — the
    /// error-accumulation profile. Sized to the engine's layer count on
    /// first probe (empty until then), surfaced as one JSON array key so
    /// the snapshot key set stays model-independent.
    pub audit_layer_rel_l2: Vec<RingStats>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Merge one paged-pool stats fragment into another: counter keys sum;
/// configuration keys (`kv_block_tokens`, `kv_block_bytes`, `kv_quant`)
/// keep the receiver's value (identical across replicas by
/// construction); `prefix_hit_ratio` is recomputed from the merged
/// `prefix_hit_tokens` / `prefix_lookup_tokens` so it stays a true
/// ratio rather than a sum of ratios. A `Null` receiver (fragment never
/// refreshed) takes the other side verbatim — the N=1 byte-identity
/// path.
fn merge_pool_fragment(dst: &mut Json, src: &Json) {
    let Json::Obj(s) = src else { return };
    match dst {
        Json::Obj(d) => {
            for (k, v) in s {
                match k.as_str() {
                    "kv_block_tokens" | "kv_block_bytes" | "kv_quant" | "prefix_hit_ratio" => {}
                    _ => {
                        if let (Some(a), Some(b)) =
                            (d.get(k).and_then(|x| x.as_f64()), v.as_f64())
                        {
                            d.insert(k.clone(), Json::num(a + b));
                        }
                    }
                }
            }
            let hit = d.get("prefix_hit_tokens").and_then(|x| x.as_f64()).unwrap_or(0.0);
            let lookups = d
                .get("prefix_lookup_tokens")
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0)
                .max(1.0);
            d.insert("prefix_hit_ratio".to_string(), Json::num(hit / lookups));
        }
        _ => *dst = src.clone(),
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            requests_cancelled: 0,
            prompt_tokens: 0,
            gen_tokens: 0,
            prefix_reused_tokens: 0,
            preemptions: 0,
            ttft_ms: RingStats::new(WINDOW),
            decode_step_ms: RingStats::new(WINDOW),
            prefill_tokens_per_round: RingStats::new(WINDOW),
            batch_occupancy: RingStats::new(WINDOW),
            decode_batch_size: RingStats::new(WINDOW),
            spec_drafted: 0,
            spec_accepted: 0,
            spec_accept_rate: RingStats::new(WINDOW),
            spec_accept_rate_greedy: RingStats::new(WINDOW),
            spec_accept_rate_sampled: RingStats::new(WINDOW),
            spec_resampled: 0,
            spec_run_len: RingStats::new(WINDOW),
            kv_peak_bytes: 0,
            kv_pool: Json::Null,
            conn_errors: 0,
            rejected_overload: 0,
            deadline_expired: 0,
            worker_restarts: 0,
            queue_depth: RingStats::new(WINDOW),
            decode_round_ms: RingStats::new(WINDOW),
            phase_ms: std::array::from_fn(|_| RingStats::new(WINDOW)),
            ttft_hist: LogHistogram::latency_ms(),
            decode_round_hist: LogHistogram::latency_ms(),
            replicas: 1,
            audit_rounds: 0,
            audit_drift_events: 0,
            audit_logit_kl: RingStats::new(WINDOW),
            audit_top1_agree: RingStats::new(WINDOW),
            audit_max_logit_delta: RingStats::new(WINDOW),
            audit_layer_rel_l2: Vec::new(),
        }
    }

    /// Record one logit-drift shadow probe (the caller decides
    /// separately whether it also counts as a drift event).
    pub fn record_audit(&mut self, kl: f64, top1: bool, max_delta: f64, layer_rel_l2: &[f64]) {
        self.audit_rounds += 1;
        self.audit_logit_kl.push(kl);
        self.audit_top1_agree.push(if top1 { 1.0 } else { 0.0 });
        self.audit_max_logit_delta.push(max_delta);
        while self.audit_layer_rel_l2.len() < layer_rel_l2.len() {
            self.audit_layer_rel_l2.push(RingStats::new(WINDOW));
        }
        for (ring, &v) in self.audit_layer_rel_l2.iter_mut().zip(layer_rel_l2) {
            ring.push(v);
        }
    }

    /// Fold another accumulator into this one — the replica-aggregation
    /// path (PR 8): the dispatcher clones its intake metrics, merges
    /// each replica's accumulator, and snapshots the result. Counters
    /// sum; rings and histograms combine via their own `merge_from`
    /// (exact for counts/means, windows concatenate); `kv_peak_bytes`
    /// sums (per-replica pools are disjoint slices of the budget); the
    /// paged-pool fragment sums its counters and recomputes the hit
    /// ratio. Because every counter has exactly one writer (intake vs.
    /// replica round), merging a single replica into a fresh intake
    /// clone reproduces today's single-worker snapshot byte for byte.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.requests_submitted += other.requests_submitted;
        self.requests_finished += other.requests_finished;
        self.requests_rejected += other.requests_rejected;
        self.requests_cancelled += other.requests_cancelled;
        self.prompt_tokens += other.prompt_tokens;
        self.gen_tokens += other.gen_tokens;
        self.prefix_reused_tokens += other.prefix_reused_tokens;
        self.preemptions += other.preemptions;
        self.ttft_ms.merge_from(&other.ttft_ms);
        self.decode_step_ms.merge_from(&other.decode_step_ms);
        self.prefill_tokens_per_round.merge_from(&other.prefill_tokens_per_round);
        self.batch_occupancy.merge_from(&other.batch_occupancy);
        self.decode_batch_size.merge_from(&other.decode_batch_size);
        self.spec_drafted += other.spec_drafted;
        self.spec_accepted += other.spec_accepted;
        self.spec_accept_rate.merge_from(&other.spec_accept_rate);
        self.spec_accept_rate_greedy.merge_from(&other.spec_accept_rate_greedy);
        self.spec_accept_rate_sampled.merge_from(&other.spec_accept_rate_sampled);
        self.spec_resampled += other.spec_resampled;
        self.spec_run_len.merge_from(&other.spec_run_len);
        self.kv_peak_bytes += other.kv_peak_bytes;
        merge_pool_fragment(&mut self.kv_pool, &other.kv_pool);
        self.conn_errors += other.conn_errors;
        self.rejected_overload += other.rejected_overload;
        self.deadline_expired += other.deadline_expired;
        self.worker_restarts += other.worker_restarts;
        self.queue_depth.merge_from(&other.queue_depth);
        self.decode_round_ms.merge_from(&other.decode_round_ms);
        for (a, b) in self.phase_ms.iter_mut().zip(&other.phase_ms) {
            a.merge_from(b);
        }
        self.ttft_hist.merge_from(&other.ttft_hist);
        self.decode_round_hist.merge_from(&other.decode_round_hist);
        self.audit_rounds += other.audit_rounds;
        self.audit_drift_events += other.audit_drift_events;
        self.audit_logit_kl.merge_from(&other.audit_logit_kl);
        self.audit_top1_agree.merge_from(&other.audit_top1_agree);
        self.audit_max_logit_delta.merge_from(&other.audit_max_logit_delta);
        while self.audit_layer_rel_l2.len() < other.audit_layer_rel_l2.len() {
            self.audit_layer_rel_l2.push(RingStats::new(WINDOW));
        }
        for (a, b) in self.audit_layer_rel_l2.iter_mut().zip(&other.audit_layer_rel_l2) {
            a.merge_from(b);
        }
        // `started` and `replicas` stay: uptime is the receiver's, and
        // the replica count is stamped by the dispatcher, not summed.
    }

    /// Aggregate decode throughput since start (tokens/sec).
    pub fn decode_tps(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el > 0.0 {
            self.gen_tokens as f64 / el
        } else {
            0.0
        }
    }

    pub fn snapshot(&self) -> Json {
        let mut fields = vec![
            ("uptime_s", Json::num(self.started.elapsed().as_secs_f64())),
            ("requests_submitted", Json::num(self.requests_submitted as f64)),
            ("requests_finished", Json::num(self.requests_finished as f64)),
            ("requests_rejected", Json::num(self.requests_rejected as f64)),
            ("requests_cancelled", Json::num(self.requests_cancelled as f64)),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("gen_tokens", Json::num(self.gen_tokens as f64)),
            ("prefix_reused_tokens", Json::num(self.prefix_reused_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("decode_tps", Json::num(self.decode_tps())),
            ("ttft_ms_mean", Json::num(self.ttft_ms.mean())),
            ("ttft_ms_p50", Json::num(self.ttft_ms.p50())),
            ("ttft_ms_p99", Json::num(self.ttft_ms.p99())),
            ("ttft_ms_max", Json::num(self.ttft_ms.max())),
            ("decode_step_ms_mean", Json::num(self.decode_step_ms.mean())),
            ("decode_step_ms_p50", Json::num(self.decode_step_ms.p50())),
            ("decode_step_ms_p99", Json::num(self.decode_step_ms.p99())),
            ("batch_occupancy_mean", Json::num(self.batch_occupancy.mean())),
            ("batch_occupancy_max", Json::num(self.batch_occupancy.max())),
            ("decode_batch_size_mean", Json::num(self.decode_batch_size.mean())),
            ("decode_batch_size_max", Json::num(self.decode_batch_size.max())),
            // Speculation counters are appended after the pre-existing
            // keys so every older key keeps its name and meaning.
            ("spec_drafted_total", Json::num(self.spec_drafted as f64)),
            ("spec_accepted_total", Json::num(self.spec_accepted as f64)),
            ("spec_accept_rate_mean", Json::num(self.spec_accept_rate.mean())),
            ("spec_accept_rate_p50", Json::num(self.spec_accept_rate.p50())),
            ("spec_accept_rate_p99", Json::num(self.spec_accept_rate.p99())),
            ("spec_run_len_mean", Json::num(self.spec_run_len.mean())),
            ("spec_run_len_p50", Json::num(self.spec_run_len.p50())),
            ("spec_run_len_p99", Json::num(self.spec_run_len.p99())),
            ("spec_run_len_max", Json::num(self.spec_run_len.max())),
            ("kv_peak_bytes", Json::num(self.kv_peak_bytes as f64)),
        ];
        // Splice in the paged-pool fragment (flat keys, stable shape).
        if let Json::Obj(pool) = &self.kv_pool {
            for (k, v) in pool {
                fields.push((k.as_str(), v.clone()));
            }
        }
        // Sampled-speculation keys (PR 5), appended after every
        // pre-existing key — including the pool fragment — so the
        // snapshot stays append-only for positional/streaming readers.
        fields.push(("spec_resample_total", Json::num(self.spec_resampled as f64)));
        fields.push((
            "spec_accept_rate_greedy_mean",
            Json::num(self.spec_accept_rate_greedy.mean()),
        ));
        fields.push(("spec_accept_rate_greedy_p50", Json::num(self.spec_accept_rate_greedy.p50())));
        fields.push(("spec_accept_rate_greedy_p99", Json::num(self.spec_accept_rate_greedy.p99())));
        fields.push((
            "spec_accept_rate_sampled_mean",
            Json::num(self.spec_accept_rate_sampled.mean()),
        ));
        fields.push((
            "spec_accept_rate_sampled_p50",
            Json::num(self.spec_accept_rate_sampled.p50()),
        ));
        fields.push((
            "spec_accept_rate_sampled_p99",
            Json::num(self.spec_accept_rate_sampled.p99()),
        ));
        // Robustness keys (PR 6), appended last for the same
        // append-only reason.
        fields.push(("conn_errors", Json::num(self.conn_errors as f64)));
        fields.push(("rejected_overload", Json::num(self.rejected_overload as f64)));
        fields.push(("deadline_expired", Json::num(self.deadline_expired as f64)));
        fields.push(("worker_restarts", Json::num(self.worker_restarts as f64)));
        fields.push(("queue_depth_mean", Json::num(self.queue_depth.mean())));
        fields.push(("queue_depth_p50", Json::num(self.queue_depth.p50())));
        fields.push(("queue_depth_p99", Json::num(self.queue_depth.p99())));
        fields.push(("queue_depth_max", Json::num(self.queue_depth.max())));
        // Observability keys (PR 7), appended after everything above —
        // append-only as always.
        fields.push(("decode_round_ms_mean", Json::num(self.decode_round_ms.mean())));
        fields.push(("decode_round_ms_p50", Json::num(self.decode_round_ms.p50())));
        fields.push(("decode_round_ms_p99", Json::num(self.decode_round_ms.p99())));
        fields.push(("decode_round_ms_max", Json::num(self.decode_round_ms.max())));
        // Replica keys (PR 8), appended last — append-only as always.
        fields.push(("replicas", Json::num(self.replicas as f64)));
        // Numerics-audit keys (PR 9), appended after everything above —
        // append-only as always. The per-layer profile is one array key
        // (windowed mean per layer) so the key *set* stays independent
        // of the model's layer count.
        fields.push(("audit_rounds", Json::num(self.audit_rounds as f64)));
        fields.push(("audit_drift_events", Json::num(self.audit_drift_events as f64)));
        fields.push(("audit_logit_kl_mean", Json::num(self.audit_logit_kl.mean())));
        fields.push(("audit_logit_kl_p50", Json::num(self.audit_logit_kl.p50())));
        fields.push(("audit_logit_kl_p99", Json::num(self.audit_logit_kl.p99())));
        fields.push(("audit_logit_kl_max", Json::num(self.audit_logit_kl.max())));
        fields.push(("audit_top1_agree_mean", Json::num(self.audit_top1_agree.mean())));
        fields.push((
            "audit_max_logit_delta_mean",
            Json::num(self.audit_max_logit_delta.mean()),
        ));
        fields.push(("audit_max_logit_delta_max", Json::num(self.audit_max_logit_delta.max())));
        fields.push((
            "audit_layer_rel_l2",
            Json::Arr(self.audit_layer_rel_l2.iter().map(|r| Json::num(r.mean())).collect()),
        ));
        let mut snap = Json::obj(fields);
        // Phase-profile keys exist only when the profiler is compiled
        // in: with default features the snapshot is byte-identical to
        // a build without this code.
        if crate::util::profile::ENABLED {
            if let Json::Obj(m) = &mut snap {
                for (i, name) in PHASE_NAMES.iter().enumerate() {
                    m.insert(format!("phase_{name}_ms_mean"), Json::num(self.phase_ms[i].mean()));
                    m.insert(format!("phase_{name}_ms_p50"), Json::num(self.phase_ms[i].p50()));
                    m.insert(format!("phase_{name}_ms_p99"), Json::num(self.phase_ms[i].p99()));
                }
            }
        }
        snap
    }

    /// Render the metrics in Prometheus text exposition format
    /// (version 0.0.4): counters, gauges, summaries for the windowed
    /// rings, and true histograms from the [`LogHistogram`]s. Served
    /// by the `metrics` op (`docs/PROTOCOL.md`).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP itq3s_{name} {help}\n# TYPE itq3s_{name} counter\nitq3s_{name} {v}\n"
            ));
        };
        counter("requests_submitted_total", "Requests accepted at intake.", self.requests_submitted as f64);
        counter("requests_finished_total", "Requests that reached a Done terminal.", self.requests_finished as f64);
        counter("requests_rejected_total", "Requests rejected (context_full at admission).", self.requests_rejected as f64);
        counter("requests_cancelled_total", "Requests cancelled by client disconnect.", self.requests_cancelled as f64);
        counter("prompt_tokens_total", "Prompt tokens consumed.", self.prompt_tokens as f64);
        counter("gen_tokens_total", "Tokens generated.", self.gen_tokens as f64);
        counter("prefix_reused_tokens_total", "Prompt tokens served from the prefix cache.", self.prefix_reused_tokens as f64);
        counter("preemptions_total", "Sequences preempted under KV pressure.", self.preemptions as f64);
        counter("spec_drafted_total", "Draft tokens proposed to verify passes.", self.spec_drafted as f64);
        counter("spec_accepted_total", "Draft tokens accepted by verify passes.", self.spec_accepted as f64);
        counter("spec_resample_total", "Verify rounds corrected by residual resampling.", self.spec_resampled as f64);
        counter("conn_errors_total", "Connection handlers that exited with an error.", self.conn_errors as f64);
        counter("rejected_overload_total", "Requests shed at the admission-queue bound.", self.rejected_overload as f64);
        counter("deadline_expired_total", "Requests whose deadline expired.", self.deadline_expired as f64);
        counter("worker_restarts_total", "Panic-isolated scheduler restarts.", self.worker_restarts as f64);
        counter("audit_rounds_total", "Logit-drift shadow probes run.", self.audit_rounds as f64);
        counter("audit_drift_events_total", "Shadow probes whose KL exceeded --audit-drift-warn.", self.audit_drift_events as f64);

        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP itq3s_{name} {help}\n# TYPE itq3s_{name} gauge\nitq3s_{name} {v}\n"
            ));
        };
        gauge("uptime_seconds", "Seconds since the coordinator started.", self.started.elapsed().as_secs_f64());
        gauge("decode_tps", "Aggregate decode throughput (tokens/sec) since start.", self.decode_tps());
        gauge("kv_peak_bytes", "Peak KV pool bytes in use.", self.kv_peak_bytes as f64);
        gauge("replicas", "Data-parallel engine replicas behind this coordinator.", self.replicas as f64);
        gauge("audit_top1_agree_rate", "Windowed greedy top-1 agreement rate of shadow probes.", self.audit_top1_agree.mean());
        // Numeric paged-pool fragment keys ride along as gauges.
        if let Json::Obj(pool) = &self.kv_pool {
            for (k, v) in pool {
                if let Some(x) = v.as_f64() {
                    gauge(k, "Paged KV pool statistic (see docs/PROTOCOL.md stats keys).", x);
                }
            }
        }

        let mut summary = |name: &str, help: &str, r: &RingStats| {
            out.push_str(&format!("# HELP itq3s_{name} {help}\n# TYPE itq3s_{name} summary\n"));
            out.push_str(&format!("itq3s_{name}{{quantile=\"0.5\"}} {}\n", r.p50()));
            out.push_str(&format!("itq3s_{name}{{quantile=\"0.99\"}} {}\n", r.p99()));
            out.push_str(&format!("itq3s_{name}_sum {}\n", r.mean() * r.count() as f64));
            out.push_str(&format!("itq3s_{name}_count {}\n", r.count()));
        };
        summary("ttft_ms", "Submit-to-first-token latency (ms; windowed quantiles).", &self.ttft_ms);
        summary("decode_step_ms", "Batch-amortized per-token decode time (ms).", &self.decode_step_ms);
        summary("decode_round_ms", "True wall time per decode round (ms).", &self.decode_round_ms);
        summary("batch_occupancy", "Active sequences per scheduling round.", &self.batch_occupancy);
        summary("decode_batch_size", "Sequences per fused decode call.", &self.decode_batch_size);
        summary("spec_accept_rate", "Per-verify-round draft acceptance rate.", &self.spec_accept_rate);
        summary("spec_run_len", "Accepted-run length per verify round.", &self.spec_run_len);
        summary("queue_depth", "Admission-queue depth per scheduling round.", &self.queue_depth);
        summary("audit_logit_kl", "KL(quantized vs reference) per shadow probe (nats).", &self.audit_logit_kl);
        summary("audit_max_logit_delta", "Largest per-logit deviation per shadow probe.", &self.audit_max_logit_delta);
        if crate::util::profile::ENABLED {
            for (i, name) in PHASE_NAMES.iter().enumerate() {
                summary(
                    &format!("phase_{name}_ms"),
                    "Engine phase wall time per scheduling round (ms; --features profiling).",
                    &self.phase_ms[i],
                );
            }
        }

        let mut histogram = |name: &str, help: &str, h: &LogHistogram| {
            out.push_str(&format!("# HELP itq3s_{name} {help}\n# TYPE itq3s_{name} histogram\n"));
            for (le, cum) in h.cumulative() {
                let le = if le.is_infinite() { "+Inf".to_string() } else { le.to_string() };
                out.push_str(&format!("itq3s_{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("itq3s_{name}_sum {}\n", h.sum()));
            out.push_str(&format!("itq3s_{name}_count {}\n", h.count()));
        };
        histogram("ttft_ms_hist", "Submit-to-first-token latency (ms; lifetime histogram).", &self.ttft_hist);
        histogram("decode_round_ms_hist", "True wall time per decode round (ms; lifetime histogram).", &self.decode_round_hist);
        // Per-layer error-accumulation profile as a labelled gauge
        // family — absent entirely until the first probe runs, so an
        // audit-off exposition is unchanged.
        if !self.audit_layer_rel_l2.is_empty() {
            out.push_str(
                "# HELP itq3s_audit_layer_rel_l2 Windowed mean residual-stream rel-L2 drift per layer (shadow probes).\n# TYPE itq3s_audit_layer_rel_l2 gauge\n",
            );
            for (i, r) in self.audit_layer_rel_l2.iter().enumerate() {
                out.push_str(&format!("itq3s_audit_layer_rel_l2{{layer=\"{i}\"}} {}\n", r.mean()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_core_fields() {
        let mut m = Metrics::new();
        m.requests_submitted = 3;
        m.gen_tokens = 42;
        m.ttft_ms.push(12.5);
        let s = m.snapshot();
        assert_eq!(s.get("requests_submitted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("gen_tokens").unwrap().as_u64(), Some(42));
        assert!(s.get("ttft_ms_mean").unwrap().as_f64().unwrap() > 12.0);
        assert!(s.get("ttft_ms_p99").unwrap().as_f64().unwrap() > 12.0);
    }

    #[test]
    fn distributions_stay_bounded_under_load() {
        let mut m = Metrics::new();
        for i in 0..100_000 {
            m.decode_step_ms.push(i as f64 % 17.0);
            m.batch_occupancy.push((i % 8) as f64);
        }
        assert_eq!(m.decode_step_ms.count(), 100_000);
        let s = m.snapshot();
        assert_eq!(s.get("batch_occupancy_max").unwrap().as_f64(), Some(7.0));
        assert!(s.get("decode_step_ms_p50").unwrap().as_f64().unwrap() <= 17.0);
    }

    #[test]
    fn speculation_counters_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.spec_drafted = 12;
        m.spec_accepted = 9;
        m.spec_accept_rate.push(0.75);
        m.spec_run_len.push(3.0);
        let s = m.snapshot();
        assert_eq!(s.get("spec_drafted_total").unwrap().as_u64(), Some(12));
        assert_eq!(s.get("spec_accepted_total").unwrap().as_u64(), Some(9));
        assert!(s.get("spec_accept_rate_mean").unwrap().as_f64().unwrap() > 0.7);
        assert_eq!(s.get("spec_run_len_max").unwrap().as_f64(), Some(3.0));
        // Pre-existing keys are still present under their old names.
        for key in ["gen_tokens", "decode_step_ms_p99", "decode_batch_size_max", "kv_peak_bytes"] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn sampled_speculation_keys_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.spec_resampled = 4;
        m.spec_accept_rate_greedy.push(1.0);
        m.spec_accept_rate_sampled.push(0.5);
        let s = m.snapshot();
        assert_eq!(s.get("spec_resample_total").unwrap().as_u64(), Some(4));
        assert_eq!(s.get("spec_accept_rate_greedy_mean").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("spec_accept_rate_sampled_p50").unwrap().as_f64(), Some(0.5));
        // The pooled PR-4 speculation keys keep their old names and
        // meaning next to the new per-mode ones.
        for key in [
            "spec_drafted_total",
            "spec_accepted_total",
            "spec_accept_rate_mean",
            "spec_accept_rate_p50",
            "spec_accept_rate_p99",
            "spec_run_len_mean",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn robustness_keys_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.conn_errors = 2;
        m.rejected_overload = 7;
        m.deadline_expired = 3;
        m.worker_restarts = 1;
        m.queue_depth.push(4.0);
        m.queue_depth.push(6.0);
        let s = m.snapshot();
        assert_eq!(s.get("conn_errors").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("rejected_overload").unwrap().as_u64(), Some(7));
        assert_eq!(s.get("deadline_expired").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("worker_restarts").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("queue_depth_max").unwrap().as_f64(), Some(6.0));
        assert!(s.get("queue_depth_p50").unwrap().as_f64().unwrap() >= 4.0);
        assert!(s.get("queue_depth_p99").unwrap().as_f64().unwrap() >= 4.0);
        // Every pre-existing key family keeps its old name.
        for key in [
            "requests_cancelled",
            "spec_resample_total",
            "decode_step_ms_p50",
            "kv_peak_bytes",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn pool_fragment_is_spliced_flat() {
        let mut m = Metrics::new();
        m.kv_pool = Json::obj(vec![("kv_blocks_in_use", Json::num(5.0))]);
        let s = m.snapshot();
        assert_eq!(s.get("kv_blocks_in_use").unwrap().as_u64(), Some(5));
    }

    /// Golden append-only key test: the exact key set of
    /// `Metrics::snapshot` (sans the pool fragment, which the pool
    /// owns). A future PR may *add* keys — extend this list — but a
    /// missing or renamed key is a break for every stats consumer.
    #[test]
    fn snapshot_key_set_is_golden_append_only() {
        let mut expected: Vec<String> = [
            // PR 1-3 core.
            "uptime_s",
            "requests_submitted",
            "requests_finished",
            "requests_rejected",
            "requests_cancelled",
            "prompt_tokens",
            "gen_tokens",
            "prefix_reused_tokens",
            "preemptions",
            "decode_tps",
            "ttft_ms_mean",
            "ttft_ms_p50",
            "ttft_ms_p99",
            "ttft_ms_max",
            "decode_step_ms_mean",
            "decode_step_ms_p50",
            "decode_step_ms_p99",
            "batch_occupancy_mean",
            "batch_occupancy_max",
            "decode_batch_size_mean",
            "decode_batch_size_max",
            "kv_peak_bytes",
            // PR 4-5 speculation.
            "spec_drafted_total",
            "spec_accepted_total",
            "spec_accept_rate_mean",
            "spec_accept_rate_p50",
            "spec_accept_rate_p99",
            "spec_run_len_mean",
            "spec_run_len_p50",
            "spec_run_len_p99",
            "spec_run_len_max",
            "spec_resample_total",
            "spec_accept_rate_greedy_mean",
            "spec_accept_rate_greedy_p50",
            "spec_accept_rate_greedy_p99",
            "spec_accept_rate_sampled_mean",
            "spec_accept_rate_sampled_p50",
            "spec_accept_rate_sampled_p99",
            // PR 6 robustness.
            "conn_errors",
            "rejected_overload",
            "deadline_expired",
            "worker_restarts",
            "queue_depth_mean",
            "queue_depth_p50",
            "queue_depth_p99",
            "queue_depth_max",
            // PR 7 observability.
            "decode_round_ms_mean",
            "decode_round_ms_p50",
            "decode_round_ms_p99",
            "decode_round_ms_max",
            // PR 8 replicas.
            "replicas",
            // PR 9 numerics audit.
            "audit_rounds",
            "audit_drift_events",
            "audit_logit_kl_mean",
            "audit_logit_kl_p50",
            "audit_logit_kl_p99",
            "audit_logit_kl_max",
            "audit_top1_agree_mean",
            "audit_max_logit_delta_mean",
            "audit_max_logit_delta_max",
            "audit_layer_rel_l2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        if crate::util::profile::ENABLED {
            for name in PHASE_NAMES {
                for suffix in ["mean", "p50", "p99"] {
                    expected.push(format!("phase_{name}_ms_{suffix}"));
                }
            }
        }
        expected.sort();

        let Json::Obj(m) = Metrics::new().snapshot() else {
            panic!("snapshot must be an object")
        };
        let actual: Vec<String> = m.keys().cloned().collect();
        // Json::Obj is a BTreeMap, so serialization order is the
        // sorted key order — comparing the sorted lists pins the
        // serialized byte layout of the key set.
        assert_eq!(actual, expected, "snapshot keys changed; stats keys are append-only");
    }

    #[test]
    fn merge_single_replica_into_fresh_intake_reproduces_the_snapshot() {
        // The N=1 identity contract: dispatcher-side merging of one
        // replica's metrics into a fresh intake clone must reproduce
        // the single-worker snapshot exactly (uptime aside, which is
        // the receiver's clock).
        let mut replica = Metrics::new();
        replica.requests_finished = 4;
        replica.gen_tokens = 80;
        replica.ttft_ms.push(3.5);
        replica.decode_step_ms.push(1.25);
        replica.spec_accept_rate.push(0.5);
        replica.kv_peak_bytes = 4096;
        replica.kv_pool = Json::obj(vec![
            ("kv_block_tokens", Json::num(16.0)),
            ("prefix_hit_tokens", Json::num(8.0)),
            ("prefix_lookup_tokens", Json::num(32.0)),
            ("prefix_hit_ratio", Json::num(0.25)),
        ]);

        let mut merged = Metrics::new();
        merged.requests_submitted = 5; // intake-owned counter
        merged.merge_from(&replica);
        merged.replicas = 1;

        let a = merged.snapshot();
        let mut solo = replica.clone();
        solo.requests_submitted = 5;
        let b = solo.snapshot();
        for key in [
            "requests_submitted",
            "requests_finished",
            "gen_tokens",
            "ttft_ms_p50",
            "decode_step_ms_mean",
            "spec_accept_rate_p99",
            "kv_peak_bytes",
            "kv_block_tokens",
            "prefix_hit_tokens",
            "prefix_hit_ratio",
            "replicas",
        ] {
            assert_eq!(a.get(key), b.get(key), "merged N=1 differs on {key}");
        }
    }

    #[test]
    fn merge_two_replicas_sums_counters_and_recomputes_the_hit_ratio() {
        let mut a = Metrics::new();
        a.gen_tokens = 10;
        a.worker_restarts = 1;
        a.ttft_ms.push(2.0);
        a.kv_peak_bytes = 100;
        a.kv_pool = Json::obj(vec![
            ("kv_block_tokens", Json::num(16.0)),
            ("kv_blocks_in_use", Json::num(3.0)),
            ("prefix_hit_tokens", Json::num(4.0)),
            ("prefix_lookup_tokens", Json::num(8.0)),
            ("prefix_hit_ratio", Json::num(0.5)),
        ]);
        let mut b = Metrics::new();
        b.gen_tokens = 5;
        b.ttft_ms.push(4.0);
        b.kv_peak_bytes = 50;
        b.kv_pool = Json::obj(vec![
            ("kv_block_tokens", Json::num(16.0)),
            ("kv_blocks_in_use", Json::num(2.0)),
            ("prefix_hit_tokens", Json::num(0.0)),
            ("prefix_lookup_tokens", Json::num(8.0)),
            ("prefix_hit_ratio", Json::num(0.0)),
        ]);

        let mut merged = Metrics::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        merged.replicas = 2;
        let s = merged.snapshot();
        assert_eq!(s.get("gen_tokens").unwrap().as_u64(), Some(15));
        assert_eq!(s.get("worker_restarts").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("kv_peak_bytes").unwrap().as_u64(), Some(150));
        assert_eq!(s.get("replicas").unwrap().as_u64(), Some(2));
        // Config keys keep the first replica's value; counters sum.
        assert_eq!(s.get("kv_block_tokens").unwrap().as_u64(), Some(16));
        assert_eq!(s.get("kv_blocks_in_use").unwrap().as_u64(), Some(5));
        // Ratio recomputed over the merged totals: 4 / 16, not 0.5 + 0.
        assert_eq!(s.get("prefix_hit_ratio").unwrap().as_f64(), Some(0.25));
        // Rings pooled both samples.
        assert_eq!(merged.ttft_ms.count(), 2);
        assert_eq!(s.get("ttft_ms_max").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn audit_keys_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.record_audit(0.01, true, 0.3, &[0.001, 0.002]);
        m.record_audit(0.05, false, 0.9, &[0.002, 0.004]);
        m.audit_drift_events = 1;
        let s = m.snapshot();
        assert_eq!(s.get("audit_rounds").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("audit_drift_events").unwrap().as_u64(), Some(1));
        assert!((s.get("audit_logit_kl_mean").unwrap().as_f64().unwrap() - 0.03).abs() < 1e-12);
        assert_eq!(s.get("audit_logit_kl_max").unwrap().as_f64(), Some(0.05));
        assert_eq!(s.get("audit_top1_agree_mean").unwrap().as_f64(), Some(0.5));
        assert_eq!(s.get("audit_max_logit_delta_max").unwrap().as_f64(), Some(0.9));
        // One array key with a windowed mean per layer.
        let layers = s.get("audit_layer_rel_l2").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert!((layers[0].as_f64().unwrap() - 0.0015).abs() < 1e-12);
        assert!((layers[1].as_f64().unwrap() - 0.003).abs() < 1e-12);
        // Pre-existing key families keep their old names.
        for key in ["replicas", "decode_round_ms_max", "queue_depth_p99", "gen_tokens"] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
        // Prometheus exposition carries the new families, including the
        // per-layer labelled gauge.
        let text = m.prometheus();
        assert!(text.contains("itq3s_audit_rounds_total 2\n"));
        assert!(text.contains("itq3s_audit_drift_events_total 1\n"));
        assert!(text.contains("# TYPE itq3s_audit_logit_kl summary"));
        assert!(text.contains("itq3s_audit_top1_agree_rate 0.5\n"));
        assert!(text.contains("itq3s_audit_layer_rel_l2{layer=\"1\"}"));
        // No probes -> no per-layer family at all.
        assert!(!Metrics::new().prometheus().contains("audit_layer_rel_l2{"));
    }

    #[test]
    fn audit_rings_merge_across_replicas() {
        let mut a = Metrics::new();
        a.record_audit(0.02, true, 0.1, &[0.001]);
        a.audit_drift_events = 2;
        let mut b = Metrics::new();
        b.record_audit(0.04, false, 0.5, &[0.003]);
        let mut merged = Metrics::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        assert_eq!(merged.audit_rounds, 2);
        assert_eq!(merged.audit_drift_events, 2);
        assert_eq!(merged.audit_logit_kl.count(), 2);
        assert_eq!(merged.audit_top1_agree.count(), 2);
        assert_eq!(merged.audit_layer_rel_l2.len(), 1);
        assert_eq!(merged.audit_layer_rel_l2[0].count(), 2);
        assert!((merged.audit_layer_rel_l2[0].mean() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn decode_round_ms_surfaces_alongside_amortized_step_time() {
        let mut m = Metrics::new();
        // A 4-wide round that took 8 ms: amortized step time 2 ms,
        // true round time 8 ms.
        for _ in 0..4 {
            m.decode_step_ms.push(2.0);
        }
        m.decode_round_ms.push(8.0);
        let s = m.snapshot();
        assert_eq!(s.get("decode_step_ms_p50").unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("decode_round_ms_p50").unwrap().as_f64(), Some(8.0));
        assert_eq!(s.get("decode_round_ms_max").unwrap().as_f64(), Some(8.0));
    }

    #[test]
    fn prometheus_exposition_renders_counters_summaries_histograms() {
        let mut m = Metrics::new();
        m.requests_submitted = 3;
        m.gen_tokens = 42;
        m.ttft_ms.push(12.5);
        m.ttft_hist.push(12.5);
        m.decode_round_ms.push(4.0);
        m.decode_round_hist.push(4.0);
        m.kv_pool = Json::obj(vec![
            ("kv_blocks_in_use", Json::num(5.0)),
            ("kv_quant", Json::str("f32")), // non-numeric: skipped
        ]);
        let text = m.prometheus();
        assert!(text.contains("# TYPE itq3s_requests_submitted_total counter"));
        assert!(text.contains("itq3s_requests_submitted_total 3\n"));
        assert!(text.contains("itq3s_gen_tokens_total 42\n"));
        assert!(text.contains("# TYPE itq3s_ttft_ms summary"));
        assert!(text.contains("itq3s_ttft_ms{quantile=\"0.5\"} 12.5\n"));
        assert!(text.contains("itq3s_ttft_ms_count 1\n"));
        assert!(text.contains("# TYPE itq3s_ttft_ms_hist histogram"));
        assert!(text.contains("itq3s_ttft_ms_hist_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("itq3s_ttft_ms_hist_count 1\n"));
        assert!(text.contains("# TYPE itq3s_decode_round_ms summary"));
        assert!(text.contains("itq3s_kv_blocks_in_use 5\n"));
        assert!(!text.contains("kv_quant"), "non-numeric pool keys are not gauges");
        // The histogram's cumulative counts are monotone: the 12.5 ms
        // sample appears in the 16 ms bucket and everything above.
        assert!(text.contains("itq3s_ttft_ms_hist_bucket{le=\"16\"} 1\n"));
        assert!(text.contains("itq3s_ttft_ms_hist_bucket{le=\"8\"} 0\n"));
    }
}
