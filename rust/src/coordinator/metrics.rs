//! Serving metrics: request counters, latency distributions, throughput.
//!
//! Distributions use [`RingStats`] — exact streaming mean/max plus
//! p50/p99 over a fixed-capacity recent window — so memory stays flat
//! under sustained load (no unbounded per-request vectors).

use crate::util::json::Json;
use crate::util::stats::RingStats;
use std::time::Instant;

/// Retained samples per distribution (percentile window).
const WINDOW: usize = 1024;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub requests_cancelled: u64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    /// Prompt tokens skipped because the prefix cache already held them.
    pub prefix_reused_tokens: u64,
    /// Sequences preempted back to the waiting queue under KV pressure.
    pub preemptions: u64,
    pub ttft_ms: RingStats,
    /// Per-token decode latency. Since the fused batched decode round
    /// (PR 3) this is the round's wall time divided by its batch size,
    /// pushed once per sequence — amortized-per-token, so within one
    /// round all samples are equal and p50/p99 reflect across-round
    /// variance only (per-sequence variance inside a fused call is not
    /// attributable). Keys are unchanged; semantics shifted from
    /// measured-per-step.
    pub decode_step_ms: RingStats,
    pub prefill_tokens_per_round: RingStats,
    pub batch_occupancy: RingStats,
    /// Sequences per fused `decode_batch` call (how much GEMM batching
    /// each decode round actually got, vs `batch_occupancy` which also
    /// counts prefill-only sequences).
    pub decode_batch_size: RingStats,
    /// Draft tokens proposed to speculative verify passes.
    pub spec_drafted: u64,
    /// Draft tokens accepted (each one saved a full decode pass).
    pub spec_accepted: u64,
    /// Per-verify-round acceptance rate (accepted / drafted), all
    /// modes pooled.
    pub spec_accept_rate: RingStats,
    /// Acceptance rate of greedy-mode verify rounds only (exact argmax
    /// matching).
    pub spec_accept_rate_greedy: RingStats,
    /// Acceptance rate of sampled-mode verify rounds only (stochastic
    /// rejection-sampling acceptance).
    pub spec_accept_rate_sampled: RingStats,
    /// Sampled-mode verify rounds whose correction token came from
    /// residual resampling after a rejected draft.
    pub spec_resampled: u64,
    /// Per-verify-round accepted-run length (0..=draft_len).
    pub spec_run_len: RingStats,
    pub kv_peak_bytes: usize,
    /// Paged-pool snapshot fragment (block/prefix stats), refreshed on
    /// each stats request.
    pub kv_pool: Json,
    /// Connection handlers that exited with an IO/protocol error
    /// (logged once per connection by the server accept loop).
    pub conn_errors: u64,
    /// Requests shed at admission because the queue was at
    /// `--max-queue-depth` (each received a typed `Overloaded` error
    /// with a `retry_after_ms` hint).
    pub rejected_overload: u64,
    /// Requests whose deadline expired — queued or mid-generation.
    pub deadline_expired: u64,
    /// Times the worker caught an engine panic and rebuilt the engine
    /// scratch + KV pool, requeuing the surviving sequences.
    pub worker_restarts: u64,
    /// Admission-queue depth sampled once per scheduling round.
    pub queue_depth: RingStats,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            requests_cancelled: 0,
            prompt_tokens: 0,
            gen_tokens: 0,
            prefix_reused_tokens: 0,
            preemptions: 0,
            ttft_ms: RingStats::new(WINDOW),
            decode_step_ms: RingStats::new(WINDOW),
            prefill_tokens_per_round: RingStats::new(WINDOW),
            batch_occupancy: RingStats::new(WINDOW),
            decode_batch_size: RingStats::new(WINDOW),
            spec_drafted: 0,
            spec_accepted: 0,
            spec_accept_rate: RingStats::new(WINDOW),
            spec_accept_rate_greedy: RingStats::new(WINDOW),
            spec_accept_rate_sampled: RingStats::new(WINDOW),
            spec_resampled: 0,
            spec_run_len: RingStats::new(WINDOW),
            kv_peak_bytes: 0,
            kv_pool: Json::Null,
            conn_errors: 0,
            rejected_overload: 0,
            deadline_expired: 0,
            worker_restarts: 0,
            queue_depth: RingStats::new(WINDOW),
        }
    }

    /// Aggregate decode throughput since start (tokens/sec).
    pub fn decode_tps(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el > 0.0 {
            self.gen_tokens as f64 / el
        } else {
            0.0
        }
    }

    pub fn snapshot(&self) -> Json {
        let mut fields = vec![
            ("uptime_s", Json::num(self.started.elapsed().as_secs_f64())),
            ("requests_submitted", Json::num(self.requests_submitted as f64)),
            ("requests_finished", Json::num(self.requests_finished as f64)),
            ("requests_rejected", Json::num(self.requests_rejected as f64)),
            ("requests_cancelled", Json::num(self.requests_cancelled as f64)),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("gen_tokens", Json::num(self.gen_tokens as f64)),
            ("prefix_reused_tokens", Json::num(self.prefix_reused_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("decode_tps", Json::num(self.decode_tps())),
            ("ttft_ms_mean", Json::num(self.ttft_ms.mean())),
            ("ttft_ms_p50", Json::num(self.ttft_ms.p50())),
            ("ttft_ms_p99", Json::num(self.ttft_ms.p99())),
            ("ttft_ms_max", Json::num(self.ttft_ms.max())),
            ("decode_step_ms_mean", Json::num(self.decode_step_ms.mean())),
            ("decode_step_ms_p50", Json::num(self.decode_step_ms.p50())),
            ("decode_step_ms_p99", Json::num(self.decode_step_ms.p99())),
            ("batch_occupancy_mean", Json::num(self.batch_occupancy.mean())),
            ("batch_occupancy_max", Json::num(self.batch_occupancy.max())),
            ("decode_batch_size_mean", Json::num(self.decode_batch_size.mean())),
            ("decode_batch_size_max", Json::num(self.decode_batch_size.max())),
            // Speculation counters are appended after the pre-existing
            // keys so every older key keeps its name and meaning.
            ("spec_drafted_total", Json::num(self.spec_drafted as f64)),
            ("spec_accepted_total", Json::num(self.spec_accepted as f64)),
            ("spec_accept_rate_mean", Json::num(self.spec_accept_rate.mean())),
            ("spec_accept_rate_p50", Json::num(self.spec_accept_rate.p50())),
            ("spec_accept_rate_p99", Json::num(self.spec_accept_rate.p99())),
            ("spec_run_len_mean", Json::num(self.spec_run_len.mean())),
            ("spec_run_len_p50", Json::num(self.spec_run_len.p50())),
            ("spec_run_len_p99", Json::num(self.spec_run_len.p99())),
            ("spec_run_len_max", Json::num(self.spec_run_len.max())),
            ("kv_peak_bytes", Json::num(self.kv_peak_bytes as f64)),
        ];
        // Splice in the paged-pool fragment (flat keys, stable shape).
        if let Json::Obj(pool) = &self.kv_pool {
            for (k, v) in pool {
                fields.push((k.as_str(), v.clone()));
            }
        }
        // Sampled-speculation keys (PR 5), appended after every
        // pre-existing key — including the pool fragment — so the
        // snapshot stays append-only for positional/streaming readers.
        fields.push(("spec_resample_total", Json::num(self.spec_resampled as f64)));
        fields.push((
            "spec_accept_rate_greedy_mean",
            Json::num(self.spec_accept_rate_greedy.mean()),
        ));
        fields.push(("spec_accept_rate_greedy_p50", Json::num(self.spec_accept_rate_greedy.p50())));
        fields.push(("spec_accept_rate_greedy_p99", Json::num(self.spec_accept_rate_greedy.p99())));
        fields.push((
            "spec_accept_rate_sampled_mean",
            Json::num(self.spec_accept_rate_sampled.mean()),
        ));
        fields.push((
            "spec_accept_rate_sampled_p50",
            Json::num(self.spec_accept_rate_sampled.p50()),
        ));
        fields.push((
            "spec_accept_rate_sampled_p99",
            Json::num(self.spec_accept_rate_sampled.p99()),
        ));
        // Robustness keys (PR 6), appended last for the same
        // append-only reason.
        fields.push(("conn_errors", Json::num(self.conn_errors as f64)));
        fields.push(("rejected_overload", Json::num(self.rejected_overload as f64)));
        fields.push(("deadline_expired", Json::num(self.deadline_expired as f64)));
        fields.push(("worker_restarts", Json::num(self.worker_restarts as f64)));
        fields.push(("queue_depth_mean", Json::num(self.queue_depth.mean())));
        fields.push(("queue_depth_p50", Json::num(self.queue_depth.p50())));
        fields.push(("queue_depth_p99", Json::num(self.queue_depth.p99())));
        fields.push(("queue_depth_max", Json::num(self.queue_depth.max())));
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_core_fields() {
        let mut m = Metrics::new();
        m.requests_submitted = 3;
        m.gen_tokens = 42;
        m.ttft_ms.push(12.5);
        let s = m.snapshot();
        assert_eq!(s.get("requests_submitted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("gen_tokens").unwrap().as_u64(), Some(42));
        assert!(s.get("ttft_ms_mean").unwrap().as_f64().unwrap() > 12.0);
        assert!(s.get("ttft_ms_p99").unwrap().as_f64().unwrap() > 12.0);
    }

    #[test]
    fn distributions_stay_bounded_under_load() {
        let mut m = Metrics::new();
        for i in 0..100_000 {
            m.decode_step_ms.push(i as f64 % 17.0);
            m.batch_occupancy.push((i % 8) as f64);
        }
        assert_eq!(m.decode_step_ms.count(), 100_000);
        let s = m.snapshot();
        assert_eq!(s.get("batch_occupancy_max").unwrap().as_f64(), Some(7.0));
        assert!(s.get("decode_step_ms_p50").unwrap().as_f64().unwrap() <= 17.0);
    }

    #[test]
    fn speculation_counters_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.spec_drafted = 12;
        m.spec_accepted = 9;
        m.spec_accept_rate.push(0.75);
        m.spec_run_len.push(3.0);
        let s = m.snapshot();
        assert_eq!(s.get("spec_drafted_total").unwrap().as_u64(), Some(12));
        assert_eq!(s.get("spec_accepted_total").unwrap().as_u64(), Some(9));
        assert!(s.get("spec_accept_rate_mean").unwrap().as_f64().unwrap() > 0.7);
        assert_eq!(s.get("spec_run_len_max").unwrap().as_f64(), Some(3.0));
        // Pre-existing keys are still present under their old names.
        for key in ["gen_tokens", "decode_step_ms_p99", "decode_batch_size_max", "kv_peak_bytes"] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn sampled_speculation_keys_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.spec_resampled = 4;
        m.spec_accept_rate_greedy.push(1.0);
        m.spec_accept_rate_sampled.push(0.5);
        let s = m.snapshot();
        assert_eq!(s.get("spec_resample_total").unwrap().as_u64(), Some(4));
        assert_eq!(s.get("spec_accept_rate_greedy_mean").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("spec_accept_rate_sampled_p50").unwrap().as_f64(), Some(0.5));
        // The pooled PR-4 speculation keys keep their old names and
        // meaning next to the new per-mode ones.
        for key in [
            "spec_drafted_total",
            "spec_accepted_total",
            "spec_accept_rate_mean",
            "spec_accept_rate_p50",
            "spec_accept_rate_p99",
            "spec_run_len_mean",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn robustness_keys_surface_without_touching_old_keys() {
        let mut m = Metrics::new();
        m.conn_errors = 2;
        m.rejected_overload = 7;
        m.deadline_expired = 3;
        m.worker_restarts = 1;
        m.queue_depth.push(4.0);
        m.queue_depth.push(6.0);
        let s = m.snapshot();
        assert_eq!(s.get("conn_errors").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("rejected_overload").unwrap().as_u64(), Some(7));
        assert_eq!(s.get("deadline_expired").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("worker_restarts").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("queue_depth_max").unwrap().as_f64(), Some(6.0));
        assert!(s.get("queue_depth_p50").unwrap().as_f64().unwrap() >= 4.0);
        assert!(s.get("queue_depth_p99").unwrap().as_f64().unwrap() >= 4.0);
        // Every pre-existing key family keeps its old name.
        for key in [
            "requests_cancelled",
            "spec_resample_total",
            "decode_step_ms_p50",
            "kv_peak_bytes",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn pool_fragment_is_spliced_flat() {
        let mut m = Metrics::new();
        m.kv_pool = Json::obj(vec![("kv_blocks_in_use", Json::num(5.0))]);
        let s = m.snapshot();
        assert_eq!(s.get("kv_blocks_in_use").unwrap().as_u64(), Some(5));
    }
}
