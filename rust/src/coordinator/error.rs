//! Structured serving errors — the typed failure vocabulary of the
//! protocol edge.
//!
//! Every way a request can fail *before producing a normal `Done`
//! terminal* is one of these variants, serialized on the wire as
//!
//! ```json
//! {"error":{"code":"overloaded","message":"…","retry_after_ms":120}}
//! ```
//!
//! (`retry_after_ms` appears only on [`ServeError::Overloaded`]).
//! Failures *during* generation keep the richer partial-result shape:
//! a deadline that expires mid-stream ends in `Done` with reason
//! `deadline_exceeded` carrying the partial text, not in this error
//! object. The full wire contract is `docs/PROTOCOL.md` § Errors; the
//! failure-domain map (which subsystem raises which code, and the test
//! enforcing it) is `docs/ARCHITECTURE.md` § "Failure domains &
//! recovery".

use crate::util::json::Json;

/// Typed terminal failure for a request (or a malformed protocol line).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue is at `--max-queue-depth`; retry after the hint.
    Overloaded { retry_after_ms: u64 },
    /// The request's deadline expired before any tokens were produced.
    /// (Mid-stream expiry surfaces as `Done{reason: DeadlineExceeded}`
    /// with partial text instead.)
    DeadlineExceeded,
    /// The client went away; nobody is listening for the result.
    Cancelled,
    /// The request line could not be understood (malformed JSON,
    /// unknown op, invalid field).
    BadRequest(String),
    /// The engine failed this request unrecoverably — e.g. the request
    /// was implicated in repeated engine panics across worker restarts.
    EngineFailure(String),
    /// The server is draining for shutdown and admits no new work.
    ShuttingDown,
}

impl ServeError {
    /// Stable wire code (the `error.code` field).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::Cancelled => "cancelled",
            ServeError::BadRequest(_) => "bad_request",
            ServeError::EngineFailure(_) => "engine_failure",
            ServeError::ShuttingDown => "shutting_down",
        }
    }

    /// Human-readable detail (the `error.message` field).
    pub fn message(&self) -> String {
        match self {
            ServeError::Overloaded { retry_after_ms } => {
                format!("admission queue full; retry after ~{retry_after_ms} ms")
            }
            ServeError::DeadlineExceeded => "deadline expired before completion".to_string(),
            ServeError::Cancelled => "request cancelled".to_string(),
            ServeError::BadRequest(m) => m.clone(),
            ServeError::EngineFailure(m) => format!("engine failure: {m}"),
            ServeError::ShuttingDown => "server is shutting down".to_string(),
        }
    }

    /// Backoff hint — `Some` only for [`ServeError::Overloaded`].
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            ServeError::Overloaded { retry_after_ms } => Some(*retry_after_ms),
            _ => None,
        }
    }

    /// The wire shape: `{"error":{"code","message"[,"retry_after_ms"]}}`.
    pub fn to_json(&self) -> Json {
        let mut inner = vec![
            ("code", Json::str(self.code())),
            ("message", Json::str(self.message())),
        ];
        if let Some(ms) = self.retry_after_ms() {
            inner.push(("retry_after_ms", Json::num(ms as f64)));
        }
        Json::obj(vec![("error", Json::obj(inner))])
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code(), self.message())
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_shape_has_code_and_message() {
        let j = ServeError::BadRequest("unknown op 'generat'".into()).to_json();
        let e = j.get("error").expect("error envelope");
        assert_eq!(e.get("code").unwrap().as_str(), Some("bad_request"));
        assert_eq!(e.get("message").unwrap().as_str(), Some("unknown op 'generat'"));
        assert!(e.get("retry_after_ms").is_none());
    }

    #[test]
    fn overloaded_carries_retry_hint() {
        let err = ServeError::Overloaded { retry_after_ms: 120 };
        let j = err.to_json();
        let e = j.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(e.get("retry_after_ms").unwrap().as_u64(), Some(120));
        assert_eq!(err.retry_after_ms(), Some(120));
    }

    #[test]
    fn codes_are_stable_and_distinct() {
        let all = [
            ServeError::Overloaded { retry_after_ms: 1 }.code(),
            ServeError::DeadlineExceeded.code(),
            ServeError::Cancelled.code(),
            ServeError::BadRequest(String::new()).code(),
            ServeError::EngineFailure(String::new()).code(),
            ServeError::ShuttingDown.code(),
        ];
        let set: std::collections::BTreeSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len());
        // Round-trippable through the wire shape and Display.
        let s = ServeError::ShuttingDown.to_string();
        assert!(s.starts_with("shutting_down: "));
    }
}
