//! Fixed-size token-block pool: the physical storage layer of the paged
//! KV cache.
//!
//! A *block* holds `block_tokens` consecutive positions of one sequence,
//! across **all** layers and both K/V planes, so that mapping a block
//! into a sequence's table shares the complete KV state of that token
//! span. Blocks are refcounted: the free list hands a block out at
//! refcount 1; prefix-cache entries and copy-on-write forks retain extra
//! references, and a block returns to the free list when the count hits
//! zero.
//!
//! Storage is either plain `f32` (bit-identical to the dense
//! [`crate::model::KvCache`], used for parity) or per-row Q8 — int8
//! payload plus one `f32` scale per stored vector, reusing the
//! `quant::act` machinery from the W3A8 activation path. Q8 cuts the
//! per-token footprint ~3.9x, which is the §7.3 argument: VRAM freed by
//! 3-bit weights (and here by 8-bit KV) converts into batch occupancy.

use crate::model::ModelConfig;
use crate::quant::act::quantize_block_q8;

/// Physical block handle (index into the pool's storage arrays).
pub type BlockId = u32;

/// K or V plane selector inside a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    K = 0,
    V = 1,
}

/// Storage precision for KV blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvQuant {
    /// Plain f32 rows — bit-identical to the dense cache.
    F32,
    /// Int8 rows with one f32 scale per stored vector (amax/127, the
    /// same `quantize_block_q8` used by the W3A8 activation path).
    Q8,
}

impl KvQuant {
    pub fn parse(s: &str) -> Option<KvQuant> {
        match s {
            "f32" => Some(KvQuant::F32),
            "q8" => Some(KvQuant::Q8),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KvQuant::F32 => "f32",
            KvQuant::Q8 => "q8",
        }
    }
}

/// Refcounted pool of fixed-size KV blocks with free-list allocation.
///
/// Capacity is derived from a byte budget; backing storage grows lazily
/// one block at a time up to that cap, so tiny test budgets and the
/// 256 MiB serving default both work without up-front allocation.
pub struct BlockPool {
    n_layers: usize,
    dim: usize,
    block_tokens: usize,
    quant: KvQuant,
    cap_blocks: usize,
    refcounts: Vec<u32>,
    free: Vec<BlockId>,
    in_use: usize,
    data_f32: Vec<f32>,
    data_i8: Vec<i8>,
    scales: Vec<f32>,
    /// Copy-on-write forks performed (served via `fork_into`).
    pub cow_forks: u64,
}

impl BlockPool {
    pub fn new(
        cfg: &ModelConfig,
        block_tokens: usize,
        quant: KvQuant,
        budget_bytes: usize,
    ) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        let mut pool = BlockPool {
            n_layers: cfg.n_layers,
            dim: cfg.dim,
            block_tokens,
            quant,
            cap_blocks: 0,
            refcounts: Vec::new(),
            free: Vec::new(),
            in_use: 0,
            data_f32: Vec::new(),
            data_i8: Vec::new(),
            scales: Vec::new(),
            cow_forks: 0,
        };
        pool.cap_blocks = (budget_bytes / pool.block_bytes()).max(1);
        pool
    }

    /// Rows (stored vectors) per block: both planes, all layers, all
    /// token slots.
    fn rows_per_block(&self) -> usize {
        2 * self.n_layers * self.block_tokens
    }

    /// Bytes of physical storage per block in the configured precision.
    pub fn block_bytes(&self) -> usize {
        let rows = self.rows_per_block();
        match self.quant {
            KvQuant::F32 => rows * self.dim * 4,
            KvQuant::Q8 => rows * self.dim + rows * 4,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn quant(&self) -> KvQuant {
        self.quant
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn capacity_blocks(&self) -> usize {
        self.cap_blocks
    }

    pub fn in_use_blocks(&self) -> usize {
        self.in_use
    }

    /// Blocks that `try_alloc` could hand out right now.
    pub fn available_blocks(&self) -> usize {
        self.free.len() + (self.cap_blocks - self.refcounts.len())
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcounts[b as usize]
    }

    /// Flat row index of (`block`, `plane`, `layer`, `slot`).
    #[inline]
    fn row_index(&self, b: BlockId, plane: Plane, layer: usize, slot: usize) -> usize {
        debug_assert!(layer < self.n_layers && slot < self.block_tokens);
        ((b as usize * 2 + plane as usize) * self.n_layers + layer) * self.block_tokens + slot
    }

    /// Allocate one block at refcount 1, or `None` when the pool is dry.
    pub fn try_alloc(&mut self) -> Option<BlockId> {
        // Chaos site: injected exhaustion. Allocation is lazy (the
        // scheduler only checks availability up front), so this
        // surfaces on the write path as the coordinator's "scheduler
        // must ensure_append first" panic — i.e. it exercises the
        // worker-restart recovery, which the chaos suite verifies ends
        // in typed terminals and a leak-free pool.
        if crate::util::failpoint::should_fail("kvpaged.alloc") {
            return None;
        }
        let b = if let Some(b) = self.free.pop() {
            b
        } else if self.refcounts.len() < self.cap_blocks {
            let b = self.refcounts.len() as BlockId;
            self.refcounts.push(0);
            let rows = self.rows_per_block();
            match self.quant {
                KvQuant::F32 => self.data_f32.resize(self.refcounts.len() * rows * self.dim, 0.0),
                KvQuant::Q8 => {
                    self.data_i8.resize(self.refcounts.len() * rows * self.dim, 0);
                    self.scales.resize(self.refcounts.len() * rows, 0.0);
                }
            }
            b
        } else {
            return None;
        };
        debug_assert_eq!(self.refcounts[b as usize], 0);
        self.refcounts[b as usize] = 1;
        self.in_use += 1;
        Some(b)
    }

    /// Add a reference (prefix-cache entry, forked table, shared map).
    pub fn retain(&mut self, b: BlockId) {
        debug_assert!(self.refcounts[b as usize] > 0, "retain of a free block");
        self.refcounts[b as usize] += 1;
    }

    /// Drop a reference; the block returns to the free list at zero.
    pub fn release(&mut self, b: BlockId) {
        let rc = &mut self.refcounts[b as usize];
        debug_assert!(*rc > 0, "release of a free block");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
            self.in_use -= 1;
        }
    }

    /// Copy-on-write fork: allocate a private copy of `src`'s payload
    /// (all planes/layers/slots — correct regardless of fill level) and
    /// drop one reference on `src`. `None` when the pool is dry.
    pub fn fork_into(&mut self, src: BlockId) -> Option<BlockId> {
        let dst = self.try_alloc()?;
        let rows = self.rows_per_block();
        match self.quant {
            KvQuant::F32 => {
                let n = rows * self.dim;
                let (s, d) = (src as usize * n, dst as usize * n);
                self.data_f32.copy_within(s..s + n, d);
            }
            KvQuant::Q8 => {
                let n = rows * self.dim;
                let (s, d) = (src as usize * n, dst as usize * n);
                self.data_i8.copy_within(s..s + n, d);
                let (s, d) = (src as usize * rows, dst as usize * rows);
                self.scales.copy_within(s..s + rows, d);
            }
        }
        self.release(src);
        self.cow_forks += 1;
        Some(dst)
    }

    /// Store one `dim`-length vector at (`b`, `plane`, `layer`, `slot`).
    /// The caller must hold the only reference (COW is the table's job).
    pub fn write_row(&mut self, b: BlockId, plane: Plane, layer: usize, slot: usize, x: &[f32]) {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(self.refcounts[b as usize], 1, "write into a shared block");
        let r = self.row_index(b, plane, layer, slot);
        match self.quant {
            KvQuant::F32 => {
                self.data_f32[r * self.dim..(r + 1) * self.dim].copy_from_slice(x);
            }
            KvQuant::Q8 => {
                let codes = &mut self.data_i8[r * self.dim..(r + 1) * self.dim];
                let (scale, _) = quantize_block_q8(x, codes);
                self.scales[r] = scale;
            }
        }
    }

    /// Borrow a stored f32 row directly (F32 pools only).
    pub fn row_f32(&self, b: BlockId, plane: Plane, layer: usize, slot: usize) -> &[f32] {
        assert_eq!(self.quant, KvQuant::F32, "row_f32 on a Q8 pool");
        let r = self.row_index(b, plane, layer, slot);
        &self.data_f32[r * self.dim..(r + 1) * self.dim]
    }

    /// Dequantize all `block_tokens` slots of (`b`, `plane`, `layer`)
    /// into `out` (`block_tokens * dim` floats). F32 pools copy.
    pub fn read_rows_into(&self, b: BlockId, plane: Plane, layer: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.block_tokens * self.dim);
        let r0 = self.row_index(b, plane, layer, 0);
        match self.quant {
            KvQuant::F32 => {
                out.copy_from_slice(&self.data_f32[r0 * self.dim..(r0 + self.block_tokens) * self.dim]);
            }
            KvQuant::Q8 => {
                for slot in 0..self.block_tokens {
                    let r = r0 + slot;
                    let scale = self.scales[r];
                    let codes = &self.data_i8[r * self.dim..(r + 1) * self.dim];
                    for (o, &c) in out[slot * self.dim..(slot + 1) * self.dim]
                        .iter_mut()
                        .zip(codes)
                    {
                        *o = c as f32 * scale;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift};

    fn pool(bt: usize, quant: KvQuant, blocks: usize) -> BlockPool {
        let cfg = ModelConfig::test();
        let mut p = BlockPool::new(&cfg, bt, quant, 1);
        // Size the budget in whole blocks for test readability.
        p = BlockPool::new(&cfg, bt, quant, blocks * p.block_bytes());
        p
    }

    #[test]
    fn alloc_release_cycles_through_free_list() {
        let mut p = pool(16, KvQuant::F32, 2);
        assert_eq!(p.capacity_blocks(), 2);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert!(p.try_alloc().is_none(), "pool must be dry");
        assert_eq!(p.in_use_blocks(), 2);
        p.release(a);
        assert_eq!(p.available_blocks(), 1);
        let c = p.try_alloc().unwrap();
        assert_eq!(c, a, "free list must recycle");
        p.release(b);
        p.release(c);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn refcounts_gate_the_free_list() {
        let mut p = pool(8, KvQuant::F32, 1);
        let a = p.try_alloc().unwrap();
        p.retain(a);
        p.release(a);
        assert_eq!(p.available_blocks(), 0, "still referenced");
        p.release(a);
        assert_eq!(p.available_blocks(), 1);
    }

    #[test]
    fn f32_rows_roundtrip_exactly() {
        let cfg = ModelConfig::test();
        let mut p = pool(4, KvQuant::F32, 2);
        let b = p.try_alloc().unwrap();
        let x: Vec<f32> = (0..cfg.dim).map(|i| (i as f32).sin()).collect();
        p.write_row(b, Plane::K, 1, 3, &x);
        assert_eq!(p.row_f32(b, Plane::K, 1, 3), &x[..]);
        // Other plane/slot untouched (zero-initialized storage).
        assert!(p.row_f32(b, Plane::V, 1, 3).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn q8_rows_roundtrip_within_bound() {
        let cfg = ModelConfig::test();
        let mut p = pool(4, KvQuant::Q8, 2);
        let b = p.try_alloc().unwrap();
        let mut rng = XorShift::new(9);
        let x: Vec<f32> = (0..cfg.dim).map(|_| rng.next_gaussian() as f32).collect();
        p.write_row(b, Plane::V, 0, 2, &x);
        let mut out = vec![0.0f32; p.block_tokens() * cfg.dim];
        p.read_rows_into(b, Plane::V, 0, &mut out);
        let rel = stats::rel_l2_err(&x, &out[2 * cfg.dim..3 * cfg.dim]);
        assert!(rel < 0.02, "q8 KV row rel err {rel}");
    }

    #[test]
    fn q8_block_bytes_are_about_4x_smaller() {
        let cfg = ModelConfig::test();
        let f = BlockPool::new(&cfg, 16, KvQuant::F32, 1 << 20).block_bytes();
        let q = BlockPool::new(&cfg, 16, KvQuant::Q8, 1 << 20).block_bytes();
        let ratio = f as f64 / q as f64;
        assert!(ratio > 3.5 && ratio <= 4.0, "ratio={ratio}");
    }

    #[test]
    fn cow_fork_copies_payload_and_moves_ref() {
        let cfg = ModelConfig::test();
        let mut p = pool(4, KvQuant::F32, 3);
        let a = p.try_alloc().unwrap();
        let x = vec![1.5f32; cfg.dim];
        p.write_row(a, Plane::K, 0, 0, &x);
        p.retain(a); // shared (e.g. two tables map it)
        let b = p.fork_into(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.refcount(a), 1);
        assert_eq!(p.refcount(b), 1);
        assert_eq!(p.cow_forks, 1);
        assert_eq!(p.row_f32(b, Plane::K, 0, 0), &x[..]);
        // Writing the fork must not touch the original.
        let y = vec![-2.0f32; cfg.dim];
        p.write_row(b, Plane::K, 0, 0, &y);
        assert_eq!(p.row_f32(a, Plane::K, 0, 0), &x[..]);
    }
}
