//! Per-sequence block tables: the logical→physical mapping with
//! copy-on-write semantics.
//!
//! A table is a vector of physical [`BlockId`]s; logical block `i` holds
//! token positions `i*block_tokens .. (i+1)*block_tokens`. Tables from
//! different sequences may map the same physical blocks (prefix-cache
//! hits, forks); a write into a block whose refcount exceeds one first
//! forks it via [`BlockPool::fork_into`], so divergence after a shared
//! prefix never corrupts a sibling.

use super::block::{BlockId, BlockPool};

/// Logical→physical block mapping for one sequence.
#[derive(Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
}

impl BlockTable {
    pub fn new() -> Self {
        BlockTable { blocks: Vec::new() }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn physical(&self, logical: usize) -> BlockId {
        self.blocks[logical]
    }

    /// Map an already-referenced physical block as the next logical
    /// block (prefix-cache hit path; the caller has done the `retain`).
    pub fn push_mapped(&mut self, b: BlockId) {
        self.blocks.push(b);
    }

    /// Clone this table for a forked sequence: every mapped block gains
    /// a reference; later writes on either side trigger COW.
    pub fn fork(&self, pool: &mut BlockPool) -> BlockTable {
        for &b in &self.blocks {
            pool.retain(b);
        }
        BlockTable { blocks: self.blocks.clone() }
    }

    /// Release every mapped block and clear the table.
    pub fn release_all(&mut self, pool: &mut BlockPool) {
        for &b in &self.blocks {
            pool.release(b);
        }
        self.blocks.clear();
    }

    /// Release the mapped blocks beyond the first `keep`, shrinking the
    /// table (speculative rollback). Refcounts make this COW-correct: a
    /// tail block shared with the prefix cache or a forked sequence
    /// merely loses this table's reference and survives for its other
    /// holders; a private one returns to the free list.
    pub fn truncate(&mut self, pool: &mut BlockPool, keep: usize) {
        while self.blocks.len() > keep {
            let b = self.blocks.pop().expect("len checked");
            pool.release(b);
        }
    }

    /// Physical block for writing position `pos`, allocating the next
    /// logical block or COW-forking a shared one as needed. `None` when
    /// the pool is dry — callers prevent this by checking
    /// [`BlockTable::blocks_needed_for_append`] first.
    pub fn block_for_write(&mut self, pool: &mut BlockPool, pos: usize) -> Option<BlockId> {
        let lb = pos / pool.block_tokens();
        if lb == self.blocks.len() {
            let b = pool.try_alloc()?;
            self.blocks.push(b);
            return Some(b);
        }
        assert!(lb < self.blocks.len(), "non-append write at block {lb}");
        let b = self.blocks[lb];
        if pool.refcount(b) > 1 {
            let forked = pool.fork_into(b)?;
            self.blocks[lb] = forked;
            return Some(forked);
        }
        Some(b)
    }

    /// Fresh physical blocks required to write positions
    /// `len .. len + n`: new logical blocks, plus one COW fork if the
    /// tail block is shared and will be written into.
    pub fn blocks_needed_for_append(&self, pool: &BlockPool, len: usize, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let bt = pool.block_tokens();
        let target_blocks = (len + n + bt - 1) / bt;
        let mut need = target_blocks.saturating_sub(self.blocks.len());
        if len % bt != 0 {
            let tail = len / bt;
            if tail < self.blocks.len() && pool.refcount(self.blocks[tail]) > 1 {
                need += 1;
            }
        }
        need
    }
}

#[cfg(test)]
mod tests {
    use super::super::block::{KvQuant, Plane};
    use super::*;
    use crate::model::ModelConfig;

    fn pool(bt: usize, blocks: usize) -> BlockPool {
        let cfg = ModelConfig::test();
        let unit = BlockPool::new(&cfg, bt, KvQuant::F32, 1).block_bytes();
        BlockPool::new(&cfg, bt, KvQuant::F32, blocks * unit)
    }

    #[test]
    fn append_allocates_one_block_per_span() {
        let mut p = pool(4, 8);
        let mut t = BlockTable::new();
        let x = vec![0.0f32; p.dim()];
        for pos in 0..10 {
            let b = t.block_for_write(&mut p, pos).unwrap();
            p.write_row(b, Plane::K, 0, pos % 4, &x);
        }
        assert_eq!(t.n_blocks(), 3); // ceil(10/4)
        assert_eq!(p.in_use_blocks(), 3);
        t.release_all(&mut p);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn shared_tail_write_forks() {
        let mut p = pool(4, 8);
        let mut a = BlockTable::new();
        let x = vec![1.0f32; p.dim()];
        for pos in 0..6 {
            let b = a.block_for_write(&mut p, pos).unwrap();
            p.write_row(b, Plane::K, 0, pos % 4, &x);
        }
        let mut b = a.fork(&mut p);
        assert_eq!(p.refcount(a.physical(1)), 2);
        // b appends into the shared partial tail block -> COW.
        let y = vec![-1.0f32; p.dim()];
        let blk = b.block_for_write(&mut p, 6).unwrap();
        p.write_row(blk, Plane::K, 0, 2, &y);
        assert_eq!(p.cow_forks, 1);
        assert_ne!(a.physical(1), b.physical(1));
        // a's copy of position 5 is untouched.
        assert_eq!(p.row_f32(a.physical(1), Plane::K, 0, 1), &x[..]);
        assert_eq!(p.row_f32(b.physical(1), Plane::K, 0, 2), &y[..]);
        a.release_all(&mut p);
        b.release_all(&mut p);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn truncate_releases_private_tail_but_only_unrefs_shared() {
        let mut p = pool(4, 8);
        let mut a = BlockTable::new();
        let x = vec![2.0f32; p.dim()];
        for pos in 0..10 {
            let b = a.block_for_write(&mut p, pos).unwrap();
            p.write_row(b, Plane::K, 0, pos % 4, &x);
        }
        assert_eq!(a.n_blocks(), 3);
        let shared_tail = a.physical(2);
        p.retain(shared_tail); // e.g. a prefix-cache reference
        a.truncate(&mut p, 1);
        assert_eq!(a.n_blocks(), 1);
        // The shared block survives its other holder; the private one
        // (logical 1) went back to the free list.
        assert_eq!(p.refcount(shared_tail), 1);
        assert_eq!(p.in_use_blocks(), 2);
        p.release(shared_tail);
        a.release_all(&mut p);
        assert_eq!(p.in_use_blocks(), 0);
        // Truncate-to-current-size is a no-op.
        a.truncate(&mut p, 5);
        assert_eq!(a.n_blocks(), 0);
    }

    #[test]
    fn blocks_needed_accounts_for_cow() {
        let mut p = pool(4, 8);
        let mut a = BlockTable::new();
        let x = vec![0.5f32; p.dim()];
        for pos in 0..6 {
            let b = a.block_for_write(&mut p, pos).unwrap();
            p.write_row(b, Plane::K, 0, pos % 4, &x);
        }
        // Private tail: appending 1 token needs nothing new.
        assert_eq!(a.blocks_needed_for_append(&p, 6, 1), 0);
        // Crossing into a new logical block needs one.
        assert_eq!(a.blocks_needed_for_append(&p, 6, 3), 1);
        let b = a.fork(&mut p);
        // Shared tail: first append must also fork.
        assert_eq!(a.blocks_needed_for_append(&p, 6, 1), 1);
        assert_eq!(a.blocks_needed_for_append(&p, 6, 3), 2);
        drop(b);
    }
}
