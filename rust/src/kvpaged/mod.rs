//! Paged KV-cache subsystem: refcounted token-block storage,
//! copy-on-write prefix sharing, and optional Q8 block compression.
//!
//! The paper's §7.3 memory economics argue that 3-bit weights pay off at
//! serving scale only if the freed VRAM converts into concurrent
//! sequences. The dense [`crate::model::KvCache`] frustrates that: the
//! coordinator had to reserve each request's **worst-case** f32
//! footprint at admission, so a modest budget serialized long requests
//! even when their prompts overlapped. This module replaces that with
//! the vLLM-style design:
//!
//! - [`block::BlockPool`] — fixed-size token blocks (`block_tokens`
//!   positions x all layers x K/V), refcounted, free-list allocated,
//!   stored as f32 or per-row Q8 (int8 + scale, ~3.9x denser);
//! - [`table::BlockTable`] — per-sequence logical→physical maps with
//!   copy-on-write: writing a block whose refcount exceeds one forks it;
//! - [`prefix::PrefixCache`] — a radix tree over token-block hashes, so
//!   requests sharing a prompt prefix map the same physical blocks and
//!   skip re-prefill of the cached span;
//! - [`PagedKvPool`] — the facade the coordinator drives: sequence
//!   creation, cached-prefix mapping, capacity checks (with cache
//!   eviction under pressure), and per-sequence [`PagedSeq`] views that
//!   implement [`KvStore`] so the engines are oblivious to paging.
//!
//! Parity: with `KvQuant::F32`, greedy decode through a paged view is
//! **bit-identical** to the dense cache (`rust/tests/kv_paged.rs`); Q8
//! stays within a tested relative-error bound.

pub mod block;
pub mod prefix;
pub mod table;

pub use block::{BlockId, BlockPool, KvQuant, Plane};
pub use prefix::PrefixCache;
pub use table::BlockTable;

use crate::model::{KvStore, ModelConfig};
use crate::util::json::Json;

/// Handle to one sequence inside a [`PagedKvPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqId(usize);

struct Seq {
    tokens: Vec<u32>,
    table: BlockTable,
}

/// The paged KV pool: block storage + prefix cache + sequence registry.
pub struct PagedKvPool {
    pool: BlockPool,
    prefix: PrefixCache,
    seqs: Vec<Option<Seq>>,
    free_slots: Vec<usize>,
    max_seq: usize,
    /// Dequant scratch for Q8 reads: every resident K *and* V row of
    /// one (sequence, layer) — both planes, because the engine's
    /// heads-outer attention sweep alternates K and V reads per head —
    /// so each block dequantizes twice per layer per decode step
    /// instead of twice per head.
    dq_buf: Vec<f32>,
    dq_key: Option<(usize, usize)>,
    /// High-water mark of in-use blocks, in bytes (metrics).
    pub peak_bytes: usize,
}

impl PagedKvPool {
    pub fn new(cfg: &ModelConfig, block_tokens: usize, quant: KvQuant, budget_bytes: usize) -> Self {
        PagedKvPool {
            pool: BlockPool::new(cfg, block_tokens, quant, budget_bytes),
            prefix: PrefixCache::new(),
            seqs: Vec::new(),
            free_slots: Vec::new(),
            max_seq: cfg.max_seq,
            dq_buf: Vec::new(),
            dq_key: None,
            peak_bytes: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    pub fn quant(&self) -> KvQuant {
        self.pool.quant()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.pool.capacity_blocks()
    }

    pub fn in_use_blocks(&self) -> usize {
        self.pool.in_use_blocks()
    }

    /// Blocks obtainable right now without evicting the prefix cache.
    pub fn available_blocks(&self) -> usize {
        self.pool.available_blocks()
    }

    pub fn cow_forks(&self) -> u64 {
        self.pool.cow_forks
    }

    pub fn prefix_stats(&self) -> (u64, u64, u64) {
        (self.prefix.lookups, self.prefix.hit_tokens, self.prefix.evictions)
    }

    pub fn create_seq(&mut self) -> SeqId {
        let seq = Seq { tokens: Vec::new(), table: BlockTable::new() };
        match self.free_slots.pop() {
            Some(i) => {
                self.seqs[i] = Some(seq);
                SeqId(i)
            }
            None => {
                self.seqs.push(Some(seq));
                SeqId(self.seqs.len() - 1)
            }
        }
    }

    fn seq(&self, id: SeqId) -> &Seq {
        self.seqs[id.0].as_ref().expect("released SeqId")
    }

    fn seq_mut(&mut self, id: SeqId) -> &mut Seq {
        self.seqs[id.0].as_mut().expect("released SeqId")
    }

    pub fn seq_len(&self, id: SeqId) -> usize {
        self.seq(id).tokens.len()
    }

    /// Map the longest cached whole-block prefix of `prompt` into a
    /// fresh sequence, leaving at least the final prompt token to
    /// re-prefill (its logits are needed to sample). Returns the number
    /// of tokens now resident (a multiple of `block_tokens`).
    pub fn map_cached_prefix(&mut self, id: SeqId, prompt: &[u32]) -> usize {
        debug_assert_eq!(self.seq(id).tokens.len(), 0, "map into a fresh sequence");
        let bt = self.pool.block_tokens();
        let cap = prompt.len().saturating_sub(1);
        let hits = self.prefix.lookup(prompt, bt, cap);
        let seq = self.seqs[id.0].as_mut().expect("released SeqId");
        for &b in &hits {
            self.pool.retain(b);
            seq.table.push_mapped(b);
        }
        let mapped = hits.len() * bt;
        seq.tokens.extend_from_slice(&prompt[..mapped]);
        mapped
    }

    /// Read-only placement probe: how many of `prompt`'s tokens this
    /// pool's prefix cache could serve at admission (same whole-block
    /// walk and last-token cap as [`Self::map_cached_prefix`], but no
    /// LRU bump and no stats). The replica scheduler probes every
    /// candidate pool and admits where the hit is largest.
    pub fn cached_prefix_tokens(&self, prompt: &[u32]) -> usize {
        let bt = self.pool.block_tokens();
        let cap = prompt.len().saturating_sub(1);
        self.prefix.probe_tokens(prompt, bt, cap)
    }

    /// Fresh blocks required to append `n` tokens to `id` (new logical
    /// blocks plus a COW fork of a shared tail).
    pub fn blocks_needed(&self, id: SeqId, n: usize) -> usize {
        let seq = self.seqs[id.0].as_ref().expect("released SeqId");
        seq.table.blocks_needed_for_append(&self.pool, seq.tokens.len(), n)
    }

    /// Make at least `total` blocks available, evicting prefix-cache
    /// entries (LRU) as needed. Returns whether the target was met. No
    /// reservation is taken: the scheduler sums its demands into one
    /// `reclaim` target per round, then writes within the same round.
    pub fn reclaim(&mut self, total: usize) -> bool {
        let avail = self.pool.available_blocks();
        if avail < total {
            self.prefix.evict_for(&mut self.pool, total - avail);
        }
        self.pool.available_blocks() >= total
    }

    /// Can `n` more tokens be appended to `id` right now (evicting
    /// cached prefixes if needed)?
    pub fn ensure_append(&mut self, id: SeqId, n: usize) -> bool {
        let need = self.blocks_needed(id, n);
        self.reclaim(need)
    }

    /// Drop every prefix-cache entry, releasing the cache's block
    /// references (admin/testing hook; live sequences are unaffected).
    pub fn clear_prefix_cache(&mut self) {
        self.prefix.clear(&mut self.pool);
    }

    /// Register `id`'s current whole-block token prefix in the prefix
    /// cache (call once its KV state is final, i.e. after prefill).
    pub fn cache_prefix(&mut self, id: SeqId) {
        let seq = self.seqs[id.0].as_ref().expect("released SeqId");
        let bt = self.pool.block_tokens();
        // Cap at the blocks actually written: a recompute engine (PJRT)
        // grows the token history without ever writing KV, leaving the
        // table shorter than the token count — nothing to cache then.
        let full = (seq.tokens.len() / bt).min(seq.table.n_blocks()) * bt;
        if full == 0 {
            return;
        }
        let blocks: Vec<BlockId> = (0..full / bt).map(|i| seq.table.physical(i)).collect();
        let tokens = seq.tokens[..full].to_vec();
        self.prefix.insert(&mut self.pool, &tokens, bt, &blocks);
    }

    /// Roll sequence `id` back to its first `len` tokens (speculative
    /// rollback): the token history is truncated, tail blocks past the
    /// last kept position are released — refcounted, so a block shared
    /// with the prefix cache or a forked sequence merely loses this
    /// sequence's reference and stays valid for its other holders
    /// (their content was COW-protected from the rolled-back writes) —
    /// and any prefix-cache chain entry registered over the dropped
    /// span is invalidated, so the cache can never serve a rolled-back
    /// span.
    pub fn truncate_seq(&mut self, id: SeqId, len: usize) {
        let bt = self.pool.block_tokens();
        let old = self.seqs[id.0].as_ref().expect("released SeqId").tokens.len();
        assert!(len <= old, "truncate({len}) beyond length {old}");
        if len == old {
            return;
        }
        // Invalidate cached entries over the dropped span first — this
        // needs the pre-truncation token history to walk the chain.
        self.prefix.forget_from(
            &mut self.pool,
            &self.seqs[id.0].as_ref().expect("released SeqId").tokens,
            bt,
            len,
        );
        let seq = self.seqs[id.0].as_mut().expect("released SeqId");
        seq.tokens.truncate(len);
        // Keep exactly the blocks that still hold a kept position. (A
        // recompute engine's table can be shorter than the token count;
        // truncate is then a no-op on blocks.)
        let keep = len.div_ceil(bt);
        seq.table.truncate(&mut self.pool, keep);
        // The dequant memo may span released (and soon recycled) blocks.
        self.dq_key = None;
    }

    /// Fork a sequence: shared block table (refcounted), copied token
    /// history. Continuations diverge via copy-on-write.
    pub fn fork_seq(&mut self, id: SeqId) -> SeqId {
        let new = self.create_seq();
        let src = self.seqs[id.0].as_ref().expect("released SeqId");
        let tokens = src.tokens.clone();
        let table = src.table.fork(&mut self.pool);
        let dst = self.seqs[new.0].as_mut().expect("fresh SeqId");
        dst.tokens = tokens;
        dst.table = table;
        new
    }

    /// Release a sequence's blocks and retire its id.
    pub fn release_seq(&mut self, id: SeqId) {
        let mut seq = self.seqs[id.0].take().expect("double release");
        seq.table.release_all(&mut self.pool);
        self.free_slots.push(id.0);
        // The slot (and so the memo key) can be reused by a new sequence.
        self.dq_key = None;
    }

    /// Borrow a [`KvStore`] view of one sequence for an engine call.
    pub fn seq_view(&mut self, id: SeqId) -> PagedSeq<'_> {
        PagedSeq { pool: self, id }
    }

    /// Borrow a [`KvBatchStore`] view of several sequences for one fused
    /// decode round. All sequences live behind this pool's single
    /// `&mut`, so concurrent [`PagedSeq`] views are impossible; the
    /// batch adapter instead routes every per-index call back through
    /// the pool (the engine touches one sequence's KV at a time anyway).
    pub fn batch_view<'a>(&'a mut self, ids: &'a [SeqId]) -> PagedBatch<'a> {
        PagedBatch { pool: self, ids }
    }

    fn kv_at(&mut self, id: SeqId, plane: Plane, layer: usize, pos: usize) -> &[f32] {
        let bt = self.pool.block_tokens();
        let dim = self.pool.dim();
        let seq = self.seqs[id.0].as_ref().expect("released SeqId");
        debug_assert!(pos / bt < seq.table.n_blocks());
        match self.pool.quant() {
            KvQuant::F32 => {
                let b = seq.table.physical(pos / bt);
                self.pool.row_f32(b, plane, layer, pos % bt)
            }
            KvQuant::Q8 => {
                let nb = seq.table.n_blocks();
                let plane_span = nb * bt * dim;
                let key = (id.0, layer);
                if self.dq_key != Some(key) || self.dq_buf.len() != 2 * plane_span {
                    self.dq_buf.resize(2 * plane_span, 0.0);
                    for (p, pl) in [Plane::K, Plane::V].into_iter().enumerate() {
                        for lb in 0..nb {
                            let o = p * plane_span + lb * bt * dim;
                            self.pool.read_rows_into(
                                seq.table.physical(lb),
                                pl,
                                layer,
                                &mut self.dq_buf[o..o + bt * dim],
                            );
                        }
                    }
                    self.dq_key = Some(key);
                }
                let o = plane as usize * plane_span + pos * dim;
                &self.dq_buf[o..o + dim]
            }
        }
    }

    fn write_kv(&mut self, id: SeqId, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "paged kv overflow at pos {pos}");
        let seq = self.seqs[id.0].as_mut().expect("released SeqId");
        let b = seq
            .table
            .block_for_write(&mut self.pool, pos)
            .expect("block pool exhausted — scheduler must ensure_append first");
        // Any write invalidates the dequant memo conservatively: the
        // memoized physical block may have been COW-swapped or recycled.
        self.dq_key = None;
        let slot = pos % self.pool.block_tokens();
        self.pool.write_row(b, Plane::K, layer, slot, k);
        self.pool.write_row(b, Plane::V, layer, slot, v);
        let bytes = self.pool.in_use_blocks() * self.pool.block_bytes();
        self.peak_bytes = self.peak_bytes.max(bytes);
    }

    /// Metrics snapshot fragment for the serving `stats` endpoint.
    pub fn stats_json(&self) -> Json {
        let (lookups, hit_tokens, evictions) = self.prefix_stats();
        let lookup_tokens = self.prefix.lookup_tokens.max(1);
        Json::obj(vec![
            ("kv_block_tokens", Json::num(self.pool.block_tokens() as f64)),
            ("kv_quant", Json::str(self.pool.quant().as_str())),
            ("kv_blocks_capacity", Json::num(self.pool.capacity_blocks() as f64)),
            ("kv_blocks_in_use", Json::num(self.pool.in_use_blocks() as f64)),
            ("kv_block_bytes", Json::num(self.pool.block_bytes() as f64)),
            ("kv_cow_forks", Json::num(self.pool.cow_forks as f64)),
            ("prefix_lookups", Json::num(lookups as f64)),
            ("prefix_hit_tokens", Json::num(hit_tokens as f64)),
            ("prefix_hit_ratio", Json::num(hit_tokens as f64 / lookup_tokens as f64)),
            ("prefix_evictions", Json::num(evictions as f64)),
            ("prefix_invalidations", Json::num(self.prefix.invalidations as f64)),
            // Appended (PR 8): the raw denominator of the hit ratio, so
            // merged multi-replica fragments can recompute the ratio
            // exactly instead of averaging per-replica ratios.
            ("prefix_lookup_tokens", Json::num(self.prefix.lookup_tokens as f64)),
        ])
    }
}

/// Borrowed [`KvStore`] view of one sequence in a [`PagedKvPool`].
pub struct PagedSeq<'a> {
    pool: &'a mut PagedKvPool,
    id: SeqId,
}

impl KvStore for PagedSeq<'_> {
    fn len(&self) -> usize {
        self.pool.seq_len(self.id)
    }

    fn capacity(&self) -> usize {
        self.pool.max_seq
    }

    fn tokens(&self) -> &[u32] {
        &self.pool.seq(self.id).tokens
    }

    fn push_token(&mut self, t: u32) {
        self.pool.seq_mut(self.id).tokens.push(t);
    }

    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.pool.kv_at(self.id, Plane::K, layer, pos)
    }

    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.pool.kv_at(self.id, Plane::V, layer, pos)
    }

    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.write_kv(self.id, layer, pos, k, v)
    }

    fn truncate(&mut self, len: usize) {
        self.pool.truncate_seq(self.id, len)
    }
}

/// Borrowed [`KvBatchStore`] view of several sequences of one
/// [`PagedKvPool`] — the coordinator hands this to
/// [`crate::model::native::Engine::decode_batch`] each decode round.
pub struct PagedBatch<'a> {
    pool: &'a mut PagedKvPool,
    ids: &'a [SeqId],
}

impl crate::model::KvBatchStore for PagedBatch<'_> {
    fn n_seqs(&self) -> usize {
        self.ids.len()
    }

    fn seq_len(&self, i: usize) -> usize {
        self.pool.seq_len(self.ids[i])
    }

    fn capacity(&self, _i: usize) -> usize {
        self.pool.max_seq
    }

    fn tokens(&self, i: usize) -> &[u32] {
        &self.pool.seq(self.ids[i]).tokens
    }

    fn push_token(&mut self, i: usize, t: u32) {
        self.pool.seq_mut(self.ids[i]).tokens.push(t);
    }

    fn k_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.pool.kv_at(self.ids[i], Plane::K, layer, pos)
    }

    fn v_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.pool.kv_at(self.ids[i], Plane::V, layer, pos)
    }

    fn write_kv(&mut self, i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.write_kv(self.ids[i], layer, pos, k, v)
    }

    fn truncate(&mut self, i: usize, len: usize) {
        self.pool.truncate_seq(self.ids[i], len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(bt: usize, blocks: usize, quant: KvQuant) -> PagedKvPool {
        let cfg = ModelConfig::test();
        let unit = BlockPool::new(&cfg, bt, quant, 1).block_bytes();
        PagedKvPool::new(&cfg, bt, quant, blocks * unit)
    }

    #[test]
    fn store_roundtrip_through_view() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::F32);
        let id = p.create_seq();
        let k: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..cfg.dim).map(|i| -(i as f32)).collect();
        {
            let mut view = p.seq_view(id);
            // Writes are append-only by position (the engine invariant).
            for pos in 0..6 {
                view.write_kv(1, pos, &k, &v);
                view.push_token(pos as u32);
            }
            assert_eq!(view.k_at(1, 5), &k[..]);
            assert_eq!(view.v_at(1, 5), &v[..]);
            assert_eq!(view.len(), 6);
        }
        // Position 5 lives in logical block 1; both blocks allocated.
        assert_eq!(p.in_use_blocks(), 2);
        p.release_seq(id);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn map_cached_prefix_skips_resident_tokens() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::F32);
        let prompt: Vec<u32> = (0..10).collect();
        let a = p.create_seq();
        assert_eq!(p.map_cached_prefix(a, &prompt), 0, "cold cache");
        let row = vec![0.25f32; cfg.dim];
        for pos in 0..prompt.len() {
            for l in 0..cfg.n_layers {
                p.write_kv(a, l, pos, &row, &row);
            }
            p.seq_mut(a).tokens.push(prompt[pos]);
        }
        p.cache_prefix(a);
        let b = p.create_seq();
        // 10 tokens -> 2 full blocks (8 tokens) cached and shareable.
        assert_eq!(p.map_cached_prefix(b, &prompt), 8);
        assert_eq!(p.seq_len(b), 8);
        // Shared blocks, not copies: only a's 3 blocks exist.
        assert_eq!(p.in_use_blocks(), 3);
        // The last-token cap: a fully cached prompt still re-prefills >= 1.
        let c = p.create_seq();
        let exact: Vec<u32> = (0..8).collect();
        assert_eq!(p.map_cached_prefix(c, &exact), 4);
        p.release_seq(a);
        p.release_seq(b);
        p.release_seq(c);
    }

    #[test]
    fn ensure_append_evicts_cache_under_pressure() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 2, KvQuant::F32);
        let a = p.create_seq();
        let row = vec![1.0f32; cfg.dim];
        for pos in 0..8 {
            for l in 0..cfg.n_layers {
                p.write_kv(a, l, pos, &row, &row);
            }
            p.seq_mut(a).tokens.push(pos as u32);
        }
        p.cache_prefix(a);
        p.release_seq(a); // cache now sole owner of both blocks
        assert_eq!(p.available_blocks(), 0);
        let b = p.create_seq();
        assert!(p.ensure_append(b, 4), "eviction must reclaim a block");
        assert!(p.available_blocks() >= 1);
        p.release_seq(b);
    }

    #[test]
    fn batch_view_routes_per_index_to_the_right_sequence() {
        use crate::model::KvBatchStore;
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::F32);
        let a = p.create_seq();
        let b = p.create_seq();
        let ka: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        let kb: Vec<f32> = (0..cfg.dim).map(|i| -(i as f32)).collect();
        let ids = [a, b];
        {
            let mut batch = p.batch_view(&ids);
            assert_eq!(batch.n_seqs(), 2);
            batch.write_kv(0, 0, 0, &ka, &ka);
            batch.write_kv(1, 0, 0, &kb, &kb);
            batch.push_token(0, 3);
            batch.push_token(1, 5);
            assert_eq!(batch.k_at(0, 0, 0), &ka[..]);
            assert_eq!(batch.v_at(1, 0, 0), &kb[..]);
            assert_eq!(batch.seq_len(0), 1);
            assert_eq!(batch.tokens(1), &[5]);
        }
        // The same state is visible through the single-sequence views.
        assert_eq!(p.seq_view(a).k_at(0, 0), &ka[..]);
        assert_eq!(p.seq_view(b).k_at(0, 0), &kb[..]);
        p.release_seq(a);
        p.release_seq(b);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn truncate_seq_releases_tail_blocks_and_keeps_content() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::F32);
        let id = p.create_seq();
        let rows: Vec<Vec<f32>> =
            (0..10).map(|i| vec![i as f32; cfg.dim]).collect();
        {
            let mut view = p.seq_view(id);
            for (pos, r) in rows.iter().enumerate() {
                for l in 0..cfg.n_layers {
                    view.write_kv(l, pos, r, r);
                }
                view.push_token(pos as u32);
            }
        }
        assert_eq!(p.in_use_blocks(), 3); // ceil(10/4)
        p.truncate_seq(id, 5);
        assert_eq!(p.seq_len(id), 5);
        assert_eq!(p.in_use_blocks(), 2); // ceil(5/4): block 2 freed
        // Kept positions are untouched, and the freed span can be
        // rewritten through the normal append path.
        {
            let mut view = p.seq_view(id);
            assert_eq!(view.k_at(1, 4), &rows[4][..]);
            assert_eq!(view.v_at(0, 0), &rows[0][..]);
            for l in 0..cfg.n_layers {
                view.write_kv(l, 5, &rows[9], &rows[9]);
            }
            view.push_token(99);
            assert_eq!(view.k_at(0, 5), &rows[9][..]);
        }
        // Truncate to a block boundary and to zero.
        p.truncate_seq(id, 4);
        assert_eq!(p.in_use_blocks(), 1);
        p.truncate_seq(id, 0);
        assert_eq!(p.in_use_blocks(), 0);
        p.release_seq(id);
    }

    #[test]
    fn truncate_seq_invalidates_cached_entries_over_the_span() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::F32);
        let id = p.create_seq();
        let row = vec![0.5f32; cfg.dim];
        for pos in 0..8 {
            for l in 0..cfg.n_layers {
                p.write_kv(id, l, pos, &row, &row);
            }
            p.seq_mut(id).tokens.push(pos as u32);
        }
        p.cache_prefix(id); // blocks 0 and 1 registered
        let prompt: Vec<u32> = (0..8).collect();
        // Roll back into block 1: its cache entry must be dropped, the
        // block-0 entry kept.
        p.truncate_seq(id, 5);
        let probe = p.create_seq();
        assert_eq!(p.map_cached_prefix(probe, &prompt), 4, "only block 0 may serve");
        p.release_seq(probe);
        p.release_seq(id);
        p.clear_prefix_cache();
        assert_eq!(p.in_use_blocks(), 0, "no reference leaked by invalidation");
    }

    #[test]
    fn release_returns_all_blocks() {
        let cfg = ModelConfig::test();
        let mut p = tiny_pool(4, 8, KvQuant::Q8);
        let a = p.create_seq();
        let row = vec![0.5f32; cfg.dim];
        for pos in 0..6 {
            for l in 0..cfg.n_layers {
                p.write_kv(a, l, pos, &row, &row);
            }
            p.seq_mut(a).tokens.push(1);
        }
        let b = p.fork_seq(a);
        assert_eq!(p.seq_len(b), 6);
        p.release_seq(a);
        assert!(p.in_use_blocks() > 0, "fork keeps blocks alive");
        p.release_seq(b);
        assert_eq!(p.in_use_blocks(), 0);
    }
}
