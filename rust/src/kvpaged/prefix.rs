//! Prompt-prefix cache: a radix tree over token blocks, flattened into a
//! hash map keyed on *chained* block hashes (the vLLM trick — a node's
//! key hashes its own tokens together with its parent's key, so one map
//! lookup per block walks the trie).
//!
//! Entries hold one pool reference on their physical block, so cached
//! blocks survive the sequence that produced them; concurrent requests
//! with a shared prompt prefix map the same physical blocks and skip
//! re-prefill of the cached span. Under pool pressure the cache evicts
//! least-recently-used entries (preferring those only it references),
//! which is also how a preempted sequence's prefix ages out.

use super::block::{BlockId, BlockPool};
use std::collections::HashMap;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// Chained FNV-1a over the parent key and one block's tokens.
fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    let mut h = FNV_OFFSET;
    for byte in parent.to_le_bytes() {
        h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
    }
    for &t in tokens {
        for byte in t.to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
    }
    // Never collide with the root sentinel.
    if h == 0 {
        1
    } else {
        h
    }
}

struct Entry {
    block: BlockId,
    parent: u64,
    /// This block's tokens, kept to verify exactness under hash
    /// collisions (the parent chain is verified recursively by lookup).
    tokens: Vec<u32>,
    last_used: u64,
}

/// Block-granular prefix cache with LRU eviction.
#[derive(Default)]
pub struct PrefixCache {
    entries: HashMap<u64, Entry>,
    tick: u64,
    pub lookups: u64,
    pub lookup_tokens: u64,
    pub hit_tokens: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Entries removed because their span was rolled back
    /// ([`PrefixCache::forget_from`]), as opposed to LRU-evicted.
    pub invalidations: u64,
}

impl PrefixCache {
    pub fn new() -> Self {
        PrefixCache::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest cached run of whole blocks prefixing `tokens`, capped at
    /// `max_tokens`. Returns the physical blocks in order; the caller
    /// must `retain` each before mapping it into a table.
    pub fn lookup(&mut self, tokens: &[u32], block_tokens: usize, max_tokens: usize) -> Vec<BlockId> {
        self.lookups += 1;
        self.lookup_tokens += tokens.len() as u64;
        self.tick += 1;
        let mut parent = 0u64;
        let mut out = Vec::new();
        for chunk in tokens.chunks_exact(block_tokens) {
            if (out.len() + 1) * block_tokens > max_tokens {
                break;
            }
            let key = chain_hash(parent, chunk);
            match self.entries.get_mut(&key) {
                Some(e) if e.parent == parent && e.tokens == chunk => {
                    e.last_used = self.tick;
                    out.push(e.block);
                    parent = key;
                }
                _ => break,
            }
        }
        self.hit_tokens += (out.len() * block_tokens) as u64;
        out
    }

    /// Read-only variant of [`PrefixCache::lookup`]: how many tokens of
    /// `tokens` (whole blocks, capped at `max_tokens`) this cache could
    /// serve right now. No LRU bump, no stats counted — the replica
    /// placement probe calls this on every candidate replica, and only
    /// the winner's real `lookup` should age the cache or feed the hit
    /// counters.
    pub fn probe_tokens(&self, tokens: &[u32], block_tokens: usize, max_tokens: usize) -> usize {
        let mut parent = 0u64;
        let mut blocks = 0usize;
        for chunk in tokens.chunks_exact(block_tokens) {
            if (blocks + 1) * block_tokens > max_tokens {
                break;
            }
            let key = chain_hash(parent, chunk);
            match self.entries.get(&key) {
                Some(e) if e.parent == parent && e.tokens == chunk => {
                    blocks += 1;
                    parent = key;
                }
                _ => break,
            }
        }
        blocks * block_tokens
    }

    /// Register the whole-block prefix of `tokens` backed by `blocks`
    /// (one physical block per logical block, `blocks.len() >=
    /// tokens.len() / block_tokens`). Existing entries are kept (their
    /// payload is equivalent by construction); new entries retain one
    /// pool reference on their block.
    pub fn insert(
        &mut self,
        pool: &mut BlockPool,
        tokens: &[u32],
        block_tokens: usize,
        blocks: &[BlockId],
    ) {
        self.tick += 1;
        let mut parent = 0u64;
        for (i, chunk) in tokens.chunks_exact(block_tokens).enumerate() {
            let key = chain_hash(parent, chunk);
            match self.entries.get_mut(&key) {
                Some(e) if e.parent == parent && e.tokens == chunk => {
                    e.last_used = self.tick;
                }
                Some(_) => break, // hash collision: stop extending this chain
                None => {
                    pool.retain(blocks[i]);
                    self.entries.insert(
                        key,
                        Entry {
                            block: blocks[i],
                            parent,
                            tokens: chunk.to_vec(),
                            last_used: self.tick,
                        },
                    );
                    self.insertions += 1;
                }
            }
            parent = key;
        }
    }

    /// Evict LRU entries until at least `need` blocks have been freed
    /// (refcount hit zero) or no freeable entry remains. Returns the
    /// number freed. Entries whose block is still shared with a live
    /// sequence are never evicted — releasing them frees nothing now
    /// and would only destroy reuse; they become freeable (and LRU-old)
    /// once their sequences retire.
    pub fn evict_for(&mut self, pool: &mut BlockPool, need: usize) -> usize {
        if need == 0 {
            return 0;
        }
        // One pass: collect freeable entries, oldest first. Releasing an
        // entry only ever drops its own block's count, so the freeable
        // set cannot grow mid-eviction.
        let mut victims: Vec<(u64, u64)> = self
            .entries
            .iter()
            .filter(|(_, e)| pool.refcount(e.block) == 1)
            .map(|(&k, e)| (e.last_used, k))
            .collect();
        victims.sort_unstable();
        let mut freed = 0usize;
        for (_, key) in victims.into_iter().take(need) {
            let e = self.entries.remove(&key).expect("victim exists");
            pool.release(e.block);
            self.evictions += 1;
            freed += 1;
        }
        freed
    }

    /// Remove every cached chain entry of `tokens` that covers any
    /// position at or beyond `keep_len`, releasing its block reference.
    /// Called on sequence rollback (`tokens` is the *pre-truncation*
    /// history) so a rolled-back span can never be served from the
    /// cache. Entries wholly inside the kept prefix stay. The walk
    /// continues through missing or removed entries — children are
    /// keyed on the parent *hash*, which is computable from the tokens
    /// alone — so orphaned children (e.g. after an earlier LRU
    /// eviction of their parent) are still found and dropped.
    pub fn forget_from(
        &mut self,
        pool: &mut BlockPool,
        tokens: &[u32],
        block_tokens: usize,
        keep_len: usize,
    ) {
        let mut parent = 0u64;
        for (i, chunk) in tokens.chunks_exact(block_tokens).enumerate() {
            let key = chain_hash(parent, chunk);
            let covers_dropped = (i + 1) * block_tokens > keep_len;
            match self.entries.get(&key) {
                Some(e) if e.parent == parent && e.tokens == chunk => {
                    if covers_dropped {
                        let e = self.entries.remove(&key).expect("entry just seen");
                        pool.release(e.block);
                        self.invalidations += 1;
                    }
                }
                Some(_) => break, // hash collision: not our chain
                None => {}
            }
            parent = key;
        }
    }

    /// Drop every entry, releasing the cache's block references.
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for (_, e) in self.entries.drain() {
            pool.release(e.block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::block::KvQuant;
    use super::*;
    use crate::model::ModelConfig;

    fn pool(bt: usize, blocks: usize) -> BlockPool {
        let cfg = ModelConfig::test();
        let unit = BlockPool::new(&cfg, bt, KvQuant::F32, 1).block_bytes();
        BlockPool::new(&cfg, bt, KvQuant::F32, blocks * unit)
    }

    fn alloc_n(p: &mut BlockPool, n: usize) -> Vec<BlockId> {
        (0..n).map(|_| p.try_alloc().unwrap()).collect()
    }

    #[test]
    fn lookup_walks_the_chain_and_stops_at_divergence() {
        let mut p = pool(4, 8);
        let mut c = PrefixCache::new();
        let toks: Vec<u32> = (0..12).collect();
        let blocks = alloc_n(&mut p, 3);
        c.insert(&mut p, &toks, 4, &blocks);
        assert_eq!(c.len(), 3);

        // Full hit.
        assert_eq!(c.lookup(&toks, 4, usize::MAX), blocks);
        // Diverging third block: only two hit.
        let mut other = toks.clone();
        other[9] = 99;
        assert_eq!(c.lookup(&other, 4, usize::MAX), blocks[..2]);
        // Diverging first block: no hit.
        other[0] = 99;
        assert!(c.lookup(&other, 4, usize::MAX).is_empty());
        // max_tokens caps the run to whole blocks.
        assert_eq!(c.lookup(&toks, 4, 11), blocks[..2]);
        assert_eq!(c.hit_tokens, 12 + 8 + 0 + 8);
    }

    #[test]
    fn probe_matches_lookup_without_touching_stats_or_lru() {
        let mut p = pool(4, 8);
        let mut c = PrefixCache::new();
        let toks: Vec<u32> = (0..12).collect();
        let blocks = alloc_n(&mut p, 3);
        c.insert(&mut p, &toks, 4, &blocks);

        assert_eq!(c.probe_tokens(&toks, 4, usize::MAX), 12);
        assert_eq!(c.probe_tokens(&toks, 4, 11), 8, "cap rounds down to whole blocks");
        let mut other = toks.clone();
        other[9] = 99;
        assert_eq!(c.probe_tokens(&other, 4, usize::MAX), 8);
        other[0] = 99;
        assert_eq!(c.probe_tokens(&other, 4, usize::MAX), 0);
        // Probing is invisible: no lookups counted, no hit tokens.
        assert_eq!(c.lookups, 0);
        assert_eq!(c.hit_tokens, 0);
        // And it agrees with the real lookup it predicts.
        assert_eq!(c.lookup(&toks, 4, usize::MAX).len() * 4, 12);
    }

    #[test]
    fn insert_holds_references_and_evict_frees() {
        let mut p = pool(4, 4);
        let mut c = PrefixCache::new();
        let toks: Vec<u32> = (0..8).collect();
        let blocks = alloc_n(&mut p, 2);
        c.insert(&mut p, &toks, 4, &blocks);
        // Sequence done: release its own references; cache keeps blocks alive.
        for &b in &blocks {
            p.release(b);
        }
        assert_eq!(p.in_use_blocks(), 2);
        assert_eq!(p.available_blocks(), 2);
        let freed = c.evict_for(&mut p, 1);
        assert_eq!(freed, 1);
        assert_eq!(p.available_blocks(), 3);
        c.clear(&mut p);
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn shared_entries_are_not_evicted() {
        let mut p = pool(4, 4);
        let mut c = PrefixCache::new();
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (100..104).collect();
        let ba = alloc_n(&mut p, 1);
        let bb = alloc_n(&mut p, 1);
        c.insert(&mut p, &a, 4, &ba); // older
        c.insert(&mut p, &b, 4, &bb);
        p.retain(ba[0]); // a's block also mapped by a live sequence
        p.release(bb[0]); // b's block is cache-only
        p.release(ba[0]); // drop the allocator ref; live seq + cache remain
        let freed = c.evict_for(&mut p, 1);
        assert_eq!(freed, 1, "must free the cache-only block first");
        // The shared entry survives, and further eviction cannot free it.
        assert_eq!(c.lookup(&a, 4, usize::MAX).len(), 1);
        assert!(c.lookup(&b, 4, usize::MAX).is_empty());
        assert_eq!(c.evict_for(&mut p, 1), 0, "shared block is pinned");
    }

    #[test]
    fn forget_from_drops_exactly_the_rolled_back_span() {
        let mut p = pool(4, 8);
        let mut c = PrefixCache::new();
        let toks: Vec<u32> = (0..16).collect();
        let blocks = alloc_n(&mut p, 4);
        c.insert(&mut p, &toks, 4, &blocks);
        assert_eq!(c.len(), 4);
        // Roll back to 10 tokens: block 2 (positions 8..12) and block 3
        // (12..16) cover dropped positions; blocks 0 and 1 stay.
        c.forget_from(&mut p, &toks, 4, 10);
        assert_eq!(c.invalidations, 2);
        assert_eq!(c.lookup(&toks, 4, usize::MAX), blocks[..2]);
        // The dropped entries released their references: only the
        // allocator refs remain on blocks 2 and 3.
        assert_eq!(p.refcount(blocks[2]), 1);
        assert_eq!(p.refcount(blocks[3]), 1);
        assert_eq!(p.refcount(blocks[0]), 2);
        // Another sequence's chain is untouched.
        let other: Vec<u32> = (100..108).collect();
        let ob = alloc_n(&mut p, 2);
        c.insert(&mut p, &other, 4, &ob);
        c.forget_from(&mut p, &toks, 4, 0);
        assert_eq!(c.lookup(&other, 4, usize::MAX), ob);
        assert!(c.lookup(&toks, 4, usize::MAX).is_empty());
    }

    #[test]
    fn reinsert_does_not_double_retain() {
        let mut p = pool(4, 4);
        let mut c = PrefixCache::new();
        let toks: Vec<u32> = (0..4).collect();
        let blocks = alloc_n(&mut p, 1);
        c.insert(&mut p, &toks, 4, &blocks);
        c.insert(&mut p, &toks, 4, &blocks);
        assert_eq!(p.refcount(blocks[0]), 2); // allocator + one cache ref
        assert_eq!(c.insertions, 1);
    }
}
