//! TCP front-end: JSON-lines protocol over `std::net` (tokio is not in
//! the offline vendor set; a thread-per-connection model with the
//! coordinator's dispatcher behind channels gives the same separation
//! of IO and compute). The coordinator may drive one engine or N
//! data-parallel replicas (`run_replicated` / `--replicas`); either
//! way the wire protocol is unchanged — `stats`/`metrics` aggregate
//! across replicas and `trace`/`dump` stamp replica ids.
//!
//! The complete wire-protocol reference below is included verbatim
//! from `docs/PROTOCOL.md` — the single source of truth for every op,
//! request field, and response shape. Its client example compiles and
//! runs as a doctest, so the documented protocol cannot drift from the
//! implementation.
//!
#![doc = include_str!("../../../docs/PROTOCOL.md")]

use crate::coordinator::{Coordinator, CoordinatorConfig, Event, GenRequest, ServeError};
use crate::model::native::Engine;
use crate::util::json::Json;
use crate::util::log;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Run the server until a client sends `{"op":"shutdown"}`.
pub fn run(addr: &str, engine: Box<dyn Engine>, cfg: CoordinatorConfig) -> Result<()> {
    run_replicated(addr, vec![engine], cfg)
}

/// Run the server over N data-parallel engine replicas (one element =
/// today's single-engine behavior; see `Coordinator::new_replicated`).
pub fn run_replicated(
    addr: &str,
    engines: Vec<Box<dyn Engine>>,
    cfg: CoordinatorConfig,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    serve_on(listener, engines, cfg)
}

/// Bind to an OS-assigned port; returns the bound address (tests, e2e).
pub fn spawn_ephemeral(
    engine: Box<dyn Engine>,
    cfg: CoordinatorConfig,
) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<()>>)> {
    spawn_ephemeral_replicated(vec![engine], cfg)
}

/// [`spawn_ephemeral`] over N engine replicas.
pub fn spawn_ephemeral_replicated(
    engines: Vec<Box<dyn Engine>>,
    cfg: CoordinatorConfig,
) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<()>>)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let h = std::thread::spawn(move || serve_on(listener, engines, cfg));
    Ok((addr, h))
}

/// Join (and drop) every finished connection handler. Called on each
/// accept and idle tick so `conns` holds live connections only —
/// before this, one `JoinHandle` accumulated per connection for the
/// whole server lifetime, an unbounded leak under sustained traffic.
fn reap_finished(conns: &mut Vec<std::thread::JoinHandle<()>>) {
    let mut i = 0;
    while i < conns.len() {
        if conns[i].is_finished() {
            // Finished: join() returns immediately. A panicked handler
            // is already logged by the panic hook; the Err is noise.
            let _ = conns.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

fn serve_on(
    listener: TcpListener,
    engines: Vec<Box<dyn Engine>>,
    cfg: CoordinatorConfig,
) -> Result<()> {
    let coord = Arc::new(Coordinator::new_replicated(engines, cfg));
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                reap_finished(&mut conns);
                let coord = coord.clone();
                let stop = stop.clone();
                conns.push(std::thread::spawn(move || {
                    // A handler error is one connection's problem, not
                    // the server's — but swallowing it silently hides
                    // misbehaving clients and broken pipes. Log once
                    // per connection and count it in stats.
                    if let Err(e) = handle_conn(stream, &coord, &stop) {
                        log::warn("server", "connection error", &[("error", format!("{e:#}"))]);
                        coord.note_conn_error();
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                reap_finished(&mut conns);
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn send(stream: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    // Chaos site: injected IO failure on the response path (a client
    // whose socket dies mid-stream), surfacing as the handler's error.
    if crate::util::failpoint::should_fail("server.send") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "failpoint 'server.send': injected IO failure",
        ));
    }
    stream.write_all(j.to_string().as_bytes())?;
    stream.write_all(b"\n")
}

fn handle_conn(
    mut stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                let err = ServeError::BadRequest(format!("malformed JSON: {e}"));
                send(&mut stream, &err.to_json())?;
                continue;
            }
        };
        match msg.get("op").and_then(|o| o.as_str()).unwrap_or("") {
            "generate" => {
                let req = GenRequest::from_json(&msg);
                let rx = coord.generate(req);
                for ev in rx.iter() {
                    match ev {
                        // Liveness probe — internal only, nothing on the wire.
                        Event::Heartbeat => {}
                        Event::Token { text, .. } => {
                            send(&mut stream, &Json::obj(vec![("token", Json::str(text))]))?;
                        }
                        Event::Done {
                            reason,
                            text,
                            prompt_tokens,
                            gen_tokens,
                            ttft_ms,
                            total_ms,
                            timing,
                        } => {
                            let mut fields = vec![
                                ("done", Json::Bool(true)),
                                ("reason", Json::str(reason.as_str())),
                                ("text", Json::str(text)),
                                ("prompt_tokens", Json::num(prompt_tokens as f64)),
                                ("gen_tokens", Json::num(gen_tokens as f64)),
                                ("ttft_ms", Json::num(ttft_ms)),
                                ("total_ms", Json::num(total_ms)),
                            ];
                            // Only traced requests carry the breakdown —
                            // untraced output stays byte-identical.
                            if let Some(t) = timing {
                                fields.push(("timing", t));
                            }
                            send(&mut stream, &Json::obj(fields))?;
                            break;
                        }
                        // Typed terminal failure (shed, expired while
                        // queued, engine failure): forward and move on
                        // — the connection itself is fine.
                        Event::Error(e) => {
                            send(&mut stream, &e.to_json())?;
                            break;
                        }
                    }
                }
            }
            "score" => {
                let text = msg.get("text").and_then(|t| t.as_str()).unwrap_or("").to_string();
                match coord.score(text) {
                    Ok(r) => send(
                        &mut stream,
                        &Json::obj(vec![
                            ("ppl", Json::num(r.ppl)),
                            ("nll", Json::num(r.nll)),
                            ("tokens", Json::num(r.tokens as f64)),
                        ]),
                    )?,
                    Err(e) => send(
                        &mut stream,
                        &ServeError::EngineFailure(e.to_string()).to_json(),
                    )?,
                }
            }
            "stats" => {
                let mut s = coord.stats().unwrap_or(Json::Null);
                // Which integer-kernel tier this process dispatches to
                // (scalar/avx2/neon) — an A/B observability field, since
                // all tiers are bit-identical by contract.
                if let Json::Obj(ref mut m) = s {
                    m.insert(
                        "simd_tier".to_string(),
                        Json::str(crate::quant::simd::active_tier().name()),
                    );
                }
                send(&mut stream, &s)?;
            }
            "trace" => {
                let n = msg.get("n").and_then(|v| v.as_u64()).unwrap_or(16) as usize;
                let t = coord.trace(n).unwrap_or(Json::Arr(Vec::new()));
                send(&mut stream, &Json::obj(vec![("timelines", t)]))?;
            }
            "dump" => {
                // Flight-recorder dump is read lock-free of the worker
                // loop, so it answers even when the engine is wedged.
                send(&mut stream, &Json::obj(vec![("events", coord.dump())]))?;
            }
            "audit" => {
                // Static weight audit: per-tensor reconstruction error
                // vs the Theorem-2 bound. A clean artifact answers with
                // the report; a violated one answers with a typed error
                // naming the offending tensors, the full report riding
                // along for forensics.
                match coord.audit() {
                    Ok(rep) => {
                        let ok = rep.get("ok").and_then(|b| b.as_bool()).unwrap_or(false);
                        if ok {
                            send(&mut stream, &Json::obj(vec![("audit", rep)]))?;
                        } else {
                            let bad: Vec<&str> = rep
                                .get("tensors")
                                .and_then(|t| t.as_arr())
                                .map(|ts| {
                                    ts.iter()
                                        .filter(|t| {
                                            t.get("ok").and_then(|b| b.as_bool())
                                                == Some(false)
                                        })
                                        .filter_map(|t| {
                                            t.get("name").and_then(|n| n.as_str())
                                        })
                                        .collect()
                                })
                                .unwrap_or_default();
                            let err = ServeError::BadRequest(format!(
                                "weight audit failed: [{}] violate the Theorem-2 \
                                 reconstruction bound",
                                bad.join(", ")
                            ));
                            let mut j = err.to_json();
                            if let Json::Obj(m) = &mut j {
                                m.insert("audit".into(), rep);
                            }
                            send(&mut stream, &j)?;
                        }
                    }
                    Err(e) => send(
                        &mut stream,
                        &ServeError::EngineFailure(e.to_string()).to_json(),
                    )?,
                }
            }
            "metrics" => {
                // Prometheus text exposition, carried as one string in
                // the line-framed JSON envelope (the transport is JSON
                // lines; a scrape sidecar unwraps the field).
                let text = coord.prometheus().unwrap_or_default();
                send(&mut stream, &Json::obj(vec![("metrics", Json::str(text))]))?;
            }
            "shutdown" => {
                send(&mut stream, &Json::obj(vec![("ok", Json::Bool(true))]))?;
                stop.store(true, Ordering::SeqCst);
                return Ok(());
            }
            other => {
                let err = ServeError::BadRequest(format!("unknown op '{other}'"));
                send(&mut stream, &err.to_json())?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client (used by examples, benches, and tests).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn send(&mut self, j: &Json) -> Result<()> {
        self.stream.write_all(j.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                anyhow::bail!("connection closed");
            }
            if !line.trim().is_empty() {
                return Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"));
            }
        }
    }

    /// Generate and collect the full response.
    pub fn generate(&mut self, prompt: &str, max_tokens: usize) -> Result<Json> {
        self.send(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(max_tokens as f64)),
        ]))?;
        loop {
            let msg = self.recv()?;
            if msg.get("done").is_some() || msg.get("error").is_some() {
                return Ok(msg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, ModelConfig, NativeEngine};

    fn spawn_test_server() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<()>>) {
        let cfg = ModelConfig::test();
        let engine = NativeEngine::dense(DenseModel::random(&cfg, 5, None));
        spawn_ephemeral(
            Box::new(engine),
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 16,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn generate_score_stats_shutdown_roundtrip() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();

        let done = c.generate("hello world", 5).unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        assert_eq!(done.get("gen_tokens").unwrap().as_u64(), Some(5));
        assert!(done.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);

        c.send(&Json::obj(vec![
            ("op", Json::str("score")),
            ("text", Json::str("score this text")),
        ]))
        .unwrap();
        let score = c.recv().unwrap();
        assert!(score.get("ppl").unwrap().as_f64().unwrap() > 1.0);

        c.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
        let stats = c.recv().unwrap();
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(1));
        // Paged-KV stats are part of the snapshot.
        assert_eq!(stats.get("kv_block_tokens").unwrap().as_u64(), Some(16));
        assert!(stats.get("kv_blocks_capacity").unwrap().as_u64().unwrap() > 0);
        assert!(stats.get("prefix_lookups").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(stats.get("kv_quant").unwrap().as_str(), Some("f32"));

        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let ok = c.recv().unwrap();
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn streaming_tokens_arrive_before_done() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        c.send(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("abc")),
            ("max_tokens", Json::num(4.0)),
        ]))
        .unwrap();
        let mut tokens = 0;
        loop {
            let msg = c.recv().unwrap();
            if msg.get("token").is_some() {
                tokens += 1;
            } else if msg.get("done").is_some() {
                break;
            }
        }
        assert_eq!(tokens, 4);
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn bad_json_reports_error_and_keeps_connection() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        c.stream.write_all(b"{not json\n").unwrap();
        let err = c.recv().unwrap();
        let body = err.get("error").expect("typed error object");
        assert_eq!(body.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(body
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("malformed JSON"));
        // Connection still works.
        let done = c.generate("x", 2).unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn unknown_op_answers_typed_bad_request() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        c.send(&Json::obj(vec![("op", Json::str("frobnicate"))])).unwrap();
        let err = c.recv().unwrap();
        let body = err.get("error").expect("typed error object");
        assert_eq!(body.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(body.get("message").unwrap().as_str().unwrap().contains("frobnicate"));
        // The connection survives a bad op.
        let done = c.generate("y", 2).unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn deadline_ms_field_expires_request() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        // A 1 ms deadline on a long prompt cannot be met; the wire-level
        // terminal is a normal Done with reason deadline_exceeded.
        c.send(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(&"z".repeat(400))),
            ("max_tokens", Json::num(500.0)),
            ("deadline_ms", Json::num(1.0)),
        ]))
        .unwrap();
        let done = loop {
            let msg = c.recv().unwrap();
            if msg.get("done").is_some() || msg.get("error").is_some() {
                break msg;
            }
        };
        assert_eq!(done.get("reason").unwrap().as_str(), Some("deadline_exceeded"));
        // The server keeps serving.
        let ok = c.generate("after", 2).unwrap();
        assert_eq!(ok.get("reason").unwrap().as_str(), Some("max_tokens"));
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn trace_dump_metrics_ops_roundtrip() {
        let (addr, handle) = spawn_test_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();

        // A traced generate carries the timing breakdown on the wire...
        c.send(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("traced request")),
            ("max_tokens", Json::num(3.0)),
            ("trace", Json::Bool(true)),
        ]))
        .unwrap();
        let done = loop {
            let msg = c.recv().unwrap();
            if msg.get("done").is_some() {
                break msg;
            }
        };
        let timing = done.get("timing").expect("traced done carries timing");
        assert!(timing.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(timing.get("prefill_ms").unwrap().as_f64().unwrap() >= 0.0);

        // ...an untraced one does not.
        let plain = c.generate("untraced", 2).unwrap();
        assert!(plain.get("timing").is_none(), "timing is opt-in");

        // trace op: newest-first completed timelines.
        c.send(&Json::obj(vec![("op", Json::str("trace")), ("n", Json::num(8.0))]))
            .unwrap();
        let t = c.recv().unwrap();
        let lines = t.get("timelines").unwrap().as_arr().unwrap();
        assert_eq!(lines.len(), 1, "only the traced request recorded a timeline");
        assert_eq!(lines[0].get("reason").unwrap().as_str(), Some("max_tokens"));

        // dump op: flight-recorder ring (admit/round events at minimum).
        c.send(&Json::obj(vec![("op", Json::str("dump"))])).unwrap();
        let d = c.recv().unwrap();
        let events = d.get("events").unwrap().as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get("kind").unwrap().as_str() == Some("admit")),
            "flight recorder saw an admission"
        );

        // metrics op: Prometheus text exposition.
        c.send(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
        let m = c.recv().unwrap();
        let text = m.get("metrics").unwrap().as_str().unwrap();
        assert!(text.contains("itq3s_requests_finished_total 2"), "{text}");
        assert!(text.contains("# TYPE itq3s_ttft_ms_hist histogram"), "{text}");

        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn audit_op_reports_clean_quantized_weights() {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 5, None);
        let q = crate::model::QuantizedModel::quantize(
            &dense,
            crate::quant::format_by_name("itq3_s").unwrap(),
        );
        let (addr, handle) = spawn_ephemeral(
            Box::new(NativeEngine::quantized(q)),
            CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        c.send(&Json::obj(vec![("op", Json::str("audit"))])).unwrap();
        let rep = c.recv().unwrap();
        let audit = rep.get("audit").expect("clean artifact answers with the report");
        assert_eq!(audit.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(audit.get("fmt").unwrap().as_str(), Some("itq3_s"));
        let tensors = audit.get("tensors").unwrap().as_arr().unwrap();
        assert_eq!(tensors.len(), cfg.n_layers * 7);
        for t in tensors {
            assert!(
                t.get("margin").unwrap().as_f64().unwrap() > 0.0,
                "clean tensors pass with headroom: {t}"
            );
        }
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn reap_joins_finished_handles_and_keeps_live_ones() {
        use std::sync::mpsc;
        // 100 short-lived handlers all finish; one long-lived handler
        // stays. Reaping must drop exactly the finished 100 — the
        // regression was never reaping at all, so `conns` grew one
        // handle per connection forever.
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for _ in 0..100 {
            conns.push(std::thread::spawn(|| {}));
        }
        let (tx, rx) = mpsc::channel::<()>();
        conns.push(std::thread::spawn(move || {
            let _ = rx.recv(); // blocks until the test releases it
        }));
        // Wait for the short handlers to finish (join-free: poll).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            reap_finished(&mut conns);
            if conns.len() == 1 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "{} handles unreaped", conns.len());
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(conns.len(), 1, "the live handler must not be reaped");
        tx.send(()).unwrap();
        let h = conns.pop().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !h.is_finished() {
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        h.join().unwrap();
    }

    #[test]
    fn many_short_connections_cycle_cleanly() {
        // Drive the real accept loop through dozens of short
        // connections: every handler exits, the server keeps accepting,
        // and shutdown still drains cleanly (the reap path runs on
        // every accept, so the handle list stays bounded — the bound
        // itself is pinned by `reap_joins_finished_handles...` above).
        let (addr, handle) = spawn_test_server();
        let addrs = addr.to_string();
        for i in 0..40 {
            let mut c = Client::connect(&addrs).unwrap();
            if i % 2 == 0 {
                c.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
                let _ = c.recv().unwrap();
            }
            // Dropping the client closes the socket; the handler exits.
        }
        let mut c = Client::connect(&addrs).unwrap();
        let done = c.generate("still alive", 2).unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn replicated_server_roundtrip_aggregates_stats() {
        let cfg = ModelConfig::test();
        let engines: Vec<Box<dyn Engine>> = (0..2)
            .map(|_| {
                Box::new(NativeEngine::dense(DenseModel::random(&cfg, 5, None)))
                    as Box<dyn Engine>
            })
            .collect();
        let (addr, handle) = spawn_ephemeral_replicated(
            engines,
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let done = c.generate("replicated hello", 4).unwrap();
        assert_eq!(done.get("gen_tokens").unwrap().as_u64(), Some(4));
        c.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
        let stats = c.recv().unwrap();
        assert_eq!(stats.get("replicas").unwrap().as_u64(), Some(2));
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(1));
        assert!(stats.get("per_replica").unwrap().as_arr().unwrap().len() == 2);
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (addr, handle) = spawn_test_server();
        let addrs = addr.to_string();
        let threads: Vec<_> = (0..3)
            .map(|i| {
                let a = addrs.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let done = c.generate(&format!("client {i}"), 3).unwrap();
                    assert_eq!(done.get("gen_tokens").unwrap().as_u64(), Some(3));
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let mut c = Client::connect(&addrs).unwrap();
        c.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
        let stats = c.recv().unwrap();
        assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(3));
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }
}
