//! IGUF — a GGUF-like single-file model container.
//!
//! The paper's formats live inside GGUF files (llama.cpp); this is the
//! equivalent substrate built from scratch: a magic/version header, a
//! JSON metadata blob (model config, format name, training provenance),
//! and a table of named tensors whose payloads are either raw f32 or
//! packed quantized blocks. `python/compile/train.py` writes the f32
//! checkpoint in this format; `itq3s quantize` rewrites it in any
//! [`crate::quant::Format`].
//!
//! Layout (all integers little-endian):
//! ```text
//! magic "IGUF" | version u32 | meta_len u64 | meta JSON bytes
//! | n_tensors u64 | entries... | payloads (64-byte aligned each)
//! entry := name_len u32, name, dtype_len u32, dtype,
//!          rows u64, cols u64, padded_cols u64, data_len u64
//! ```

use crate::model::{
    weights::{DenseLayer, PaddedLinear, QuantLayer},
    DenseModel, ModelConfig, QuantizedModel,
};
use crate::quant::{format_by_name, matmul::QuantizedLinear, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"IGUF";
pub const VERSION: u32 = 1;
const ALIGN: usize = 64;

/// One stored tensor.
#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    /// `"f32"` or a quant format name (`"itq3_s"`, ...).
    pub dtype: String,
    pub rows: usize,
    /// Logical column count.
    pub cols: usize,
    /// Stored column count (>= cols when the format required padding).
    pub padded_cols: usize,
    pub data: Vec<u8>,
}

impl TensorEntry {
    pub fn from_f32(name: &str, rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(rows * cols, data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for &x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        TensorEntry {
            name: name.to_string(),
            dtype: "f32".to_string(),
            rows,
            cols,
            padded_cols: cols,
            data: bytes,
        }
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != "f32" {
            bail!("tensor {} has dtype {}, expected f32", self.name, self.dtype);
        }
        // Checked: rows/cols come from untrusted file headers, so the
        // expected-size product must not wrap around in release builds.
        let expect = self
            .rows
            .checked_mul(self.cols)
            .and_then(|n| n.checked_mul(4))
            .with_context(|| format!("tensor {}: element count overflows", self.name))?;
        if self.data.len() != expect {
            bail!("tensor {}: payload size mismatch", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        Ok(Tensor::new(vec![self.rows, self.cols], self.to_f32()?))
    }
}

/// A parsed IGUF file.
pub struct IgufFile {
    pub meta: Json,
    pub tensors: Vec<TensorEntry>,
}

impl IgufFile {
    pub fn tensor(&self, name: &str) -> Result<&TensorEntry> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    /// Serialize to the wire layout (module doc). `save` writes exactly
    /// these bytes; hardening tests build files in memory and corrupt
    /// them deterministically without touching the filesystem.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        let meta = self.meta.to_string().into_bytes();
        buf.extend_from_slice(&(meta.len() as u64).to_le_bytes());
        buf.extend_from_slice(&meta);
        buf.extend_from_slice(&(self.tensors.len() as u64).to_le_bytes());
        for t in &self.tensors {
            buf.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
            buf.extend_from_slice(t.name.as_bytes());
            buf.extend_from_slice(&(t.dtype.len() as u32).to_le_bytes());
            buf.extend_from_slice(t.dtype.as_bytes());
            for v in [t.rows as u64, t.cols as u64, t.padded_cols as u64, t.data.len() as u64]
            {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        for t in &self.tensors {
            while buf.len() % ALIGN != 0 {
                buf.push(0);
            }
            buf.extend_from_slice(&t.data);
        }
        buf
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let buf = self.to_bytes();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        if crate::util::failpoint::should_fail("gguf.load.io") {
            bail!("failpoint 'gguf.load.io': injected IO failure reading {}", path.display());
        }
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut bytes)?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<Self> {
        if crate::util::failpoint::should_fail("gguf.parse.header") {
            bail!("failpoint 'gguf.parse.header': injected header parse failure");
        }
        let mut pos = 0usize;
        // Checked: `n` comes straight from untrusted length fields, so
        // the bound test must not wrap (e.g. meta_len = u64::MAX).
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            let end = pos
                .checked_add(n)
                .filter(|&e| e <= bytes.len())
                .with_context(|| format!("truncated IGUF file at offset {}", *pos))?;
            let s = &bytes[*pos..end];
            *pos = end;
            Ok(s)
        };
        let u32_at = |pos: &mut usize| -> Result<u32> {
            Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
        };
        let u64_at = |pos: &mut usize| -> Result<u64> {
            Ok(u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()))
        };

        if take(&mut pos, 4)? != MAGIC {
            bail!("bad magic (not an IGUF file)");
        }
        let ver = u32_at(&mut pos)?;
        if ver != VERSION {
            bail!("unsupported IGUF version {ver}");
        }
        let meta_len = u64_at(&mut pos)? as usize;
        let meta_str = std::str::from_utf8(take(&mut pos, meta_len)?)
            .context("metadata is not UTF-8")?;
        let meta = Json::parse(meta_str).map_err(|e| anyhow::anyhow!("metadata: {e}"))?;
        let n = u64_at(&mut pos)? as usize;
        if n > 1_000_000 {
            bail!("implausible tensor count {n}");
        }
        let mut headers = Vec::with_capacity(n);
        for _ in 0..n {
            let nl = u32_at(&mut pos)? as usize;
            let name = String::from_utf8(take(&mut pos, nl)?.to_vec())?;
            let dl = u32_at(&mut pos)? as usize;
            let dtype = String::from_utf8(take(&mut pos, dl)?.to_vec())?;
            let rows = u64_at(&mut pos)? as usize;
            let cols = u64_at(&mut pos)? as usize;
            let padded = u64_at(&mut pos)? as usize;
            let dlen = u64_at(&mut pos)? as usize;
            headers.push((name, dtype, rows, cols, padded, dlen));
        }
        let mut tensors = Vec::with_capacity(n);
        for (name, dtype, rows, cols, padded_cols, dlen) in headers {
            if crate::util::failpoint::should_fail("gguf.parse.tensor") {
                bail!("failpoint 'gguf.parse.tensor': injected failure at tensor '{name}'");
            }
            while pos % ALIGN != 0 {
                pos += 1;
            }
            let data = take(&mut pos, dlen)?.to_vec();
            tensors.push(TensorEntry { name, dtype, rows, cols, padded_cols, data });
        }
        Ok(IgufFile { meta, tensors })
    }
}

// ---------------------------------------------------------------------
// Model <-> IGUF
// ---------------------------------------------------------------------

fn layer_names(i: usize) -> [String; 9] {
    [
        format!("layers.{i}.attn_norm"),
        format!("layers.{i}.wq"),
        format!("layers.{i}.wk"),
        format!("layers.{i}.wv"),
        format!("layers.{i}.wo"),
        format!("layers.{i}.ffn_norm"),
        format!("layers.{i}.w1"),
        format!("layers.{i}.w3"),
        format!("layers.{i}.w2"),
    ]
}

/// Serialize a dense f32 model.
pub fn save_dense(model: &DenseModel, path: &Path) -> Result<()> {
    let mut tensors = Vec::new();
    tensors.push(TensorEntry::from_f32(
        "embed",
        model.cfg.vocab,
        model.cfg.dim,
        model.embed.data(),
    ));
    for (i, l) in model.layers.iter().enumerate() {
        let names = layer_names(i);
        tensors.push(TensorEntry::from_f32(&names[0], 1, model.cfg.dim, &l.attn_norm));
        for (name, t) in [
            (&names[1], &l.wq),
            (&names[2], &l.wk),
            (&names[3], &l.wv),
            (&names[4], &l.wo),
        ] {
            tensors.push(TensorEntry::from_f32(name, t.rows(), t.cols(), t.data()));
        }
        tensors.push(TensorEntry::from_f32(&names[5], 1, model.cfg.dim, &l.ffn_norm));
        for (name, t) in [(&names[6], &l.w1), (&names[7], &l.w3), (&names[8], &l.w2)] {
            tensors.push(TensorEntry::from_f32(name, t.rows(), t.cols(), t.data()));
        }
    }
    tensors.push(TensorEntry::from_f32("final_norm", 1, model.cfg.dim, &model.final_norm));
    let meta = Json::obj(vec![
        ("kind", Json::str("dense")),
        ("config", model.cfg.to_json()),
    ]);
    IgufFile { meta, tensors }.save(path)
}

/// Load a dense f32 model.
pub fn load_dense(path: &Path) -> Result<DenseModel> {
    let f = IgufFile::load(path)?;
    let cfg = ModelConfig::from_json(f.meta.get("config").context("missing config")?)
        .context("bad config")?;
    let embed = f.tensor("embed")?.to_tensor()?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let names = layer_names(i);
        layers.push(DenseLayer {
            attn_norm: f.tensor(&names[0])?.to_f32()?,
            wq: f.tensor(&names[1])?.to_tensor()?,
            wk: f.tensor(&names[2])?.to_tensor()?,
            wv: f.tensor(&names[3])?.to_tensor()?,
            wo: f.tensor(&names[4])?.to_tensor()?,
            ffn_norm: f.tensor(&names[5])?.to_f32()?,
            w1: f.tensor(&names[6])?.to_tensor()?,
            w3: f.tensor(&names[7])?.to_tensor()?,
            w2: f.tensor(&names[8])?.to_tensor()?,
        });
    }
    let final_norm = f.tensor("final_norm")?.to_f32()?;
    Ok(DenseModel { cfg, embed, layers, final_norm })
}

fn quant_entry(name: &str, pl: &PaddedLinear, fmt_name: &str) -> TensorEntry {
    TensorEntry {
        name: name.to_string(),
        dtype: fmt_name.to_string(),
        rows: pl.lin.w.rows,
        cols: pl.logical_in,
        padded_cols: pl.lin.w.cols,
        data: pl.lin.w.data.clone(),
    }
}

fn load_quant_entry(t: &TensorEntry) -> Result<PaddedLinear> {
    let fmt = format_by_name(&t.dtype)
        .with_context(|| format!("unknown format '{}' for tensor {}", t.dtype, t.name))?;
    let be = fmt.block_elems();
    if t.padded_cols % be != 0 {
        bail!(
            "tensor {}: padded_cols {} is not a multiple of the {} block size {}",
            t.name,
            t.padded_cols,
            t.dtype,
            be
        );
    }
    // Checked: header fields are untrusted; the size product must not
    // wrap around in release builds.
    let expect = t
        .rows
        .checked_mul(t.padded_cols / be)
        .and_then(|n| n.checked_mul(fmt.block_bytes()))
        .with_context(|| format!("tensor {}: payload size overflows", t.name))?;
    if t.data.len() != expect {
        bail!("tensor {}: payload {} != expected {}", t.name, t.data.len(), expect);
    }
    Ok(PaddedLinear {
        lin: QuantizedLinear {
            w: QuantizedMatrix {
                fmt,
                rows: t.rows,
                cols: t.padded_cols,
                data: t.data.clone(),
            },
        },
        logical_in: t.cols,
    })
}

/// Serialize a quantized model.
pub fn save_quantized(model: &QuantizedModel, path: &Path) -> Result<()> {
    let fmt = &model.fmt_name;
    let mut tensors = Vec::new();
    tensors.push(TensorEntry::from_f32(
        "embed",
        model.cfg.vocab,
        model.cfg.dim,
        model.embed.data(),
    ));
    for (i, l) in model.layers.iter().enumerate() {
        let names = layer_names(i);
        tensors.push(TensorEntry::from_f32(&names[0], 1, model.cfg.dim, &l.attn_norm));
        tensors.push(quant_entry(&names[1], &l.wq, fmt));
        tensors.push(quant_entry(&names[2], &l.wk, fmt));
        tensors.push(quant_entry(&names[3], &l.wv, fmt));
        tensors.push(quant_entry(&names[4], &l.wo, fmt));
        tensors.push(TensorEntry::from_f32(&names[5], 1, model.cfg.dim, &l.ffn_norm));
        tensors.push(quant_entry(&names[6], &l.w1, fmt));
        tensors.push(quant_entry(&names[7], &l.w3, fmt));
        tensors.push(quant_entry(&names[8], &l.w2, fmt));
    }
    tensors.push(TensorEntry::from_f32("final_norm", 1, model.cfg.dim, &model.final_norm));
    let meta = Json::obj(vec![
        ("kind", Json::str("quantized")),
        ("format", Json::str(fmt.as_str())),
        ("config", model.cfg.to_json()),
    ]);
    IgufFile { meta, tensors }.save(path)
}

/// Load a quantized model.
pub fn load_quantized(path: &Path) -> Result<QuantizedModel> {
    let f = IgufFile::load(path)?;
    let cfg = ModelConfig::from_json(f.meta.get("config").context("missing config")?)
        .context("bad config")?;
    let fmt_name = f
        .meta
        .get("format")
        .and_then(|j| j.as_str())
        .context("missing format")?
        .to_string();
    let embed = f.tensor("embed")?.to_tensor()?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let names = layer_names(i);
        layers.push(QuantLayer {
            attn_norm: f.tensor(&names[0])?.to_f32()?,
            wq: load_quant_entry(f.tensor(&names[1])?)?,
            wk: load_quant_entry(f.tensor(&names[2])?)?,
            wv: load_quant_entry(f.tensor(&names[3])?)?,
            wo: load_quant_entry(f.tensor(&names[4])?)?,
            ffn_norm: f.tensor(&names[5])?.to_f32()?,
            w1: load_quant_entry(f.tensor(&names[6])?)?,
            w3: load_quant_entry(f.tensor(&names[7])?)?,
            w2: load_quant_entry(f.tensor(&names[8])?)?,
        });
    }
    let final_norm = f.tensor("final_norm")?.to_f32()?;
    Ok(QuantizedModel { cfg, fmt_name, embed, layers, final_norm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name as fbn;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("itq3s-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn file_roundtrip_raw() {
        let meta = Json::obj(vec![("hello", Json::str("world"))]);
        let t = TensorEntry::from_f32("x", 2, 3, &[1., 2., 3., 4., 5., 6.]);
        let path = tmp("raw.iguf");
        IgufFile { meta: meta.clone(), tensors: vec![t] }.save(&path).unwrap();
        let f = IgufFile::load(&path).unwrap();
        assert_eq!(f.meta, meta);
        assert_eq!(f.tensor("x").unwrap().to_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn dense_model_roundtrip() {
        let cfg = ModelConfig::test();
        let m = DenseModel::random(&cfg, 1, Some(5.0));
        let path = tmp("dense.iguf");
        save_dense(&m, &path).unwrap();
        let m2 = load_dense(&path).unwrap();
        assert_eq!(m2.cfg, cfg);
        assert_eq!(m.embed.data(), m2.embed.data());
        assert_eq!(m.layers[1].w2.data(), m2.layers[1].w2.data());
        assert_eq!(m.final_norm, m2.final_norm);
    }

    #[test]
    fn quantized_model_roundtrip_bit_exact() {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 2, Some(5.0));
        let qm = QuantizedModel::quantize(&dense, fbn("itq3_s").unwrap());
        let path = tmp("quant.iguf");
        save_quantized(&qm, &path).unwrap();
        let qm2 = load_quantized(&path).unwrap();
        assert_eq!(qm2.fmt_name, "itq3_s");
        // Packed payloads are byte-identical.
        assert_eq!(qm.layers[0].wq.lin.w.data, qm2.layers[0].wq.lin.w.data);
        // And they dequantize identically.
        let a = qm.layers[0].w2.lin.w.dequantize();
        let b = qm2.layers[0].w2.lin.w.dequantize();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn corrupted_file_rejected() {
        let path = tmp("bad.iguf");
        std::fs::write(&path, b"NOPE____junk").unwrap();
        assert!(IgufFile::load(&path).is_err());
        // Truncation is caught too.
        let cfg = ModelConfig::test();
        let m = DenseModel::random(&cfg, 3, None);
        let good = tmp("good.iguf");
        save_dense(&m, &good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        assert!(IgufFile::parse(&bytes[..bytes.len() / 2]).is_err());
    }

    fn small_file() -> IgufFile {
        IgufFile {
            meta: Json::obj(vec![("kind", Json::str("test"))]),
            tensors: vec![
                TensorEntry::from_f32("a", 2, 2, &[1., 2., 3., 4.]),
                TensorEntry::from_f32("b", 1, 3, &[5., 6., 7.]),
            ],
        }
    }

    #[test]
    fn truncation_at_every_offset_is_a_typed_error() {
        // The wire format has no optional trailer: every proper prefix
        // cuts a required field or payload and must surface as Err —
        // never a panic, never a partially-populated Ok.
        let bytes = small_file().to_bytes();
        IgufFile::parse(&bytes).expect("full file parses");
        for cut in 0..bytes.len() {
            assert!(
                IgufFile::parse(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} bytes must be rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn corrupt_bytes_never_panic() {
        // Single-byte corruption anywhere in the file may parse (payload
        // bytes are opaque) or err (structure damaged) but must never
        // panic or wrap an allocation size.
        let bytes = small_file().to_bytes();
        crate::util::prop::forall("corrupt IGUF bytes parse totally", 500, |g| {
            let mut b = bytes.clone();
            let i = g.usize_in(0, b.len() - 1);
            b[i] ^= (g.u64() as u8) | 1; // always flips at least one bit
            let _ = IgufFile::parse(&b);
        });
    }

    #[test]
    fn audit_flags_a_corrupted_artifact_and_passes_a_clean_one() {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 6, None);
        let qm = QuantizedModel::quantize(&dense, fbn("itq3_s").unwrap());
        let path = tmp("audit-clean.iguf");
        save_quantized(&qm, &path).unwrap();

        // A clean artifact passes every tensor with headroom.
        let clean = load_quantized(&path).unwrap().audit();
        assert!(clean.ok());
        assert_eq!(clean.tensors.len(), cfg.n_layers * 7);
        for t in &clean.tensors {
            assert!(t.margin > 0.0, "{}: margin {}", t.name, t.margin);
        }

        // Corrupt one block's stored f16 scale (d -> +Inf, word at byte
        // offset 96) inside the packed payload of layers.0.wq. Payload
        // bytes are opaque to the parser — the file still loads clean —
        // so only the audit can see the damage.
        let mut f = IgufFile::load(&path).unwrap();
        let t = f.tensors.iter_mut().find(|t| t.name == "layers.0.wq").unwrap();
        t.data[96] = 0x00;
        t.data[97] = 0x7C;
        let bad_path = tmp("audit-corrupt.iguf");
        f.save(&bad_path).unwrap();

        let qm2 = load_quantized(&bad_path).unwrap();
        let report = qm2.audit();
        assert!(!report.ok(), "corrupted scale must violate the audit");
        assert_eq!(report.violations(), vec!["layers.0.wq"]);
        let bad = report.tensors.iter().find(|t| t.name == "layers.0.wq").unwrap();
        assert_eq!(bad.worst_block, 0);
        assert!(bad.detail.contains("non-finite"), "{}", bad.detail);

        // The `audit` op on a server unknowingly serving that artifact
        // answers with a typed error naming the tensor (the serve CLI
        // additionally refuses to start on it — same `ok()` gate).
        let (addr, handle) = crate::server::spawn_ephemeral(
            Box::new(crate::model::NativeEngine::quantized(qm2)),
            crate::coordinator::CoordinatorConfig {
                max_batch: 2,
                kv_budget_bytes: 64 << 20,
                prefill_chunk: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = crate::server::Client::connect(&addr.to_string()).unwrap();
        c.send(&Json::obj(vec![("op", Json::str("audit"))])).unwrap();
        let resp = c.recv().unwrap();
        let err = resp.get("error").expect("typed error for a violated audit");
        assert_eq!(err.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(err.get("message").unwrap().as_str().unwrap().contains("layers.0.wq"));
        // The full report rides along for forensics.
        assert_eq!(
            resp.get("audit").unwrap().get("ok").unwrap().as_bool(),
            Some(false)
        );
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        let _ = c.recv();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn implausible_sizes_are_rejected_not_overflowed() {
        // meta_len = u64::MAX is a truncation error, not an OOM or a
        // wrapped bounds check.
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION.to_le_bytes());
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(IgufFile::parse(&b).is_err());
        // Element-count products that overflow usize are typed errors.
        let t = TensorEntry {
            name: "x".into(),
            dtype: "f32".into(),
            rows: usize::MAX / 2,
            cols: 3,
            padded_cols: 3,
            data: vec![0u8; 12],
        };
        assert!(t.to_f32().is_err());
        // Same for quantized payload sizing: blocks * block_bytes wraps.
        let fmt = fbn("itq3_s").unwrap();
        let q = TensorEntry {
            name: "q".into(),
            dtype: "itq3_s".into(),
            rows: usize::MAX / 2,
            cols: 3,
            padded_cols: 2 * fmt.block_elems(),
            data: vec![0u8; 12],
        };
        assert!(load_quant_entry(&q).is_err());
        // And a padded_cols that is not block-aligned is rejected before
        // any division.
        let misaligned = TensorEntry {
            name: "m".into(),
            dtype: "itq3_s".into(),
            rows: 1,
            cols: 3,
            padded_cols: fmt.block_elems() + 1,
            data: vec![0u8; 12],
        };
        assert!(load_quant_entry(&misaligned).is_err());
    }
}
