//! # ITQ3_S — Interleaved Ternary Quantization with Rotation-Domain Smoothing
//!
//! A from-scratch reproduction of *"ITQ3_S: High-Fidelity 3-bit LLM
//! Inference via Interleaved Ternary Quantization with Rotation-Domain
//! Smoothing"* (Yoon, 2026) as a three-layer Rust + JAX/Pallas stack:
//!
//! - **Layer 1** (build-time Python): Pallas kernels for the fused
//!   unpack → dequantize → inverse-FWHT → matmul pipeline
//!   (`python/compile/kernels/`).
//! - **Layer 2** (build-time Python): a LLaMA-style transformer in JAX
//!   whose linears consume packed ITQ3_S buffers, AOT-lowered to HLO text
//!   (`python/compile/model.py`, `aot.py`).
//! - **Layer 3** (this crate): the serving coordinator — request router,
//!   continuous batcher, KV-cache manager — plus every substrate the
//!   paper depends on: the FWHT, the full quantization format zoo
//!   (ITQ3_S and all evaluated baselines), the W3A8 integer serving
//!   kernels (`quant::act` + `Format::dot_block_q8`, the CPU analog of
//!   the paper's DP4A MMQ/MMVQ pipeline) with row-sharded parallelism
//!   (`util::threadpool`), speculative decoding (`spec`: zero-artifact
//!   drafters + a fused multi-position verify pass with paged-KV
//!   rollback, lossless for greedy *and* sampled decoding via
//!   rejection-sampling verification), a GGUF-like model container, a
//!   perplexity evaluator, and the PJRT runtime that executes the AOT
//!   artifacts. Python never runs on the request path.
//!
//! Standalone documentation:
//!
//! - `docs/ARCHITECTURE.md` — module map, data flow, and the
//!   bit-identity contracts the test suite enforces.
//! - `docs/PROTOCOL.md` — the complete JSON-lines serving protocol
//!   (also included into [`server`]'s rustdoc, where its examples run
//!   as doctests).
//! - `EXPERIMENTS.md` — reproduced tables, benchmark methodology, and
//!   the `BENCH_*.json` schemas.

pub mod bench;
pub mod coordinator;
pub mod eval;
pub mod f16;
pub mod fwht;
pub mod gguf;
pub mod kvpaged;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod tensor;
pub mod util;

/// Temporary CLI placeholder (replaced by the full CLI in `main.rs`).
#[doc(hidden)]
pub fn cli_placeholder() {
    println!("itq3s: CLI under construction");
}
