//! IEEE 754 binary16 ("half") conversion.
//!
//! The ITQ3_S format stores the per-block scale `d_k` and zero-point `z_k`
//! as FP16 (paper §4.1), as do the Q4/Q8 baseline formats, so the container
//! stores raw `u16` and converts at the block boundary. The `half` crate is
//! not in the offline vendor set; conversions are implemented bit-exactly
//! here (round-to-nearest-even on encode).

/// Convert an `f32` to IEEE binary16 bits, rounding to nearest-even,
/// with overflow to ±inf and graceful subnormal handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf or NaN.
        return if man == 0 {
            sign | 0x7C00
        } else {
            // Preserve a quiet NaN with some payload.
            sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF)
        };
    }

    // Unbiased exponent, rebiasing from 127 to 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half. 23-bit mantissa -> 10-bit with RNE.
        let mant = man >> 13;
        let rem = man & 0x1FFF;
        let half_exp = ((unbiased + 15) as u16) << 10;
        let mut out = sign | half_exp | mant as u16;
        // Round: rem > half, or rem == half and mant odd.
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: correct (rounds to inf)
        }
        return out;
    }
    if unbiased >= -25 {
        // Subnormal half.
        let full_man = man | 0x0080_0000; // implicit leading 1
        let shift = (-14 - unbiased) as u32 + 13;
        let mant = full_man >> shift;
        let rem_mask = (1u32 << shift) - 1;
        let rem = full_man & rem_mask;
        let halfway = 1u32 << (shift - 1);
        let mut out = sign | mant as u16;
        if rem > halfway || (rem == halfway && (mant & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow -> signed zero
}

/// Convert IEEE binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((e - 13 + 127) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 precision (quantize-to-half).
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Largest finite f16 value.
pub const F16_MAX: f32 = 65504.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(1e10), 0x7C00); // overflow
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
        // Smallest positive subnormal: 2^-24.
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
    }

    #[test]
    fn nan_preserved() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and the next half;
        // RNE picks the even mantissa (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
        // 1.0 + 3*2^-11 is halfway between odd and even; rounds up to even.
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(y), 0x3C02);
    }

    #[test]
    fn exact_halves_roundtrip() {
        // Every f16 value must round-trip bit-exactly through f32.
        for h in 0u16..=0xFFFF {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(f), h, "h={h:#06x} f={f}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_error_bounded() {
        forall("f16 relative error <= 2^-11", 500, |g| {
            let x = g.f32_in(-60000.0, 60000.0);
            let y = f16_round(x);
            if x != 0.0 && x.abs() >= 2.0f32.powi(-14) {
                let rel = ((y - x) / x).abs();
                assert!(rel <= 2.0f32.powi(-11) + 1e-7, "x={x} y={y} rel={rel}");
            }
        });
    }

    #[test]
    fn prop_monotone() {
        forall("f16 conversion is monotone", 300, |g| {
            let a = g.f32_in(-1000.0, 1000.0);
            let b = g.f32_in(-1000.0, 1000.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(f16_round(lo) <= f16_round(hi));
        });
    }
}
