//! Speculative decoding: draft-and-verify on the fused batch path.
//!
//! PRs 1–3 built exactly the machinery speculative decoding needs —
//! cheap W3A8 integer kernels, a fused batched GEMM that scores many
//! positions per weight-block unpack, and a refcounted paged KV pool —
//! yet a low-batch request still paid one full model pass per token.
//! This module converts that batch efficiency into single-stream
//! latency:
//!
//! 1. a [`Drafter`] guesses the next `k` tokens from state the stack
//!    already has (no second model, no extra artifacts);
//! 2. one **verify pass** ([`spec_step`]) feeds the pending token plus
//!    the `k` drafts through
//!    [`Engine::score_tokens`](crate::model::native::Engine::score_tokens)
//!    — on the native engine that is the same fused Q8 GEMM path as
//!    `decode_batch`, so all `k + 1` positions cost roughly one
//!    weight-unpack sweep — writing KV as it goes;
//! 3. the longest draft prefix matching the model's own greedy argmax
//!    chain is **accepted**; the rejected suffix's KV is **rolled
//!    back** via [`KvStore::truncate`] (dense stores drop tail tokens
//!    in place; the paged pool releases refcounted tail blocks
//!    COW-correctly and invalidates any cached chain entry over the
//!    span).
//!
//! Acceptance logic never changes outputs, only latency: with greedy
//! decoding the accepted run plus the correction/bonus token is
//! *exactly* the token stream sequential
//! [`decode_step`](crate::model::native::Engine::decode_step) rounds
//! would have produced (test-enforced across drafters, draft lengths,
//! and KV backends in `rust/tests/spec_decode.rs`). Temperature
//! sampling is therefore not speculated — lossless sampled
//! verification needs the top-p machinery to replay the sampler's
//! distribution, which lands separately — and the coordinator disables
//! drafting automatically for sampled requests.

pub mod drafter;

pub use drafter::{Drafter, DrafterKind, NgramDrafter, SelfDraft};

use crate::coordinator::sampler::argmax;
use crate::model::native::Engine;
use crate::model::KvStore;

/// Result of one draft-and-verify round.
pub struct SpecOutcome {
    /// Draft tokens verified as the model's own greedy continuation
    /// (`drafts[..accepted]` in the caller's buffer).
    pub accepted: usize,
    /// The model's next token after the accepted run: the correction
    /// for the first rejected draft, or the bonus token when every
    /// draft was accepted.
    pub next: u32,
    /// Greedy argmax at every scored position (`accepted + 1 ..` were
    /// computed under stale context — drafter reuse material).
    pub verify_argmax: Vec<u32>,
    /// Store length before the pass; after the call the store holds
    /// `base + 1 + accepted` tokens.
    pub base: usize,
}

/// One greedy draft-and-verify round over any engine and KV store.
///
/// Feeds `[pending, drafts...]` through the engine's multi-token verify
/// pass, accepts the longest prefix of `drafts` matching the model's
/// greedy argmax chain, rolls the store back to the last accepted
/// position, and returns the model's true next token. On return the
/// store has consumed exactly `pending` plus the accepted drafts —
/// the same state sequential greedy `decode_step` rounds would have
/// left behind.
///
/// The caller must ensure `store.len() + 1 + drafts.len()` does not
/// exceed the store/context capacity (the verify pass writes the whole
/// span before rollback).
pub fn spec_step(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    pending: u32,
    drafts: &[u32],
) -> SpecOutcome {
    let base = store.len();
    let mut feed = Vec::with_capacity(1 + drafts.len());
    feed.push(pending);
    feed.extend_from_slice(drafts);
    let logits = engine.score_tokens(store, &feed);
    debug_assert_eq!(logits.len(), feed.len());
    let verify_argmax: Vec<u32> = logits.iter().map(|l| argmax(l)).collect();
    let mut accepted = 0usize;
    while accepted < drafts.len() && verify_argmax[accepted] == drafts[accepted] {
        accepted += 1;
    }
    // Rollback: keep `pending` plus the accepted run, discard the
    // rejected suffix's tokens and KV.
    store.truncate(base + 1 + accepted);
    SpecOutcome { accepted, next: verify_argmax[accepted], verify_argmax, base }
}

/// Result of [`run_greedy`].
pub struct SpecRun {
    /// The produced greedy tokens: `n` of them, or fewer if the
    /// context window filled first.
    pub tokens: Vec<u32>,
    /// Draft tokens proposed across all verify passes.
    pub drafted: u64,
    /// Draft tokens accepted across all verify passes.
    pub accepted: u64,
}

/// Single-stream reference driver: prefill `prompt`, then produce `n`
/// greedy tokens with up-to-`k`-token drafts from `drafter` verified
/// through [`spec_step`] (rounds where the drafter proposes nothing
/// fall back to one vanilla `decode_step`). This is the round protocol
/// the coordinator's speculative path follows, minus scheduling — the
/// differential tests and `benches/spec_decode.rs` both drive this one
/// function, so the measured protocol and the tested protocol cannot
/// drift apart.
pub fn run_greedy(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    prompt: &[u32],
    n: usize,
    drafter: &mut dyn Drafter,
    k: usize,
) -> SpecRun {
    let max_seq = engine.config().max_seq;
    let l = engine.prefill(store, prompt);
    let mut pending = argmax(l.row(prompt.len() - 1));
    let mut tokens = vec![pending];
    let mut history: Vec<u32> = prompt.to_vec();
    history.push(pending);
    let (mut drafted, mut accepted) = (0u64, 0u64);
    while tokens.len() < n {
        if store.len() >= max_seq {
            break; // context exhausted: the pending token cannot be fed
        }
        // The verify span (pending + drafts) must fit the context, and
        // drafts past the remaining token budget would be surplus work
        // (a round produces up to kk + 1 tokens).
        let kk = k
            .min(max_seq - store.len() - 1)
            .min((n - tokens.len()).saturating_sub(1));
        let mut drafts = drafter.draft(&history, kk);
        drafts.truncate(kk);
        if drafts.is_empty() {
            let logits = engine.decode_step(store, pending);
            pending = argmax(&logits);
            tokens.push(pending);
            history.push(pending);
            continue;
        }
        let o = spec_step(engine, store, pending, &drafts);
        drafter.observe(&drafts, o.accepted, &o.verify_argmax);
        drafted += drafts.len() as u64;
        accepted += o.accepted as u64;
        for &g in &drafts[..o.accepted] {
            tokens.push(g);
            history.push(g);
        }
        pending = o.next;
        tokens.push(pending);
        history.push(pending);
        assert_eq!(
            store.len(),
            prompt.len() + tokens.len() - 1,
            "store must hold exactly the fed prefix after rollback"
        );
    }
    tokens.truncate(n);
    SpecRun { tokens, drafted, accepted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, KvCache, ModelConfig, NativeEngine};

    fn engine() -> NativeEngine {
        NativeEngine::dense(DenseModel::random(&ModelConfig::test(), 17, Some(5.0)))
    }

    /// Greedy reference stream: first token from the prefill logits,
    /// then sequential decode steps.
    fn greedy_reference(eng: &NativeEngine, prompt: &[u32], n: usize) -> Vec<u32> {
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, prompt);
        let mut tok = argmax(l.row(prompt.len() - 1));
        let mut out = vec![tok];
        while out.len() < n {
            let logits = eng.decode_step(&mut c, tok);
            tok = argmax(&logits);
            out.push(tok);
        }
        out
    }

    #[test]
    fn all_correct_drafts_are_accepted_with_a_bonus_token() {
        let eng = engine();
        let prompt = [1u32, 2, 3, 4, 5];
        let want = greedy_reference(&eng, &prompt, 6);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        assert_eq!(pending, want[0]);
        // Oracle drafts: the true greedy continuation.
        let o = spec_step(&eng, &mut c, pending, &want[1..5]);
        assert_eq!(o.accepted, 4, "oracle drafts must all be accepted");
        assert_eq!(o.next, want[5], "bonus token must be the true 6th token");
        assert_eq!(c.len(), prompt.len() + 5, "pending + 4 accepted consumed");
    }

    #[test]
    fn all_wrong_drafts_degrade_to_one_true_token() {
        let eng = engine();
        let prompt = [9u32, 8, 7];
        let want = greedy_reference(&eng, &prompt, 2);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        // Drafts guaranteed wrong: shift the true tokens by one.
        let bad: Vec<u32> = (0..4).map(|i| (want[1] + 1 + i) % 256).collect();
        let base = c.len();
        let o = spec_step(&eng, &mut c, pending, &bad);
        assert_eq!(o.accepted, 0);
        assert_eq!(o.next, want[1], "correction token is the true next token");
        assert_eq!(c.len(), base + 1, "rejected suffix rolled back");
    }

    #[test]
    fn partial_acceptance_stops_at_first_divergence() {
        let eng = engine();
        let prompt = [40u32, 41, 42, 43];
        let want = greedy_reference(&eng, &prompt, 5);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        // Two correct drafts, then a wrong one, then garbage.
        let drafts = vec![want[1], want[2], (want[3] + 1) % 256, 0];
        let base = c.len();
        let o = spec_step(&eng, &mut c, pending, &drafts);
        assert_eq!(o.accepted, 2);
        assert_eq!(o.next, want[3], "correction replaces the rejected draft");
        assert_eq!(c.len(), base + 3);
        // The verify chain prefix is the true token stream.
        assert_eq!(&o.verify_argmax[..3], &want[1..4]);
    }
}
