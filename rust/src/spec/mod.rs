//! Speculative decoding: draft-and-verify on the fused batch path,
//! lossless under greedy *and* sampled decoding.
//!
//! PRs 1–3 built exactly the machinery speculative decoding needs —
//! cheap W3A8 integer kernels, a fused batched GEMM that scores many
//! positions per weight-block unpack, and a refcounted paged KV pool —
//! yet a low-batch request still paid one full model pass per token.
//! This module converts that batch efficiency into single-stream
//! latency:
//!
//! 1. a [`Drafter`] guesses the next `k` tokens from state the stack
//!    already has (no second model, no extra artifacts), each wrapped
//!    in a [`DraftDist`] — the proposal distribution the token was
//!    drawn from (a point mass for the built-in drafters);
//! 2. one **verify pass** feeds the pending token plus the `k` drafts
//!    through
//!    [`Engine::score_tokens`](crate::model::native::Engine::score_tokens)
//!    — on the native engine that is the same fused Q8 GEMM path as
//!    `decode_batch`, so all `k + 1` positions cost roughly one
//!    weight-unpack sweep — writing KV as it goes and returning
//!    per-position logits;
//! 3. [`spec_step_sampled`] runs the **rejection-sampling accept
//!    loop** against the sequence's own seeded
//!    [`Sampler`](crate::coordinator::sampler::Sampler): at each
//!    drafted position the target distribution is the sampler's
//!    post-filter (temperature/top-k/top-p) distribution over the
//!    verify logits; draft `d` is accepted with probability
//!    `min(1, p_target(d) / p_draft(d))`, and the first rejection is
//!    replaced by a token from the normalized residual
//!    `max(0, p_target - p_draft)` restricted to the post-filter
//!    support. The rejected suffix's KV is **rolled back** via
//!    [`KvStore::truncate`] (dense stores drop tail tokens in place;
//!    the paged pool releases refcounted tail blocks COW-correctly and
//!    invalidates any cached chain entry over the span).
//!
//! Acceptance never changes the output distribution, only latency —
//! the standard speculative-sampling theorem. Two special cases make
//! it *exactly* lossless in the strongest (same-seed, token-identical)
//! sense this repo tests by:
//!
//! - **Point-mass drafts** (the default [`Drafter::draft_dist`]): the
//!   accept rule is implemented as a *coupled replay* — the verifier
//!   draws the target's own token `t*` exactly as vanilla sampling
//!   would (same [`Sampler::dist`]/[`Sampler::draw`] arithmetic, same
//!   RNG stream) and accepts iff `t* == d`. Mathematically this *is*
//!   rejection sampling (accept probability `p_target(d)`, and `t*`
//!   conditioned on rejection follows the normalized residual, which
//!   for a point mass is the target restricted to `!= d`), but the
//!   coupling additionally makes the produced token stream
//!   bit-identical to vanilla same-seed sampling — test-enforced in
//!   `rust/tests/spec_decode.rs`.
//! - **Greedy decoding** (`temperature <= 0`): the target distribution
//!   is a point mass on the argmax and drawing from it consumes no
//!   randomness, so the loop degenerates to the argmax-prefix rule —
//!   greedy speculation ([`spec_step`]) is a thin wrapper over the
//!   sampled path, not a separate code path.
//!
//! Spread (non-degenerate) proposal distributions take the general
//! accept-ratio + residual-resampling branch, which is
//! distribution-lossless (χ²-tested in `rust/tests/spec_decode.rs`)
//! though not sample-path coupled.

pub mod drafter;

pub use drafter::{DraftDist, Drafter, DrafterKind, NgramDrafter, SelfDraft};

use crate::coordinator::sampler::{argmax, Sampler};
use crate::model::native::Engine;
use crate::model::KvStore;
use crate::util::profile;

/// Result of one draft-and-verify round.
pub struct SpecOutcome {
    /// Draft tokens verified as accepted (`drafts[..accepted]` in the
    /// caller's buffer).
    pub accepted: usize,
    /// The model's next token after the accepted run: the correction
    /// for the first rejected draft, or the bonus token when every
    /// draft was accepted.
    pub next: u32,
    /// Did `next` come from residual resampling after a sampled-mode
    /// rejection? (Greedy corrections and bonus tokens are not
    /// resamples.) Feeds the coordinator's `spec_resample_total`.
    pub resampled: bool,
    /// Greedy argmax at every scored position (`accepted + 1 ..` were
    /// computed under stale context — drafter reuse material).
    pub verify_argmax: Vec<u32>,
    /// Store length before the pass; after the call the store holds
    /// `base + 1 + accepted` tokens.
    pub base: usize,
}

/// One draft-and-verify round over any engine and KV store, lossless
/// for the sampler's exact decoding mode (greedy, temperature,
/// top-k/top-p or any composition).
///
/// Feeds `[pending, drafts...]` through the engine's multi-position
/// verify pass, runs the rejection-sampling accept loop against
/// `sampler` (see the module docs for the acceptance rule and its
/// greedy/point-mass degenerations), rolls the store back to the last
/// accepted position, and returns the model's true next token. On
/// return the store has consumed exactly `pending` plus the accepted
/// drafts — the same state sequential decode rounds would have left
/// behind. For **point-mass** proposals (the default drafters),
/// `sampler`'s RNG additionally advances exactly one draw per produced
/// token (accepted drafts, then the correction or bonus), so spec and
/// vanilla rounds interleave with same-seed token identity. Spread
/// proposals spend extra randomness (accept coins, residual draws):
/// the output *distribution* is still exactly the sampler's, but the
/// sample path is no longer coupled to the vanilla RNG stream.
///
/// The caller must ensure `store.len() + 1 + drafts.len()` does not
/// exceed the store/context capacity (the verify pass writes the whole
/// span before rollback).
pub fn spec_step_sampled(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    pending: u32,
    drafts: &[DraftDist],
    sampler: &mut Sampler,
) -> SpecOutcome {
    let base = store.len();
    let mut feed = Vec::with_capacity(1 + drafts.len());
    feed.push(pending);
    feed.extend(drafts.iter().map(|d| d.token));
    let logits = engine.score_tokens(store, &feed);
    debug_assert_eq!(logits.len(), feed.len());
    let verify_argmax: Vec<u32> = logits.iter().map(|l| argmax(l)).collect();

    // Profiler: everything from here to the rollback is sampler replay
    // (dist/draw/residual arithmetic) — the engine pass above carries
    // its own phase scopes, so this never nests.
    let sampler_scope = profile::scope(profile::Phase::Sampler);
    let mut accepted = 0usize;
    let mut next = None;
    let mut resampled = false;
    for d in drafts {
        let target = sampler.dist(&logits[accepted]);
        if d.is_point() {
            // Coupled replay (see module docs): draw the target's own
            // token with vanilla's exact arithmetic and RNG stream;
            // accepting iff it equals the draft IS the rejection rule
            // for a point-mass proposal, and rejection hands us the
            // residual-distributed correction for free.
            let t_star = sampler.draw(&target);
            if t_star == d.token {
                accepted += 1;
                continue;
            }
            resampled = !target.is_greedy();
            next = Some(t_star);
        } else {
            // General rejection sampling: accept with probability
            // min(1, p_target(d) / p_draft(d)). p_t >= p_d accepts
            // with certainty, so no coin is spent on it.
            let p_t = target.prob_of(d.token);
            let p_d = d.prob_of(d.token).max(f64::MIN_POSITIVE);
            if p_t >= p_d || sampler.next_uniform() * p_d < p_t {
                accepted += 1;
                continue;
            }
            // Residual resample, restricted to the target's post-filter
            // support (tokens the truncated target can emit at all —
            // what keeps truncated-support compositions exactly
            // lossless).
            let residual: Vec<(u32, f64)> = target
                .support()
                .iter()
                .map(|&(t, p)| (t, (p - d.prob_of(t)).max(0.0)))
                .filter(|&(_, p)| p > 0.0)
                .collect();
            let sum: f64 = residual.iter().map(|&(_, p)| p).sum();
            next = Some(if sum > 0.0 {
                let norm: Vec<(u32, f64)> = residual.iter().map(|&(t, p)| (t, p / sum)).collect();
                sampler.draw_from(&norm)
            } else {
                // Numerically-empty residual (proposal dominates the
                // target everywhere, so the reject branch has measure
                // ~0): a fresh target draw is still the target law.
                sampler.draw(&target)
            });
            resampled = true;
        }
        break;
    }
    let next = next.unwrap_or_else(|| {
        // Every draft accepted: the bonus token from the last scored
        // position, drawn exactly as a vanilla round would.
        let target = sampler.dist(&logits[drafts.len()]);
        sampler.draw(&target)
    });
    drop(sampler_scope);
    // Rollback: keep `pending` plus the accepted run, discard the
    // rejected suffix's tokens and KV.
    store.truncate(base + 1 + accepted);
    SpecOutcome { accepted, next, resampled, verify_argmax, base }
}

/// One greedy draft-and-verify round: accepts the longest prefix of
/// `drafts` matching the model's greedy argmax chain. This is
/// [`spec_step_sampled`] with a greedy sampler and point-mass drafts —
/// the temperature-0 special case, kept as the zero-state entry point
/// for callers that have no sampler (benches, greedy-only tests).
pub fn spec_step(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    pending: u32,
    drafts: &[u32],
) -> SpecOutcome {
    let dd: Vec<DraftDist> = drafts.iter().map(|&t| DraftDist::point(t)).collect();
    // A greedy sampler never touches its RNG, so the seed is inert.
    let mut greedy = Sampler::new(0.0, 0);
    spec_step_sampled(engine, store, pending, &dd, &mut greedy)
}

/// Result of [`run_greedy`] / [`run_sampled`].
pub struct SpecRun {
    /// The produced tokens: `n` of them, or fewer if the context
    /// window filled first.
    pub tokens: Vec<u32>,
    /// Draft tokens proposed across all verify passes.
    pub drafted: u64,
    /// Draft tokens accepted across all verify passes.
    pub accepted: u64,
    /// Verify rounds whose correction token came from residual
    /// resampling (sampled mode only; always 0 for greedy runs).
    pub resampled: u64,
}

/// Single-stream sampled driver: prefill `prompt`, then produce `n`
/// tokens with the sequence's own seeded `sampler`, speculating with
/// up-to-`k`-token proposals from `drafter` verified through
/// [`spec_step_sampled`] (rounds where the drafter proposes nothing
/// fall back to one vanilla `decode_step` + sample). This is the round
/// protocol the coordinator's speculative path follows, minus
/// scheduling — the differential tests and `benches/spec_decode.rs`
/// both drive this one function, so the measured protocol and the
/// tested protocol cannot drift apart.
pub fn run_sampled(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    prompt: &[u32],
    n: usize,
    drafter: &mut dyn Drafter,
    k: usize,
    sampler: &mut Sampler,
) -> SpecRun {
    let max_seq = engine.config().max_seq;
    let l = engine.prefill(store, prompt);
    let mut pending = sampler.sample(l.row(prompt.len() - 1));
    let mut tokens = vec![pending];
    let mut history: Vec<u32> = prompt.to_vec();
    history.push(pending);
    let (mut drafted, mut accepted, mut resampled) = (0u64, 0u64, 0u64);
    while tokens.len() < n {
        if store.len() >= max_seq {
            break; // context exhausted: the pending token cannot be fed
        }
        // The verify span (pending + drafts) must fit the context, and
        // drafts past the remaining token budget would be surplus work
        // (a round produces up to kk + 1 tokens).
        let kk = k
            .min(max_seq - store.len() - 1)
            .min((n - tokens.len()).saturating_sub(1));
        let mut drafts = drafter.draft_dist(&history, kk);
        drafts.truncate(kk);
        if drafts.is_empty() {
            let logits = engine.decode_step(store, pending);
            pending = sampler.sample(&logits);
            tokens.push(pending);
            history.push(pending);
            continue;
        }
        let o = spec_step_sampled(engine, store, pending, &drafts, sampler);
        let draft_toks: Vec<u32> = drafts.iter().map(|d| d.token).collect();
        drafter.observe(&draft_toks, o.accepted, &o.verify_argmax);
        drafted += drafts.len() as u64;
        accepted += o.accepted as u64;
        resampled += o.resampled as u64;
        for &g in &draft_toks[..o.accepted] {
            tokens.push(g);
            history.push(g);
        }
        pending = o.next;
        tokens.push(pending);
        history.push(pending);
        assert_eq!(
            store.len(),
            prompt.len() + tokens.len() - 1,
            "store must hold exactly the fed prefix after rollback"
        );
    }
    tokens.truncate(n);
    SpecRun { tokens, drafted, accepted, resampled }
}

/// Single-stream greedy driver: [`run_sampled`] with a greedy sampler
/// (which never touches its RNG) — kept as the zero-state entry point
/// for greedy benches and tests.
pub fn run_greedy(
    engine: &dyn Engine,
    store: &mut dyn KvStore,
    prompt: &[u32],
    n: usize,
    drafter: &mut dyn Drafter,
    k: usize,
) -> SpecRun {
    let mut greedy = Sampler::new(0.0, 0);
    run_sampled(engine, store, prompt, n, drafter, k, &mut greedy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, KvCache, ModelConfig, NativeEngine};

    fn engine() -> NativeEngine {
        NativeEngine::dense(DenseModel::random(&ModelConfig::test(), 17, Some(5.0)))
    }

    /// Greedy reference stream: first token from the prefill logits,
    /// then sequential decode steps.
    fn greedy_reference(eng: &NativeEngine, prompt: &[u32], n: usize) -> Vec<u32> {
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, prompt);
        let mut tok = argmax(l.row(prompt.len() - 1));
        let mut out = vec![tok];
        while out.len() < n {
            let logits = eng.decode_step(&mut c, tok);
            tok = argmax(&logits);
            out.push(tok);
        }
        out
    }

    /// Sampled reference stream with a fresh sampler built by `mk`.
    fn sampled_reference(
        eng: &NativeEngine,
        prompt: &[u32],
        n: usize,
        mk: impl Fn() -> Sampler,
    ) -> Vec<u32> {
        let mut c = KvCache::new(eng.config());
        let mut s = mk();
        let l = eng.prefill(&mut c, prompt);
        let mut tok = s.sample(l.row(prompt.len() - 1));
        let mut out = vec![tok];
        while out.len() < n {
            let logits = eng.decode_step(&mut c, tok);
            tok = s.sample(&logits);
            out.push(tok);
        }
        out
    }

    #[test]
    fn all_correct_drafts_are_accepted_with_a_bonus_token() {
        let eng = engine();
        let prompt = [1u32, 2, 3, 4, 5];
        let want = greedy_reference(&eng, &prompt, 6);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        assert_eq!(pending, want[0]);
        // Oracle drafts: the true greedy continuation.
        let o = spec_step(&eng, &mut c, pending, &want[1..5]);
        assert_eq!(o.accepted, 4, "oracle drafts must all be accepted");
        assert_eq!(o.next, want[5], "bonus token must be the true 6th token");
        assert!(!o.resampled, "greedy rounds never resample");
        assert_eq!(c.len(), prompt.len() + 5, "pending + 4 accepted consumed");
    }

    #[test]
    fn all_wrong_drafts_degrade_to_one_true_token() {
        let eng = engine();
        let prompt = [9u32, 8, 7];
        let want = greedy_reference(&eng, &prompt, 2);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        // Drafts guaranteed wrong: shift the true tokens by one.
        let bad: Vec<u32> = (0..4).map(|i| (want[1] + 1 + i) % 256).collect();
        let base = c.len();
        let o = spec_step(&eng, &mut c, pending, &bad);
        assert_eq!(o.accepted, 0);
        assert_eq!(o.next, want[1], "correction token is the true next token");
        assert_eq!(c.len(), base + 1, "rejected suffix rolled back");
    }

    #[test]
    fn partial_acceptance_stops_at_first_divergence() {
        let eng = engine();
        let prompt = [40u32, 41, 42, 43];
        let want = greedy_reference(&eng, &prompt, 5);
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let pending = argmax(l.row(prompt.len() - 1));
        // Two correct drafts, then a wrong one, then garbage.
        let drafts = vec![want[1], want[2], (want[3] + 1) % 256, 0];
        let base = c.len();
        let o = spec_step(&eng, &mut c, pending, &drafts);
        assert_eq!(o.accepted, 2);
        assert_eq!(o.next, want[3], "correction replaces the rejected draft");
        assert_eq!(c.len(), base + 3);
        // The verify chain prefix is the true token stream.
        assert_eq!(&o.verify_argmax[..3], &want[1..4]);
    }

    #[test]
    fn sampled_point_mass_round_replays_vanilla_rng_exactly() {
        // One sampled verify round with point-mass drafts must consume
        // the RNG and produce tokens exactly as vanilla same-seed
        // sampling would — whatever the drafts are.
        let eng = engine();
        let prompt = [3u32, 1, 4, 1, 5];
        let mk = || Sampler::new(0.8, 123).with_top_k(Some(16));
        let want = sampled_reference(&eng, &prompt, 5, mk);

        for junk in [[7u32, 7, 7, 7], [250, 1, 9, 33]] {
            // Draft junk (arbitrary acceptance pattern) and then finish
            // the stream with vanilla rounds: the full token stream and
            // the sampler state must match the reference.
            let mut c = KvCache::new(eng.config());
            let mut s = mk();
            let l = eng.prefill(&mut c, &prompt);
            let mut tokens = vec![s.sample(l.row(prompt.len() - 1))];
            let dd: Vec<DraftDist> = junk.iter().map(|&t| DraftDist::point(t)).collect();
            let o = spec_step_sampled(&eng, &mut c, tokens[0], &dd, &mut s);
            tokens.extend(junk[..o.accepted].iter().copied());
            tokens.push(o.next);
            while tokens.len() < 5 {
                let logits = eng.decode_step(&mut c, *tokens.last().unwrap());
                tokens.push(s.sample(&logits));
            }
            tokens.truncate(5);
            assert_eq!(tokens, want, "junk={junk:?}");
        }
    }

    #[test]
    fn run_sampled_is_token_identical_to_vanilla_for_point_drafters() {
        let eng = engine();
        let prompt = [10u32, 11, 12, 10, 11, 12, 10, 11];
        let mk = || Sampler::new(0.9, 7).with_top_p(Some(0.9));
        let want = sampled_reference(&eng, &prompt, 12, mk);
        for k in [1usize, 3, 6] {
            let mut d = SelfDraft::default();
            let mut c = KvCache::new(eng.config());
            let mut s = mk();
            let run = run_sampled(&eng, &mut c, &prompt, 12, &mut d, k, &mut s);
            assert_eq!(run.tokens, want, "k={k} diverged from vanilla sampling");
            assert!(run.drafted > 0, "self-draft always proposes");
        }
    }

    #[test]
    fn spread_draft_rejection_resamples_within_the_target_support() {
        // A proposal with zero target mass on its token is always
        // rejected; the correction must come from the target's
        // post-filter support and be flagged as a resample.
        let eng = engine();
        let prompt = [5u32, 6, 7, 8];
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let mut s = Sampler::new(0.8, 11).with_top_k(Some(4));
        let pending = s.sample(l.row(prompt.len() - 1));
        // The target support at the next position, from a side sampler
        // (dist() is pure, so this consumes no randomness).
        let mut probe = KvCache::new(eng.config());
        eng.prefill(&mut probe, &prompt);
        let next_logits = eng.decode_step(&mut probe, pending);
        let target = s.dist(&next_logits);
        // Proposal: spread over two tokens that are OUTSIDE the top-4
        // support (tokens get ~0 target probability).
        let outside: Vec<u32> = (0..256u32)
            .filter(|t| target.prob_of(*t) == 0.0)
            .take(2)
            .collect();
        let d = DraftDist {
            token: outside[0],
            probs: vec![(outside[0], 0.5), (outside[1], 0.5)],
        };
        let o = spec_step_sampled(&eng, &mut c, pending, &[d], &mut s);
        assert_eq!(o.accepted, 0, "zero-target-mass draft must be rejected");
        assert!(o.resampled, "correction must be flagged as a resample");
        assert!(
            target.prob_of(o.next) > 0.0,
            "correction {} left the post-filter support",
            o.next
        );
    }

    #[test]
    fn spread_draft_with_dominating_target_is_always_accepted() {
        // p_target(d) >= p_draft(d) accepts deterministically (accept
        // probability 1) — exercised via a proposal that spreads mass
        // away from its own token.
        let eng = engine();
        let prompt = [1u32, 9, 9, 1];
        let mut c = KvCache::new(eng.config());
        let l = eng.prefill(&mut c, &prompt);
        let mut s = Sampler::new(1.0, 5).with_top_k(Some(2));
        let pending = s.sample(l.row(prompt.len() - 1));
        let mut probe = KvCache::new(eng.config());
        eng.prefill(&mut probe, &prompt);
        let next_logits = eng.decode_step(&mut probe, pending);
        let target = s.dist(&next_logits);
        // Propose the target's most likely token but claim only 1% of
        // the proposal mass on it: p_t >= p_d, certain accept.
        let (top, p_top) = target.support()[0];
        assert!(p_top >= 0.01);
        let spread = DraftDist {
            token: top,
            probs: vec![(top, 0.01), (top.wrapping_add(1) % 256, 0.99)],
        };
        let o = spec_step_sampled(&eng, &mut c, pending, &[spread], &mut s);
        assert_eq!(o.accepted, 1, "dominated proposal must always be accepted");
    }
}
