//! Zero-extra-artifact drafters: token-sequence guessers that cost no
//! second model, no adapter heads, and no extra weights.
//!
//! Both implementations draft purely from state the serving stack
//! already has:
//!
//! - [`NgramDrafter`] — prompt-lookup decoding: the longest recent
//!   n-gram suffix of the sequence is searched for an earlier
//!   occurrence in the sequence's *own* token history, and the tokens
//!   that followed that occurrence are proposed. Strong on extractive /
//!   repetitive continuations (summaries, code, structured text), free
//!   elsewhere.
//! - [`SelfDraft`] — greedy-reuse: every verify pass already computes a
//!   greedy argmax at each scored position; the chain beyond the
//!   accepted run (computed under partially stale context) is kept and
//!   replayed as the next round's draft. Bootstraps by repeating the
//!   last token until the first verify pass refills the buffer.
//!
//! Drafters only ever *guess*: the verify pass accepts exactly the
//! prefix that matches the model's own greedy choices, so a bad drafter
//! costs latency, never correctness.

/// A drafted token together with the proposal distribution it was
/// drawn from — the unit the rejection-sampling verify loop
/// ([`crate::spec::spec_step_sampled`]) consumes.
///
/// For the theorem behind lossless sampled speculation to hold, the
/// proposed `token` must actually be *drawn from* `probs` (a drafter
/// with a spread proposal samples with its own RNG). The default
/// everywhere is the degenerate case: a **point mass** on the token the
/// drafter would have proposed greedily, for which rejection sampling
/// reduces to "accept iff the verifier's own draw equals the draft" —
/// no extra randomness, and greedy verification falls out as the
/// temperature-0 special case.
#[derive(Clone, Debug)]
pub struct DraftDist {
    /// The token proposed for this position (drawn from `probs`).
    pub token: u32,
    /// The proposal distribution: `(token, probability)` pairs summing
    /// to 1. Length 1 marks a point mass.
    pub probs: Vec<(u32, f64)>,
}

impl DraftDist {
    /// A point-mass proposal on `token` (the default drafting mode).
    pub fn point(token: u32) -> Self {
        DraftDist { token, probs: vec![(token, 1.0)] }
    }

    /// Is this proposal a point mass (probability 1 on its token)?
    pub fn is_point(&self) -> bool {
        self.probs.len() == 1
    }

    /// Proposal probability of `t` (0 outside the proposal support).
    pub fn prob_of(&self, t: u32) -> f64 {
        self.probs
            .iter()
            .find(|&&(tok, _)| tok == t)
            .map(|&(_, p)| p)
            .unwrap_or(0.0)
    }
}

/// A speculative token proposer. Implementations must be cheap — the
/// coordinator drafts once per decode round per sequence.
pub trait Drafter: Send {
    /// Propose up to `k` tokens continuing `history` (the sequence's
    /// full token stream, ending with the token about to be fed to the
    /// verify pass). Returning fewer than `k` (or none) is always
    /// legal; returning more is truncated by the caller.
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32>;

    /// Propose up to `k` tokens *with their proposal distributions* —
    /// what the sampled verify loop consumes. The default wraps
    /// [`Drafter::draft`]'s tokens as point masses, which makes
    /// rejection sampling degenerate to exact-match acceptance (and, at
    /// temperature 0, to the greedy argmax-prefix rule) — greedy
    /// speculation is a special case of this interface, not a separate
    /// code path. Drafters with a genuine distribution (e.g. a small
    /// draft model) override this and must *sample* each token from
    /// its returned distribution.
    fn draft_dist(&mut self, history: &[u32], k: usize) -> Vec<DraftDist> {
        self.draft(history, k).into_iter().map(DraftDist::point).collect()
    }

    /// Verification feedback: of `proposed`, the first `accepted`
    /// matched the model, and `verify_argmax` holds the verify pass's
    /// greedy token at every scored position (index `accepted` is the
    /// next pending token; later entries were computed under stale
    /// context). Stateless drafters ignore this.
    fn observe(&mut self, proposed: &[u32], accepted: usize, verify_argmax: &[u32]);

    fn name(&self) -> &'static str;
}

/// Prompt-lookup drafter: proposes the continuation of the most recent
/// earlier occurrence of the sequence's n-gram suffix, preferring the
/// longest match (`max_n` down to `min_n`).
pub struct NgramDrafter {
    /// Longest suffix length tried first.
    pub max_n: usize,
    /// Shortest suffix length tried before giving up.
    pub min_n: usize,
}

impl Default for NgramDrafter {
    fn default() -> Self {
        NgramDrafter { max_n: 4, min_n: 1 }
    }
}

impl Drafter for NgramDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        if k == 0 || history.is_empty() {
            return Vec::new();
        }
        let len = history.len();
        for n in (self.min_n..=self.max_n).rev() {
            if len < n + 1 {
                continue; // need the suffix plus at least one earlier token
            }
            let suffix = &history[len - n..];
            // Most recent earlier occurrence wins (recency tracks the
            // local pattern better than the first occurrence).
            let mut i = len - n;
            while i > 0 {
                i -= 1;
                if &history[i..i + n] == suffix {
                    // Propose what followed it; the span may overlap the
                    // suffix itself (periodic patterns draft themselves).
                    let cont = &history[i + n..(i + n + k).min(len)];
                    if !cont.is_empty() {
                        return cont.to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    fn observe(&mut self, _proposed: &[u32], _accepted: usize, _verify_argmax: &[u32]) {}

    fn name(&self) -> &'static str {
        "ngram"
    }
}

/// Greedy-reuse drafter: replays the previous verify pass's argmax
/// chain beyond the accepted run as the next round's draft.
#[derive(Default)]
pub struct SelfDraft {
    /// Stale-context greedy continuation from the last verify pass.
    buf: Vec<u32>,
}

impl Drafter for SelfDraft {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        if k == 0 {
            return Vec::new();
        }
        if !self.buf.is_empty() {
            let take = self.buf.len().min(k);
            return self.buf[..take].to_vec();
        }
        // Bootstrap: repeat the last token. Trivial, but it costs one
        // verify pass at worst and self-sustains from then on (the pass
        // refills `buf` whatever the acceptance).
        match history.last() {
            Some(&t) => vec![t; k],
            None => Vec::new(),
        }
    }

    fn observe(&mut self, _proposed: &[u32], accepted: usize, verify_argmax: &[u32]) {
        // verify_argmax[accepted] becomes the next pending token; the
        // entries after it are the model's greedy guesses one context
        // slip away — exactly what the next round should try.
        self.buf = verify_argmax.get(accepted + 1..).map(|s| s.to_vec()).unwrap_or_default();
    }

    fn name(&self) -> &'static str {
        "self"
    }
}

/// Which drafter the coordinator builds per speculating sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrafterKind {
    Ngram,
    SelfDraft,
}

impl DrafterKind {
    pub fn parse(s: &str) -> Option<DrafterKind> {
        match s {
            "ngram" => Some(DrafterKind::Ngram),
            "self" => Some(DrafterKind::SelfDraft),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DrafterKind::Ngram => "ngram",
            DrafterKind::SelfDraft => "self",
        }
    }

    /// Fresh drafter state for one sequence (drafters are per-sequence:
    /// their history view and reuse buffers must not leak across
    /// requests).
    pub fn build(&self) -> Box<dyn Drafter> {
        match self {
            DrafterKind::Ngram => Box::new(NgramDrafter::default()),
            DrafterKind::SelfDraft => Box::new(SelfDraft::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_drafts_the_repeating_continuation() {
        let mut d = NgramDrafter::default();
        // history: a b c d a b c d a b -> suffix [a b] last seen at 4,
        // followed by [c d a b ...].
        let h = [10u32, 11, 12, 13, 10, 11, 12, 13, 10, 11];
        assert_eq!(d.draft(&h, 4), vec![12, 13, 10, 11]);
        // k caps the proposal.
        assert_eq!(d.draft(&h, 2), vec![12, 13]);
    }

    #[test]
    fn ngram_prefers_the_longest_and_most_recent_match() {
        let mut d = NgramDrafter::default();
        // Suffix [1 2] occurs at 0 (followed by 3) and at 3 (followed
        // by 9): recency must pick 9.
        let h = [1u32, 2, 3, 1, 2, 9, 1, 2];
        assert_eq!(d.draft(&h, 1), vec![9]);
    }

    #[test]
    fn ngram_gives_up_on_novel_suffixes() {
        let mut d = NgramDrafter::default();
        let h = [1u32, 2, 3, 4, 5, 6, 7, 8];
        assert!(d.draft(&h, 4).is_empty());
        assert!(d.draft(&[], 4).is_empty());
        assert!(d.draft(&h, 0).is_empty());
    }

    #[test]
    fn self_draft_bootstraps_then_reuses_the_verify_chain() {
        let mut d = SelfDraft::default();
        let h = [5u32, 6, 7];
        // Bootstrap: repeat the last token.
        assert_eq!(d.draft(&h, 3), vec![7, 7, 7]);
        // A verify pass (2 of 3 accepted) leaves its stale-context tail.
        d.observe(&[7, 7, 7], 2, &[7, 7, 40, 41]);
        assert_eq!(d.draft(&h, 8), vec![41]);
        // Full acceptance leaves nothing to reuse -> bootstrap again.
        d.observe(&[41], 1, &[41, 50]);
        assert_eq!(d.draft(&h, 2), vec![7, 7]);
    }

    #[test]
    fn default_draft_dist_is_a_point_mass_on_the_greedy_draft() {
        let mut d = NgramDrafter::default();
        let h = [10u32, 11, 12, 13, 10, 11, 12, 13, 10, 11];
        let toks = d.draft(&h, 4);
        let dists = d.draft_dist(&h, 4);
        assert_eq!(dists.len(), toks.len());
        for (dd, &t) in dists.iter().zip(&toks) {
            assert_eq!(dd.token, t);
            assert!(dd.is_point());
            assert_eq!(dd.prob_of(t), 1.0);
            assert_eq!(dd.prob_of(t.wrapping_add(1)), 0.0);
        }
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(DrafterKind::parse("ngram"), Some(DrafterKind::Ngram));
        assert_eq!(DrafterKind::parse("self"), Some(DrafterKind::SelfDraft));
        assert_eq!(DrafterKind::parse("medusa"), None);
        assert_eq!(DrafterKind::Ngram.build().name(), "ngram");
        assert_eq!(DrafterKind::SelfDraft.build().name(), "self");
    }
}
