//! Repack ITQ3_S block bytes into the flat plane arrays the AOT-lowered
//! JAX graph consumes (the L3 side of the L1 kernel's input contract —
//! see `python/compile/kernels/ref.py` for the layout spec).
//!
//! Per 256-element block the Rust encoder emits
//! `[base 64B][sel 32B][d f16][z f16]`; the HLO inputs want, per linear,
//! `codes u32[rows, nb*16]`, `sel u32[rows, nb*8]`, `d f32[rows, nb]`,
//! `z f32[rows, nb]` (little-endian words, so the byte planes reinterpret
//! directly as u32).

use crate::quant::QuantizedMatrix;
use anyhow::{bail, Result};

/// Flat plane arrays for one packed matrix.
pub struct Planes {
    pub rows: usize,
    pub nb: usize,
    pub codes: Vec<u32>,
    pub sel: Vec<u32>,
    pub d: Vec<f32>,
    pub z: Vec<f32>,
}

pub fn to_planes(m: &QuantizedMatrix) -> Result<Planes> {
    if m.fmt.name() != "itq3_s" || m.fmt.block_elems() != 256 {
        bail!(
            "PJRT artifact expects itq3_s@256 packing, model is {}@{}",
            m.fmt.name(),
            m.fmt.block_elems()
        );
    }
    let bb = m.fmt.block_bytes(); // 100
    let nb = m.blocks_per_row();
    let rows = m.rows;
    let mut codes = Vec::with_capacity(rows * nb * 16);
    let mut sel = Vec::with_capacity(rows * nb * 8);
    let mut d = Vec::with_capacity(rows * nb);
    let mut z = Vec::with_capacity(rows * nb);
    for r in 0..rows {
        for b in 0..nb {
            let bytes = &m.data[(r * nb + b) * bb..(r * nb + b + 1) * bb];
            for w in bytes[..64].chunks_exact(4) {
                codes.push(u32::from_le_bytes(w.try_into().unwrap()));
            }
            for w in bytes[64..96].chunks_exact(4) {
                sel.push(u32::from_le_bytes(w.try_into().unwrap()));
            }
            d.push(crate::quant::packing::read_f16(bytes, 96));
            z.push(crate::quant::packing::read_f16(bytes, 98));
        }
    }
    Ok(Planes { rows, nb, codes, sel, d, z })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name;
    use crate::tensor::Tensor;
    use crate::util::XorShift;

    #[test]
    fn planes_decode_consistently() {
        // Decoding via the plane layout must equal the byte-level decoder.
        let mut rng = XorShift::new(1);
        let w = Tensor::randn(vec![4, 512], 0.05, &mut rng);
        let q = QuantizedMatrix::quantize(format_by_name("itq3_s").unwrap(), &w);
        let p = to_planes(&q).unwrap();
        assert_eq!(p.codes.len(), 4 * 2 * 16);
        let full = q.dequantize();
        // Manual decode of row 2, block 1 from planes + ifwht.
        let (r, b) = (2usize, 1usize);
        let mut vals = [0.0f32; 256];
        for t in 0..256 {
            let word = p.codes[(r * 2 + b) * 16 + t / 16];
            let code = (word >> (2 * (t % 16))) & 3;
            let sword = p.sel[(r * 2 + b) * 8 + t / 32];
            let sbit = (sword >> (t % 32)) & 1;
            let dd = p.d[r * 2 + b];
            let zz = p.z[r * 2 + b];
            let digit = code as f32 - 1.0;
            vals[t] = digit * dd * (1.0 + 2.0 * sbit as f32) + zz;
        }
        crate::fwht::fwht_256(&mut vals);
        for (i, &v) in vals.iter().enumerate() {
            let want = full.row(r)[b * 256 + i];
            assert!((v - want).abs() < 1e-5, "t={i}: {v} vs {want}");
        }
    }

    #[test]
    fn rejects_non_itq3s() {
        let mut rng = XorShift::new(2);
        let w = Tensor::randn(vec![2, 256], 0.05, &mut rng);
        let q = QuantizedMatrix::quantize(format_by_name("q8_0").unwrap(), &w);
        assert!(to_planes(&q).is_err());
    }
}
