//! PJRT runtime: load + execute the AOT-compiled JAX artifacts.
//!
//! The L2 model is lowered once at build time (`python/compile/aot.py`)
//! to HLO **text** (serialized protos from jax ≥ 0.5 are rejected by the
//! image's xla_extension 0.5.1). This module compiles the text on the
//! PJRT CPU client, uploads the model weights to device buffers **once**
//! (`execute_b` reuses them every call), and exposes the result behind
//! the same [`Engine`] trait as the native backend.
//!
//! The lowered graph scores a fixed-length window (`manifest.seq`,
//! default 128): `score(tokens[S], *weights) -> logits[S, vocab]`.
//! Prefill slices the rows it needs; decode re-scores the growing
//! sequence (the recompute strategy — KV state lives in the graph-free
//! native engine; see DESIGN.md §2). Python is never on this path.

pub mod pack;

use crate::gguf;
use crate::model::native::Engine;
use crate::model::{KvStore, ModelConfig};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

pub struct PjrtEngine {
    cfg: ModelConfig,
    seq: usize,
    vocab: usize,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    /// Device-resident weight buffers in manifest order (after `tokens`).
    weights: Vec<xla::PjRtBuffer>,
}

// SAFETY: the PJRT CPU client is internally synchronized for the
// single-owner usage here — the engine is moved into the coordinator's
// single worker thread and never aliased across threads (the coordinator
// owns it behind a Box; no concurrent access in this codebase).
unsafe impl Send for PjrtEngine {}
// SAFETY: all &self entry points funnel into PJRT Execute; we never
// share one PjrtEngine across threads (single worker ownership).
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load `model.iguf` (dense → fp32 artifact; itq3_s-quantized →
    /// fused-kernel artifact) against the artifacts directory produced by
    /// `make artifacts`.
    pub fn load(model: &Path, artifacts: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(artifacts.join("manifest.json"))
            .context("read manifest.json (run `make artifacts`)")?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let seq = manifest.get("seq").and_then(|j| j.as_u64()).context("manifest.seq")? as usize;

        // Peek at the checkpoint kind to pick the artifact.
        let f = gguf::IgufFile::load(model)?;
        let kind = f.meta.get("kind").and_then(|j| j.as_str()).unwrap_or("dense").to_string();
        drop(f);

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e:?}"))?;

        let (hlo_name, cfg, weights) = match kind.as_str() {
            "dense" => {
                let m = gguf::load_dense(model)?;
                let mut bufs: Vec<xla::PjRtBuffer> = Vec::new();
                let mut push = |data: &[f32], dims: &[usize]| -> Result<()> {
                    bufs.push(
                        client
                            .buffer_from_host_buffer(data, dims, None)
                            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))?,
                    );
                    Ok(())
                };
                push(m.embed.data(), &[m.cfg.vocab, m.cfg.dim])?;
                push(&m.final_norm, &[m.cfg.dim])?;
                for l in &m.layers {
                    push(&l.attn_norm, &[m.cfg.dim])?;
                    for t in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w3, &l.w2] {
                        push(t.data(), &[t.rows(), t.cols()])?;
                    }
                    push(&l.ffn_norm, &[m.cfg.dim])?;
                }
                ("model_fp32.hlo.txt", m.cfg.clone(), bufs)
            }
            "quantized" => {
                let m = gguf::load_quantized(model)?;
                if m.fmt_name != "itq3_s" {
                    bail!("PJRT artifact supports itq3_s; model is {}", m.fmt_name);
                }
                let mut bufs: Vec<xla::PjRtBuffer> = Vec::new();
                let up_f32 = |client: &xla::PjRtClient, d: &[f32], dims: &[usize]| {
                    client
                        .buffer_from_host_buffer(d, dims, None)
                        .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
                };
                let up_u32 = |client: &xla::PjRtClient, d: &[u32], dims: &[usize]| {
                    client
                        .buffer_from_host_buffer(d, dims, None)
                        .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
                };
                bufs.push(up_f32(&client, m.embed.data(), &[m.cfg.vocab, m.cfg.dim])?);
                bufs.push(up_f32(&client, &m.final_norm, &[m.cfg.dim])?);
                for l in &m.layers {
                    bufs.push(up_f32(&client, &l.attn_norm, &[m.cfg.dim])?);
                    for pl in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w3, &l.w2] {
                        let p = pack::to_planes(&pl.lin.w)?;
                        bufs.push(up_u32(&client, &p.codes, &[p.rows, p.nb * 16])?);
                        bufs.push(up_u32(&client, &p.sel, &[p.rows, p.nb * 8])?);
                        bufs.push(up_f32(&client, &p.d, &[p.rows, p.nb])?);
                        bufs.push(up_f32(&client, &p.z, &[p.rows, p.nb])?);
                    }
                    bufs.push(up_f32(&client, &l.ffn_norm, &[m.cfg.dim])?);
                }
                ("model_itq3s.hlo.txt", m.cfg.clone(), bufs)
            }
            other => bail!("unknown checkpoint kind '{other}'"),
        };

        let hlo_path = artifacts.join(hlo_name);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("path utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("compile: {e:?}"))?;

        // The PJRT window bounds the effective context length.
        let mut cfg = cfg;
        let vocab = cfg.vocab;
        cfg.max_seq = cfg.max_seq.min(seq);
        Ok(PjrtEngine { cfg, seq, vocab, exe, client, weights })
    }

    /// Score a full window: returns `(seq, vocab)` logits.
    fn score(&self, tokens: &[u32]) -> Result<Tensor> {
        assert!(tokens.len() <= self.seq);
        let mut padded = vec![0i32; self.seq];
        for (p, &t) in padded.iter_mut().zip(tokens) {
            *p = t as i32;
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&padded, &[self.seq], None)
            .map_err(|e| anyhow::anyhow!("tokens upload: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&tok_buf);
        args.extend(self.weights.iter());
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        Ok(Tensor::new(vec![self.seq, self.vocab], data))
    }
}

impl Engine for PjrtEngine {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn decode_step(&self, cache: &mut dyn KvStore, token: u32) -> Vec<f32> {
        cache.push_token(token);
        let n = cache.len();
        assert!(n <= self.seq, "PJRT window ({}) exceeded", self.seq);
        let logits = self.score(cache.tokens()).expect("pjrt score");
        logits.row(n - 1).to_vec()
    }

    // `Engine::decode_batch` is deliberately NOT overridden: the AOT
    // graph scores one fixed-length window per execute (batch dim 1),
    // so a decode round can only ever be one independent re-score per
    // sequence — exactly the trait's default sequential fallback, which
    // is trivially bit-identical to per-sequence `decode_step`.
    //
    // `Engine::score_tokens` (the speculative verify pass) keeps its
    // default for the same reason: the recompute engine re-scores the
    // whole window per decode step anyway, so the sequential fallback
    // is already one execute per fed token and trivially matches
    // `decode_step`. Speculation — greedy or sampled — still *works*
    // against this engine (the acceptance loop only needs per-position
    // logits, and rollback only touches the token history here); it
    // just cannot amortize the passes.

    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u32]) -> Tensor {
        let start = cache.len();
        for &t in tokens {
            cache.push_token(t);
        }
        let n = cache.len();
        assert!(n <= self.seq, "PJRT window ({}) exceeded", self.seq);
        let logits = self.score(cache.tokens()).expect("pjrt score");
        let mut out = Tensor::zeros(vec![tokens.len(), self.vocab]);
        for (i, r) in (start..n).enumerate() {
            out.row_mut(i).copy_from_slice(logits.row(r));
        }
        out
    }
}
