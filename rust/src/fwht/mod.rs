//! Fast Walsh-Hadamard Transform (FWHT).
//!
//! This is the rotation at the heart of ITQ3_S (paper §2.3, §3): the
//! normalized WHT `H_n` is involutory (`H_n H_n = I`) and an isometry, so
//! the same routine serves as forward rotation (offline quantization,
//! Alg 1) and inverse rotation (online dequantization, Alg 2 /
//! `ifwht_256` in Listing 2). Block sizes are powers of two in
//! `32..=512` — the ablation range of Table 3.
//!
//! Three implementations are provided:
//! - [`fwht_inplace`]: textbook radix-2 butterflies, any power-of-two `n`
//!   (the reference; mirrors the CUDA kernel stage-for-stage).
//! - [`fwht_256`]: the hot-path 256-point transform used by the serving
//!   dequantization loop, with radix-4 stages for fewer passes over the
//!   block (see EXPERIMENTS.md §Perf for the measured speedup).
//! - [`WalshMatrix`]: explicit dense `H_n` for oracle tests.

mod radix;

pub use radix::fwht_256;

/// Largest supported block size (ablation upper bound, Table 3).
pub const MAX_BLOCK: usize = 512;

/// In-place normalized FWHT of a power-of-two-length slice.
///
/// Applies `log2(n)` butterfly stages `(u, v) -> (u + v, u - v)` then a
/// single `1/sqrt(n)` normalization pass, matching the paper's Eq. (2)-(4)
/// and the normalization convention of Listing 2 (`0.0625` for n = 256).
///
/// Panics if `v.len()` is not a power of two.
pub fn fwht_inplace(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut step = 1;
    while step < n {
        let stride = step * 2;
        for block in (0..n).step_by(stride) {
            for j in block..block + step {
                let a = v[j];
                let b = v[j + step];
                v[j] = a + b;
                v[j + step] = a - b;
            }
        }
        step = stride;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for x in v.iter_mut() {
        *x *= norm;
    }
}

/// Inverse FWHT. `H_n` is involutory under the normalized convention, so
/// this is literally the forward transform — kept as a named alias so call
/// sites read like the paper (`ifwht` in Alg 2).
#[inline]
pub fn ifwht_inplace(v: &mut [f32]) {
    fwht_inplace(v);
}

/// Unnormalized FWHT (no `1/sqrt(n)` pass). Useful to fuse the
/// normalization into a subsequent scale multiply: `H_n = unnorm / sqrt(n)`,
/// so dequantization can fold `d_k / sqrt(n)` into one constant.
pub fn fwht_unnormalized(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut step = 1;
    while step < n {
        let stride = step * 2;
        for block in (0..n).step_by(stride) {
            for j in block..block + step {
                let a = v[j];
                let b = v[j + step];
                v[j] = a + b;
                v[j + step] = a - b;
            }
        }
        step = stride;
    }
}

/// Apply the FWHT independently to each contiguous `block` of `v`.
/// `v.len()` must be a multiple of `block`. This is the whole-tensor
/// rotation of Alg 1 step 2 (per-256-block in the paper; `block` is the
/// Table 3 ablation knob).
pub fn fwht_blocked(v: &mut [f32], block: usize) {
    assert!(block.is_power_of_two(), "block must be a power of two");
    assert_eq!(v.len() % block, 0, "length {} not a multiple of block {}", v.len(), block);
    if block == 256 {
        for chunk in v.chunks_exact_mut(256) {
            fwht_256(chunk.try_into().unwrap());
        }
    } else {
        for chunk in v.chunks_exact_mut(block) {
            fwht_inplace(chunk);
        }
    }
}

/// Dense Walsh-Hadamard matrix `H_n` (normalized), for oracle testing and
/// for the `H_16 ⊗ H_16` MXU decomposition analysis (DESIGN.md §5).
pub struct WalshMatrix {
    pub n: usize,
    /// Row-major `n x n` entries, each `±1/sqrt(n)`.
    pub data: Vec<f32>,
}

impl WalshMatrix {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two());
        let norm = 1.0 / (n as f32).sqrt();
        let mut data = vec![0.0f32; n * n];
        for (i, row) in data.chunks_exact_mut(n).enumerate() {
            for (j, x) in row.iter_mut().enumerate() {
                // H[i][j] = (-1)^{popcount(i & j)} / sqrt(n)  (natural order)
                *x = if (i & j).count_ones() % 2 == 0 { norm } else { -norm };
            }
        }
        WalshMatrix { n, data }
    }

    /// y = H x (dense, O(n^2); oracle only).
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0f32; self.n];
        for (i, yi) in y.iter_mut().enumerate() {
            let row = &self.data[i * self.n..(i + 1) * self.n];
            *yi = row.iter().zip(x).map(|(&h, &v)| h * v).sum();
        }
        y
    }
}

/// FLOP count of one blocked FWHT application over `len` elements: each
/// block does `n log2 n` add/subs plus `n` multiplies. Used by the
/// overhead model for Table 3.
pub fn fwht_flops(len: usize, block: usize) -> u64 {
    let blocks = (len / block) as u64;
    let n = block as u64;
    blocks * (n * (block as f64).log2() as u64 + n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::stats;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_walsh_matrix_all_sizes() {
        for k in 1..=9 {
            let n = 1 << k;
            let m = WalshMatrix::new(n);
            let mut rng = crate::util::XorShift::new(n as u64);
            let x: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let oracle = m.apply(&x);
            let mut fast = x.clone();
            fwht_inplace(&mut fast);
            assert_close(&fast, &oracle, 1e-4);
        }
    }

    #[test]
    fn hadamard_4_known_values() {
        // H_4 * [1,0,0,0] = [1,1,1,1]/2
        let mut v = [1.0f32, 0.0, 0.0, 0.0];
        fwht_inplace(&mut v);
        assert_close(&v, &[0.5, 0.5, 0.5, 0.5], 1e-7);
        // H_2 * [a,b] = [(a+b), (a-b)]/sqrt(2)
        let mut w = [3.0f32, 1.0];
        fwht_inplace(&mut w);
        let s = 2.0f32.sqrt();
        assert_close(&w, &[4.0 / s, 2.0 / s], 1e-6);
    }

    #[test]
    fn involution_identity() {
        // H(H(x)) == x — Prop 1's round-trip exactness, pre-quantization.
        let mut rng = crate::util::XorShift::new(1);
        for &n in &[32usize, 64, 128, 256, 512] {
            let x: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
            let mut y = x.clone();
            fwht_inplace(&mut y);
            ifwht_inplace(&mut y);
            assert_close(&y, &x, 1e-4);
        }
    }

    #[test]
    fn isometry() {
        // ||Hx||_2 == ||x||_2 — the property Theorem 2's proof leans on.
        forall("fwht is an isometry", 100, |g| {
            let k = g.usize_in(5, 9);
            let x = g.vec_f32(1 << k, -3.0, 3.0);
            let mut y = x.clone();
            fwht_inplace(&mut y);
            let nx = stats::l2(&x);
            let ny = stats::l2(&y);
            assert!((nx - ny).abs() <= 1e-3 * nx.max(1.0), "{nx} vs {ny}");
        });
    }

    #[test]
    fn unnormalized_scales_by_sqrt_n() {
        let mut rng = crate::util::XorShift::new(2);
        let x: Vec<f32> = (0..64).map(|_| rng.next_f32()).collect();
        let mut a = x.clone();
        let mut b = x.clone();
        fwht_inplace(&mut a);
        fwht_unnormalized(&mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u * 8.0 - v).abs() < 1e-4);
        }
    }

    #[test]
    fn fwht_256_matches_reference() {
        let mut rng = crate::util::XorShift::new(3);
        for _ in 0..20 {
            let x: Vec<f32> = (0..256).map(|_| (rng.next_gaussian() as f32) * 0.3).collect();
            let mut a: [f32; 256] = x.clone().try_into().unwrap();
            let mut b = x.clone();
            fwht_256(&mut a);
            fwht_inplace(&mut b);
            assert_close(&a, &b, 1e-4);
        }
    }

    #[test]
    fn blocked_is_per_block() {
        let mut rng = crate::util::XorShift::new(4);
        let x: Vec<f32> = (0..1024).map(|_| rng.next_f32() - 0.5).collect();
        let mut whole = x.clone();
        fwht_blocked(&mut whole, 256);
        for (bi, chunk) in x.chunks_exact(256).enumerate() {
            let mut c = chunk.to_vec();
            fwht_inplace(&mut c);
            assert_close(&c, &whole[bi * 256..(bi + 1) * 256], 1e-5);
        }
    }

    #[test]
    fn outlier_energy_spreads() {
        // Corollary 1: a single outlier M contributes M/sqrt(n) per
        // coefficient after rotation.
        let n = 256;
        let mut v = vec![0.0f32; n];
        v[17] = 16.0; // M = 16, so each |coeff| must be 16/16 = 1
        fwht_inplace(&mut v);
        for &c in &v {
            assert!((c.abs() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gaussianizes_heavy_tails() {
        // Theorem 1 reproduction: rotated heavy-tailed blocks have
        // kurtosis near 3 and much smaller than the input's.
        let mut rng = crate::util::XorShift::new(7);
        let n = 256;
        let mut input_kurt = 0.0;
        let mut rot_kurt = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let mut v: Vec<f32> = (0..n).map(|_| rng.next_student_t(4.0) as f32).collect();
            input_kurt += stats::kurtosis(&v);
            fwht_inplace(&mut v);
            rot_kurt += stats::kurtosis(&v);
        }
        input_kurt /= trials as f64;
        rot_kurt /= trials as f64;
        assert!(input_kurt > 4.5, "t(4) should be heavy-tailed: {input_kurt}");
        assert!(rot_kurt < 3.6, "rotated kurtosis should be near 3: {rot_kurt}");
        assert!(rot_kurt < input_kurt * 0.8);
    }

    #[test]
    fn linf_reduction_on_outlier_blocks() {
        // Cor 1's practical claim: E[linf] after rotation ~ sigma*sqrt(log n),
        // far below the raw outlier magnitude.
        let mut rng = crate::util::XorShift::new(8);
        let n = 256;
        let mut reduced = 0usize;
        let trials = 100;
        for _ in 0..trials {
            let mut v: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
            // Plant outliers at 20x sigma.
            v[3] = 0.4;
            v[100] = -0.4;
            let before = stats::linf(&v);
            fwht_inplace(&mut v);
            let after = stats::linf(&v);
            if after < before * 0.5 {
                reduced += 1;
            }
        }
        assert!(reduced > 90, "linf halved in only {reduced}/{trials} trials");
    }

    #[test]
    fn flops_model() {
        assert_eq!(fwht_flops(256, 256), 256 * 8 + 256);
        assert_eq!(fwht_flops(512, 256), 2 * (256 * 8 + 256));
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        let mut v = vec![0.0f32; 100];
        fwht_inplace(&mut v);
    }
}
