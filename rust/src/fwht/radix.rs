//! Optimized 256-point FWHT for the serving hot path.
//!
//! The dequantization loop of the Rust fallback/native path applies one
//! 256-point inverse FWHT per weight block (Alg 2). The generic
//! [`super::fwht_inplace`] makes 8 passes over the block (one per
//! butterfly stage). This variant fuses pairs of stages into radix-4
//! passes — 4 passes total — which roughly halves memory traffic per
//! block and lets the compiler keep the 4-point kernel in registers.
//! (The CUDA analog keeps the whole block in shared memory; on CPU the
//! win is cache/loop-overhead, not synchronization.)
//!
//! Equivalence with the reference is covered by
//! `fwht::tests::fwht_256_matches_reference` and the property tests.

/// Normalized 256-point FWHT, radix-4 stages, in place.
pub fn fwht_256(v: &mut [f32; 256]) {
    // Stages (step=1,2), (4,8), (16,32), (64,128) fused as radix-4 passes.
    // One radix-4 pass with quarter-stride s combines elements
    // {i, i+s, i+2s, i+3s} as the 4-point Hadamard:
    //   y0 = a+b+c+d, y1 = a-b+c-d, y2 = a+b-c-d, y3 = a-b-c+d
    let mut s = 1usize;
    while s < 256 {
        let stride = s * 4;
        let mut base = 0usize;
        while base < 256 {
            for i in base..base + s {
                let a = v[i];
                let b = v[i + s];
                let c = v[i + 2 * s];
                let d = v[i + 3 * s];
                let apb = a + b;
                let amb = a - b;
                let cpd = c + d;
                let cmd = c - d;
                v[i] = apb + cpd;
                v[i + s] = amb + cmd;
                v[i + 2 * s] = apb - cpd;
                v[i + 3 * s] = amb - cmd;
            }
            base += stride;
        }
        s = stride;
    }
    // 1/sqrt(256) = 0.0625 — the paper's Listing 2 normalization constant.
    for x in v.iter_mut() {
        *x *= 0.0625;
    }
}

/// Unnormalized 256-point FWHT (for fusing the 0.0625 into a scale).
pub fn fwht_256_unnorm(v: &mut [f32; 256]) {
    let mut s = 1usize;
    while s < 256 {
        let stride = s * 4;
        let mut base = 0usize;
        while base < 256 {
            for i in base..base + s {
                let a = v[i];
                let b = v[i + s];
                let c = v[i + 2 * s];
                let d = v[i + 3 * s];
                let apb = a + b;
                let amb = a - b;
                let cpd = c + d;
                let cmd = c - d;
                v[i] = apb + cpd;
                v[i + s] = amb + cmd;
                v[i + 2 * s] = apb - cpd;
                v[i + 3 * s] = amb - cmd;
            }
            base += stride;
        }
        s = stride;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radix4_covers_all_stages() {
        // 256 = 4^4, so exactly four radix-4 passes and no radix-2
        // remainder; verify on the impulse response (all-equal output).
        let mut v = [0.0f32; 256];
        v[0] = 16.0;
        fwht_256(&mut v);
        for &x in &v {
            assert!((x - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn unnorm_matches_norm_times_16() {
        let mut a = [0.0f32; 256];
        let mut b = [0.0f32; 256];
        for i in 0..256 {
            a[i] = (i as f32).sin();
            b[i] = a[i];
        }
        fwht_256(&mut a);
        fwht_256_unnorm(&mut b);
        for i in 0..256 {
            assert!((a[i] * 16.0 - b[i]).abs() < 1e-3);
        }
    }
}
