//! Minimal host-side tensor type.
//!
//! The coordinator needs a small amount of host linear algebra: staging
//! weights for quantization, the native-Rust fallback forward pass (used
//! when PJRT artifacts are absent, e.g. in unit tests), and marshalling
//! literals in and out of the XLA runtime. This is a deliberately simple
//! row-major f32 tensor — not a general ndarray.

use std::fmt;

/// Row-major f32 tensor with up to 4 dimensions.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Create from shape and data; panics if sizes disagree.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Filled with i.i.d. N(0, sigma^2) entries.
    pub fn randn(shape: Vec<usize>, sigma: f32, rng: &mut crate::util::XorShift) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    /// Number of columns for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    /// Borrow row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape to {shape:?}");
        self.shape = shape;
        self
    }

    /// Dense matmul: (m,k) x (k,n) -> (m,n). Reference implementation for
    /// the native fallback path; the serving hot path uses the blocked
    /// kernels in `quant::matmul`.
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
        let mut out = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (p, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &rhs.data[p * n..(p + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transpose a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(vec![n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for x in &mut self.data {
            *x = f(*x);
        }
        self
    }
}

/// y += W x for row-major `W: (out, inp)`, the matvec orientation used by
/// the decode (B=1) path.
pub fn matvec_accum(w: &Tensor, x: &[f32], y: &mut [f32]) {
    let (out_dim, in_dim) = (w.rows(), w.cols());
    assert_eq!(x.len(), in_dim);
    assert_eq!(y.len(), out_dim);
    for (o, yo) in y.iter_mut().enumerate() {
        let row = w.row(o);
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(x) {
            acc += a * b;
        }
        *yo += acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = crate::util::XorShift::new(5);
        let a = Tensor::randn(vec![4, 4], 1.0, &mut rng);
        let mut eye = Tensor::zeros(vec![4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        let b = a.matmul(&eye);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = crate::util::XorShift::new(6);
        let a = Tensor::randn(vec![3, 5], 1.0, &mut rng);
        let b = a.transpose().transpose();
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = crate::util::XorShift::new(7);
        let w = Tensor::randn(vec![6, 4], 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32 + 0.5).collect();
        let mut y = vec![0.0f32; 6];
        matvec_accum(&w, &x, &mut y);
        let xm = Tensor::new(vec![4, 1], x.clone());
        let ym = w.matmul(&xm);
        for (a, b) in y.iter().zip(ym.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }
}
