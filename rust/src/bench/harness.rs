//! Minimal timing harness (criterion stand-in).

use std::time::Instant;

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

/// Time `f` with warmup; `iters` timed runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Render a fixed-width table row.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    let mut s = String::new();
    for (c, w) in cells.iter().zip(widths) {
        s.push_str(&format!("{c:<w$}  ", w = w));
    }
    s.trim_end().to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_work() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
    }

    #[test]
    fn per_sec_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert_eq!(r.per_sec(10.0), 20.0);
    }
}
