//! Benchmark infrastructure.
//!
//! `criterion` is not in the offline vendor set, so `harness` provides a
//! small timing core (warmup + N timed iterations + stats) that the
//! `rust/benches/*` targets (`harness = false`) and the `tables` drivers
//! share.

pub mod harness;
pub mod tables;
