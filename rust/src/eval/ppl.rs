//! Perplexity evaluation (Table 1 / Table 3 metric).
//!
//! Standard sliding-window PPL: the text is tokenized, split into
//! `max_seq`-sized chunks (each prefixed with BOS), and the model scores
//! every next-token prediction. `PPL = exp(mean NLL)`.

use crate::model::native::Engine;
use crate::model::{tokenizer, KvCache};

/// Scoring window. Matches the AOT artifact window (manifest.seq = 128)
/// and stays within the context length the tiny model was trained on —
/// RoPE positions beyond the training window are out-of-distribution and
/// would inflate PPL for engines with longer `max_seq`, making
/// cross-engine numbers incomparable.
pub const EVAL_WINDOW: usize = 128;

/// Result of a perplexity run.
#[derive(Clone, Debug)]
pub struct PplReport {
    pub ppl: f64,
    pub nll: f64,
    pub tokens: usize,
}

/// log-softmax value of `logits[target]`.
fn log_prob(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = m + logits.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln();
    logits[target] as f64 - lse
}

/// Compute perplexity of `text` under `engine`.
pub fn perplexity(engine: &dyn Engine, text: &str) -> PplReport {
    let cfg = engine.config().clone();
    let ids = tokenizer::encode_raw(text);
    let chunk = cfg.max_seq.min(EVAL_WINDOW) - 1;
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for window in ids.chunks(chunk) {
        if window.len() < 2 {
            continue;
        }
        // BOS + window; predictions for window[i] come from position i.
        let mut toks = Vec::with_capacity(window.len() + 1);
        toks.push(tokenizer::BOS);
        toks.extend_from_slice(window);
        let mut cache = KvCache::new(&cfg);
        let logits = engine.prefill(&mut cache, &toks);
        for i in 0..window.len() {
            nll -= log_prob(logits.row(i), window[i] as usize);
            count += 1;
        }
    }
    let mean_nll = if count > 0 { nll / count as f64 } else { f64::NAN };
    PplReport { ppl: mean_nll.exp(), nll: mean_nll, tokens: count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseModel, ModelConfig, NativeEngine};

    #[test]
    fn log_prob_is_log_softmax() {
        let logits = vec![0.0f32, 1.0, 2.0];
        let p: f64 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((p - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        // An untrained model must score near uniform: PPL ≈ vocab size.
        let cfg = ModelConfig::test();
        let eng = NativeEngine::dense(DenseModel::random(&cfg, 9, None));
        let text = "abcd efgh ijkl mnop qrst";
        let r = perplexity(&eng, text);
        assert!(r.tokens > 0);
        assert!(
            (cfg.vocab as f64 * 0.3..cfg.vocab as f64 * 3.0).contains(&r.ppl),
            "ppl={}",
            r.ppl
        );
    }

    #[test]
    fn ppl_deterministic() {
        let cfg = ModelConfig::test();
        let eng = NativeEngine::dense(DenseModel::random(&cfg, 10, None));
        let a = perplexity(&eng, "the quick brown fox").ppl;
        let b = perplexity(&eng, "the quick brown fox").ppl;
        assert_eq!(a, b);
    }

    #[test]
    fn longer_text_spans_chunks() {
        let cfg = ModelConfig::test(); // max_seq 64
        let eng = NativeEngine::dense(DenseModel::random(&cfg, 11, None));
        let text = "x".repeat(200);
        let r = perplexity(&eng, &text);
        assert_eq!(r.tokens, 200);
        assert!(r.ppl.is_finite());
    }
}
