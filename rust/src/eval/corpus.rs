//! Deterministic synthetic corpus generator (WikiText-2 / C4 stand-in).
//!
//! Sentences are drawn from a small templated grammar with enough
//! structure (agreement between templates, recurring entities, numeric
//! patterns) that a ~6M-parameter byte LM trains to a low perplexity —
//! and therefore *degrades measurably* when its weights are quantized,
//! which is what Table 1 needs. Two styles:
//!
//! - [`Style::Wiki`]: encyclopedic sentences (train + valid splits).
//! - [`Style::Web`]: the "C4-like" distribution-shifted split — chattier
//!   templates, partially overlapping vocabulary.

use crate::util::XorShift;

const NAMES: &[&str] = &[
    "aster", "bryn", "corin", "dara", "evin", "farrow", "galen", "hollis", "iris",
    "jorin", "kara", "lorin", "merek", "nessa", "orin", "petra", "quill", "rowan",
    "sable", "tamsin",
];

const PLACES: &[&str] = &[
    "the northern valley", "the old harbor", "the glass city", "the salt flats",
    "the cedar forest", "the river delta", "the high plateau", "the iron hills",
    "the quiet archive", "the stone bridge",
];

const NOUNS: &[&str] = &[
    "archive", "bridge", "canal", "dialect", "engine", "festival", "granary",
    "harvest", "instrument", "journal", "kiln", "ledger", "market", "northroad",
    "observatory", "press", "quarry", "reservoir", "senate", "tower",
];

const ADJS: &[&str] = &[
    "ancient", "broad", "careful", "distant", "early", "formal", "gradual",
    "hollow", "inner", "joint", "known", "late",
];

const VERBS: &[&str] = &[
    "described", "founded", "mapped", "measured", "rebuilt", "recorded",
    "restored", "studied", "surveyed", "translated",
];

const WEB_OPENERS: &[&str] = &[
    "honestly,", "quick update:", "note to self:", "for what it is worth,",
    "as promised,", "in short,",
];

const WEB_VERBS: &[&str] =
    &["posted", "shared", "reviewed", "shipped", "tested", "fixed", "packed"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    Wiki,
    Web,
}

/// Deterministic corpus generator.
pub struct CorpusGen {
    rng: XorShift,
    style: Style,
}

impl CorpusGen {
    pub fn new(seed: u64, style: Style) -> Self {
        CorpusGen { rng: XorShift::new(seed), style }
    }

    fn pick<'a>(&mut self, items: &'a [&'a str]) -> &'a str {
        items[self.rng.next_below(items.len() as u64) as usize]
    }

    /// One sentence, terminated by a space.
    pub fn sentence(&mut self) -> String {
        match self.style {
            Style::Wiki => self.wiki_sentence(),
            Style::Web => self.web_sentence(),
        }
    }

    fn wiki_sentence(&mut self) -> String {
        let t = self.rng.next_below(5);
        match t {
            0 => format!(
                "{} {} the {} {} in {}. ",
                self.pick(NAMES),
                self.pick(VERBS),
                self.pick(ADJS),
                self.pick(NOUNS),
                self.pick(PLACES)
            ),
            1 => format!(
                "the {} of {} was {} by {}. ",
                self.pick(NOUNS),
                self.pick(PLACES),
                self.pick(VERBS),
                self.pick(NAMES)
            ),
            2 => format!(
                "in the year {}, the {} {} held {} {}s. ",
                700 + self.rng.next_below(300),
                self.pick(ADJS),
                self.pick(NOUNS),
                2 + self.rng.next_below(9),
                self.pick(NOUNS)
            ),
            3 => format!(
                "{} and {} {} the {} together. ",
                self.pick(NAMES),
                self.pick(NAMES),
                self.pick(VERBS),
                self.pick(NOUNS)
            ),
            _ => format!(
                "the {} {} is {} miles from {}. ",
                self.pick(ADJS),
                self.pick(NOUNS),
                1 + self.rng.next_below(40),
                self.pick(PLACES)
            ),
        }
    }

    fn web_sentence(&mut self) -> String {
        let t = self.rng.next_below(3);
        match t {
            0 => format!(
                "{} {} {} the {} today. ",
                self.pick(WEB_OPENERS),
                self.pick(NAMES),
                self.pick(WEB_VERBS),
                self.pick(NOUNS)
            ),
            1 => format!(
                "{} the {} looks {} now. ",
                self.pick(WEB_OPENERS),
                self.pick(NOUNS),
                self.pick(ADJS)
            ),
            _ => format!(
                "{} {} it in {} minutes. ",
                self.pick(NAMES),
                self.pick(WEB_VERBS),
                1 + self.rng.next_below(59)
            ),
        }
    }

    /// Generate at least `nbytes` of text.
    pub fn text(&mut self, nbytes: usize) -> String {
        let mut out = String::with_capacity(nbytes + 80);
        while out.len() < nbytes {
            out.push_str(&self.sentence());
        }
        out
    }
}

/// The canonical splits used by training (python) and evaluation (rust).
/// Seeds are fixed constants shared with `python/compile/train.py`.
pub fn standard_splits(nbytes: usize) -> (String, String, String) {
    let train = CorpusGen::new(0x7261_494E, Style::Wiki).text(nbytes);
    let valid = CorpusGen::new(0x7661_4C49, Style::Wiki).text(nbytes / 8);
    let web = CorpusGen::new(0x7765_4221, Style::Web).text(nbytes / 8);
    (train, valid, web)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = CorpusGen::new(1, Style::Wiki).text(1000);
        let b = CorpusGen::new(1, Style::Wiki).text(1000);
        assert_eq!(a, b);
    }

    #[test]
    fn splits_disjoint_seeds() {
        let (t, v, w) = standard_splits(4000);
        assert!(t.len() >= 4000 && v.len() >= 500 && w.len() >= 500);
        assert_ne!(&t[..200], &v[..200]);
        assert_ne!(&v[..200], &w[..200]);
    }

    #[test]
    fn ascii_only_no_nul() {
        let t = CorpusGen::new(3, Style::Web).text(5000);
        assert!(t.bytes().all(|b| b != 0 && b.is_ascii()));
    }

    #[test]
    fn styles_differ() {
        let wiki = CorpusGen::new(5, Style::Wiki).text(3000);
        let web = CorpusGen::new(5, Style::Web).text(3000);
        assert!(web.contains("update:") || web.contains("honestly,"));
        assert!(!wiki.contains("update:"));
    }

    #[test]
    fn sentences_terminate() {
        let mut g = CorpusGen::new(7, Style::Wiki);
        for _ in 0..50 {
            let s = g.sentence();
            assert!(s.ends_with(". "), "{s:?}");
        }
    }
}
