//! Quantized linear algebra — the serving hot path (L3's analog of the
//! paper's fused MMQ/MMVQ CUDA kernels, §5.2/§5.4).
//!
//! Three evaluation strategies:
//!
//! - **naive**: dequantize every weight block to the original domain
//!   (inverse FWHT per block per use) and dot with raw activations — the
//!   paper's Alg 2 executed literally. O(rows·blocks·(n + n·log n)).
//! - **fused f32** ([`QuantizedLinear::matvec`]): exploit
//!   `dot(Hw, Hx) = dot(w, x)` — rotate each *activation* block once per
//!   matvec, then dot raw (still-rotated) weight grids against rotated
//!   activations. The inverse transform disappears from the per-row loop
//!   entirely: O(cols·log n) once plus O(rows·cols) of pure dot products.
//! - **fused W3A8 integer** ([`QuantizedLinear::matvec_q8`], default on
//!   the decode path): additionally quantize the rotated activations to
//!   int8 once per matvec ([`super::act`]) and run every per-block dot in
//!   i32 via [`super::Format::dot_block_q8`] — the CPU realization of the
//!   paper's DP4A pipeline, with all scales folded into one final f32
//!   multiply per block.
//! - **fused batched W3A8 GEMM** ([`QuantizedLinear::gemm_q8`], the
//!   decode path when several sequences step together): the B
//!   sequences' activations are rotated and Q8-quantized once into a
//!   **block-major** batch ([`super::act::QuantizedBatch`]) — for each
//!   column block, the B code vectors (plus their scales and code sums)
//!   sit in one contiguous slab. The per-row loop then walks the packed
//!   weight blocks exactly once, unpacking each block once and dotting
//!   it against all B columns ([`super::Format::gemm_block_q8`]): the
//!   weights-stationary MMQ scheduling of the paper's §5.2 (the same
//!   trick TWLA/CAT-Q use to make ternary-weight inference pay off),
//!   which turns PR 2's batch occupancy into per-token latency wins.
//!   Contract: every `(row, column)` output is **bit-identical** to
//!   [`QuantizedLinear::matvec_q8`] on that column alone — batching is
//!   never a numerics change (see `gemm_q8_matches_matvec_q8_bitwise`).
//!
//! All fused paths row-shard across cores via [`crate::util::threadpool`]
//! (bit-identical to single-threaded — see
//! `tests::parallel_matvec_bit_identical`). Before/after numbers live in
//! `benches/micro_kernels.rs`, `benches/batched_gemm.rs` and
//! EXPERIMENTS.md §Perf / §Batched.
//!
//! All variants walk packed blocks through one shared helper
//! (`for_each_row_block`), so block-indexing logic cannot drift between
//! them.

use super::act::{QuantizedActs, QuantizedBatch};
use super::{Format, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::profile;
use crate::util::threadpool;
use std::sync::Arc;

/// A quantized weight matrix `(out_dim, in_dim)` with the scratch needed
/// to apply it. Cloneable view — scratch is allocated per call site.
pub struct QuantizedLinear {
    pub w: QuantizedMatrix,
}

/// Reusable per-caller scratch for the fused matvec paths: the rotated
/// activation copy, its Q8 form, a padding staging buffer, and the
/// fallback-format dequant buffer. Carrying one of these across calls
/// (the engine holds one per worker) removes every per-matvec allocation
/// from the decode loop.
#[derive(Default)]
pub struct MatvecScratch {
    pub(crate) x_rot: Vec<f32>,
    pub(crate) x_pad: Vec<f32>,
    pub(crate) acts: QuantizedActs,
    pub(crate) bacts: QuantizedBatch,
    pub(crate) yt: Vec<f32>,
    pub(crate) tmp: Vec<f32>,
}

impl MatvecScratch {
    pub fn new() -> Self {
        MatvecScratch::default()
    }

    /// Poison every f32 staging buffer — live contents *and* spare
    /// `Vec` capacity — with NaN. The differential harness calls this
    /// between runs so that any kernel lane reading past the logical
    /// end of a staged buffer (e.g. a SIMD tail overrunning the
    /// zero-padded region a `PaddedLinear` stages into `x_pad`) drags a
    /// NaN into the output instead of silently consuming stale zeros.
    /// Every consumer of these buffers clears/overwrites the region it
    /// reads before use, so poisoning is invisible to correct kernels.
    pub fn poison(&mut self) {
        fn p(v: &mut Vec<f32>) {
            let len = v.len();
            v.resize(v.capacity(), 0.0);
            for x in v.iter_mut() {
                *x = f32::NAN;
            }
            v.truncate(len);
        }
        p(&mut self.x_rot);
        p(&mut self.x_pad);
        p(&mut self.yt);
        p(&mut self.tmp);
    }
}

/// Dot product with 4-way accumulator splitting (helps the autovectorizer
/// and breaks the dependency chain; see §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

impl QuantizedLinear {
    pub fn new(fmt: Arc<dyn Format>, dense: &Tensor) -> Self {
        QuantizedLinear { w: QuantizedMatrix::quantize(fmt, dense) }
    }

    pub fn out_dim(&self) -> usize {
        self.w.rows
    }

    pub fn in_dim(&self) -> usize {
        self.w.cols
    }

    /// Walk the packed blocks of row `r`: `f(block_in_row, rotation_idx,
    /// block_bytes)`. The single place that maps (row, block) to packed
    /// bytes and rotation index — every matvec/matmul variant iterates
    /// through here, so their block-indexing logic cannot drift.
    #[inline]
    fn for_each_row_block(&self, r: usize, mut f: impl FnMut(usize, u64, &[u8])) {
        let bb = self.w.fmt.block_bytes();
        let bpr = self.w.blocks_per_row();
        let row = &self.w.data[r * bpr * bb..(r + 1) * bpr * bb];
        for b in 0..bpr {
            f(b, self.w.block_idx(r, b), &row[b * bb..(b + 1) * bb]);
        }
    }

    /// One output row of the fused f32 path (the per-row MMVQ loop).
    #[inline]
    fn fused_row(&self, r: usize, x_rot: &[f32], xsums: &[f32], tmp: &mut Vec<f32>) -> f32 {
        let be = self.w.fmt.block_elems();
        let mut acc = 0.0f32;
        self.for_each_row_block(r, |b, idx, bytes| {
            acc += self.w.fmt.dot_block_raw(
                idx,
                bytes,
                &x_rot[b * be..(b + 1) * be],
                xsums[b],
                tmp,
            );
        });
        acc
    }

    /// One output row of the W3A8 integer path.
    #[inline]
    fn q8_row(&self, r: usize, acts: &QuantizedActs, tmp: &mut Vec<f32>) -> f32 {
        let mut acc = 0.0f32;
        self.for_each_row_block(r, |b, idx, bytes| {
            acc += self.w.fmt.dot_block_q8(idx, bytes, acts.block_at(b), tmp);
        });
        acc
    }

    /// Rotate a full activation vector into the storage domain, block by
    /// block (no-op for unrotated formats). The block ordinal passed to
    /// the format is the *column* block index: every weight row uses the
    /// same rotation per column block, which is why activations can be
    /// rotated once. (QuIP#-sim derives its signs from this index, so
    /// its per-block transforms also match across rows — see
    /// `quip3::tests::fused_rotation_identity`.)
    pub fn rotate_activations(&self, x: &mut [f32]) {
        if !self.w.fmt.is_rotated() {
            return;
        }
        let be = self.w.fmt.block_elems();
        for (b, chunk) in x.chunks_exact_mut(be).enumerate() {
            self.w.fmt.rotate_activation_block(b as u64, chunk);
        }
    }

    /// Per-block activation sums, shared by every weight row (the
    /// zero-point contribution of a block is `z * sum(x_block)`).
    fn block_sums(&self, x_rot: &[f32]) -> Vec<f32> {
        let be = self.w.fmt.block_elems();
        x_rot.chunks_exact(be).map(|c| c.iter().sum::<f32>()).collect()
    }

    /// Fused f32 matvec: `y = W x`. `x` is consumed in the *rotated*
    /// domain — call [`Self::rotate_activations`] first (or use
    /// [`Self::matvec`]). Single-threaded; `scratch` backs the generic
    /// per-block fallback for formats without a specialized kernel.
    pub fn matvec_rotated(&self, x_rot: &[f32], y: &mut [f32], scratch: &mut Vec<f32>) {
        assert_eq!(x_rot.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        let xsums = self.block_sums(x_rot);
        for (r, yo) in y.iter_mut().enumerate() {
            *yo = self.fused_row(r, x_rot, &xsums, scratch);
        }
    }

    /// Convenience fused f32 matvec on raw activations (single-threaded,
    /// allocating — kept for tests and cold paths; the serving path is
    /// [`Self::matvec_q8`]).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let mut xr = x.to_vec();
        self.rotate_activations(&mut xr);
        let mut scratch = Vec::new();
        self.matvec_rotated(&xr, y, &mut scratch);
    }

    /// Row-sharded fused f32 matvec: output rows are partitioned into
    /// `shards` contiguous ranges run on the shared scoped-thread pool.
    /// Bit-identical to [`Self::matvec`] for any shard count.
    pub fn matvec_par(&self, x: &[f32], y: &mut [f32], shards: usize) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        let mut xr = x.to_vec();
        {
            let _p = profile::scope(profile::Phase::RotQuant);
            self.rotate_activations(&mut xr);
        }
        let _p = profile::scope(profile::Phase::Gemm);
        let xsums = self.block_sums(&xr);
        threadpool::parallel_rows(y, shards, |row0, ys| {
            let mut tmp = Vec::new();
            for (dr, yo) in ys.iter_mut().enumerate() {
                *yo = self.fused_row(row0 + dr, &xr, &xsums, &mut tmp);
            }
        });
    }

    /// W3A8 integer fused matvec (the serving decode path): rotate the
    /// activations once, quantize them to per-block Q8 once, then run
    /// every per-block dot in integer domain via
    /// [`Format::dot_block_q8`], row-sharded across `shards` threads.
    /// All buffers live in `scratch` — zero allocation once warm.
    pub fn matvec_q8(
        &self,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut MatvecScratch,
        shards: usize,
    ) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        {
            // Profiler: FWHT rotation + Q8 activation quantization.
            let _p = profile::scope(profile::Phase::RotQuant);
            scratch.x_rot.clear();
            scratch.x_rot.extend_from_slice(x);
            self.rotate_activations(&mut scratch.x_rot);
            let be = self.w.fmt.block_elems();
            scratch.acts.quantize(&scratch.x_rot, be);
        }
        self.matvec_q8_acts(&scratch.acts, y, &mut scratch.tmp, shards);
    }

    /// Integer matvec core against pre-quantized activations (shared by
    /// the decode path and the batched prefill path, which quantizes each
    /// batch row's activations once and reuses them across weight rows).
    pub fn matvec_q8_acts(
        &self,
        acts: &QuantizedActs,
        y: &mut [f32],
        tmp: &mut Vec<f32>,
        shards: usize,
    ) {
        assert_eq!(acts.len(), self.in_dim());
        assert_eq!(acts.block(), self.w.fmt.block_elems());
        assert_eq!(y.len(), self.out_dim());
        // Profiler: the integer kernel proper (wall time of the whole
        // sharded call). Scoped here, in the innermost entry point, so
        // every caller is covered and scopes never nest.
        let _p = profile::scope(profile::Phase::Gemm);
        if shards <= 1 {
            for (r, yo) in y.iter_mut().enumerate() {
                *yo = self.q8_row(r, acts, tmp);
            }
            return;
        }
        threadpool::parallel_rows(y, shards, |row0, ys| {
            // Per-shard fallback buffer (only generic formats touch it).
            let mut tmp = Vec::new();
            for (dr, yo) in ys.iter_mut().enumerate() {
                *yo = self.q8_row(row0 + dr, acts, &mut tmp);
            }
        });
    }

    /// Fused batched W3A8 GEMM (the multi-sequence decode path):
    /// `Y = X Wᵀ` for `X: (batch, in)` row-major, into `Y: (batch, out)`
    /// row-major. Activations are rotated and Q8-quantized once
    /// (block-major — see the module docs), then each packed weight
    /// block is unpacked **once** and dotted against all `batch` columns
    /// via [`Format::gemm_block_q8`], with weight rows sharded across
    /// `shards` threads.
    ///
    /// Every output row is bit-identical to [`Self::matvec_q8`] on the
    /// corresponding activation row, for any `batch` or `shards`:
    ///
    /// ```
    /// use itq3s::quant::format_by_name;
    /// use itq3s::quant::matmul::{MatvecScratch, QuantizedLinear};
    /// use itq3s::tensor::Tensor;
    /// let w = Tensor::new(vec![2, 256], (0..512).map(|i| (i % 7) as f32 * 0.01).collect());
    /// let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
    /// let x: Vec<f32> = (0..512).map(|i| (i as f32 * 0.01).sin()).collect(); // 2 rows
    /// let mut y = vec![0.0f32; 2 * 2];
    /// let mut scratch = MatvecScratch::new();
    /// lin.gemm_q8(&x, 2, &mut y, &mut scratch, 1);
    /// // Row 0 of the batch equals the sequential matvec, bit for bit.
    /// let mut y0 = vec![0.0f32; 2];
    /// lin.matvec_q8(&x[..256], &mut y0, &mut scratch, 1);
    /// assert_eq!(&y[..2], &y0[..]);
    /// ```
    pub fn gemm_q8(
        &self,
        x: &[f32],
        batch: usize,
        y: &mut [f32],
        scratch: &mut MatvecScratch,
        shards: usize,
    ) {
        assert!(batch > 0, "batch must be positive");
        assert_eq!(x.len(), batch * self.in_dim());
        assert_eq!(y.len(), batch * self.out_dim());
        {
            // Profiler: FWHT rotation + Q8 quantization of the batch.
            let _p = profile::scope(profile::Phase::RotQuant);
            scratch.x_rot.clear();
            scratch.x_rot.extend_from_slice(x);
            for row in scratch.x_rot.chunks_exact_mut(self.in_dim()) {
                self.rotate_activations(row);
            }
            let be = self.w.fmt.block_elems();
            scratch.bacts.quantize(&scratch.x_rot, batch, be);
        }
        let mut yt = std::mem::take(&mut scratch.yt);
        let mut tmp = std::mem::take(&mut scratch.tmp);
        self.gemm_q8_acts(&scratch.bacts, y, &mut yt, &mut tmp, shards);
        scratch.yt = yt;
        scratch.tmp = tmp;
    }

    /// Batched-GEMM core against a pre-quantized activation batch. `yt`
    /// is the `(rows, batch)` transposed accumulator (reused across
    /// calls so each weight-row shard owns a contiguous slab); the
    /// result is scattered into row-major `y: (batch, out)` at the end.
    pub fn gemm_q8_acts(
        &self,
        acts: &QuantizedBatch,
        y: &mut [f32],
        yt: &mut Vec<f32>,
        tmp: &mut Vec<f32>,
        shards: usize,
    ) {
        let batch = acts.cols();
        assert_eq!(acts.seq_len(), self.in_dim());
        assert_eq!(acts.block(), self.w.fmt.block_elems());
        assert_eq!(y.len(), batch * self.out_dim());
        // Profiler: the batched integer kernel (innermost entry point —
        // see `matvec_q8_acts`).
        let _p = profile::scope(profile::Phase::Gemm);
        let rows = self.w.rows;
        yt.clear();
        yt.resize(rows * batch, 0.0);
        // Per row, blocks advance in the same order as `q8_row`, and each
        // `gemm_block_q8` increment is bit-identical to `dot_block_q8` on
        // that column (the Format contract), so y[t] reproduces the
        // sequential accumulation exactly.
        let run_rows = |r0: usize, slab: &mut [f32], tmp: &mut Vec<f32>| {
            for (dr, yrow) in slab.chunks_exact_mut(batch).enumerate() {
                self.for_each_row_block(r0 + dr, |b, idx, bytes| {
                    self.w.fmt.gemm_block_q8(idx, bytes, acts.block_at(b), yrow, tmp);
                });
            }
        };
        if shards <= 1 {
            run_rows(0, &mut yt[..], tmp);
        } else {
            threadpool::parallel_chunks(&mut yt[..], batch, shards, |r0, slab| {
                // Per-shard fallback buffer (only generic formats use it).
                let mut tmp = Vec::new();
                run_rows(r0, slab, &mut tmp);
            });
        }
        for (r, yrow) in yt.chunks_exact(batch).enumerate() {
            for (t, &v) in yrow.iter().enumerate() {
                y[t * rows + r] = v;
            }
        }
    }

    /// Naive matvec: dequantize each block to the original domain
    /// (inverse rotation per block) and dot raw activations. Kept for
    /// correctness cross-checks and the §Perf before/after.
    pub fn matvec_naive(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        let be = self.w.fmt.block_elems();
        let mut buf = vec![0.0f32; be];
        for (r, yo) in y.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            self.for_each_row_block(r, |b, idx, bytes| {
                self.w.fmt.dequantize_block(idx, bytes, &mut buf);
                acc += dot(&buf, &x[b * be..(b + 1) * be]);
            });
            *yo = acc;
        }
    }

    /// Fused batched matmul: `Y = X Wᵀ` for `X: (batch, in)`, returning
    /// `(batch, out)`. Each weight block is dequantized **once** and
    /// reused across the whole batch — the prefill-path (MMQ)
    /// optimization that Table 2 attributes to the interleaved layout —
    /// with weight rows sharded across the thread pool.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        let shards = threadpool::suggested_shards(
            self.w.rows,
            self.w.rows * self.w.cols * x.rows().max(1),
        );
        self.matmul_sharded(x, shards)
    }

    /// [`Self::matmul`] with an explicit shard count (benches, tests).
    /// Bit-identical to the single-shard result for any `shards`.
    pub fn matmul_sharded(&self, x: &Tensor, shards: usize) -> Tensor {
        assert_eq!(x.cols(), self.in_dim());
        let batch = x.rows();
        let rows = self.w.rows;
        if batch == 0 {
            return Tensor::zeros(vec![0, self.out_dim()]);
        }
        let be = self.w.fmt.block_elems();
        // Rotate all activation rows once.
        let mut xr = x.clone();
        {
            let _p = profile::scope(profile::Phase::RotQuant);
            for t in 0..batch {
                self.rotate_activations(xr.row_mut(t));
            }
        }
        // Accumulate transposed — (rows, batch) — so each weight-row
        // shard owns a contiguous slab; transpose once at the end.
        let _p = profile::scope(profile::Phase::Gemm);
        let mut yt = vec![0.0f32; rows * batch];
        threadpool::parallel_chunks(&mut yt, batch, shards, |r0, slab| {
            let mut buf = vec![0.0f32; be];
            for (dr, yrow) in slab.chunks_exact_mut(batch).enumerate() {
                self.for_each_row_block(r0 + dr, |b, idx, bytes| {
                    self.w.fmt.dequantize_block_raw(idx, bytes, &mut buf);
                    for (t, yo) in yrow.iter_mut().enumerate() {
                        let xa = &xr.row(t)[b * be..(b + 1) * be];
                        *yo += dot(&buf, xa);
                    }
                });
            }
        });
        let mut out = Tensor::zeros(vec![batch, rows]);
        for (r, yrow) in yt.chunks_exact(batch).enumerate() {
            for (t, &v) in yrow.iter().enumerate() {
                out.row_mut(t)[r] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name;
    use crate::util::prop::{forall, forall_kernel_cases, heavy_tailed_tensor};
    use crate::util::{stats, XorShift};

    // dof=5 keeps the exact RNG stream these tests' tolerances were
    // calibrated on (previously a local generator; now the shared one
    // in util::prop).
    fn test_weight(rows: usize, cols: usize, seed: u64) -> Tensor {
        heavy_tailed_tensor(rows, cols, seed, 5.0)
    }

    /// Tolerance of the W3A8 path vs the fused f32 path, per format.
    ///
    /// Derivation (by inspection — ROADMAP's statistical-triage item):
    /// the only error source the W3A8 path adds over the fused f32 path
    /// is int8 activation resolution. Per block, codes round within
    /// ±0.5·(amax/127), so the activation's relative L2 error is about
    /// `(amax/254)·√n / ‖x‖₂ ≈ √3/254 ≈ 0.7%` for roughly-uniform
    /// blocks (‖x‖₂ ≈ amax·√(n/3)); heavy-tailed blocks concentrate
    /// mass in few coordinates and land *below* that. A matvec row
    /// inherits ~0.7% amplified by cancellation in the weight row —
    /// empirically ≤ 2-3× on these fixtures. Budgets are that estimate
    /// with ~3× headroom: 2% where weights are near-lossless (the
    /// activation term dominates), 3% for the 4-bit formats (weight
    /// error adds cancellation), 5% for the 3-bit formats.
    fn w3a8_tol(name: &str) -> f64 {
        match name {
            "fp16" | "q8_0" => 0.02,
            "q4_k_m" | "iq4_xs" => 0.03,
            _ => 0.05, // 3-bit formats
        }
    }

    #[test]
    fn dot_matches_reference() {
        let mut rng = XorShift::new(1);
        for n in [1usize, 3, 4, 7, 256, 511] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn fused_equals_naive_all_formats() {
        let w = test_weight(16, 512, 2);
        let mut rng = XorShift::new(3);
        let x: Vec<f32> = (0..512).map(|_| rng.next_f32() - 0.5).collect();
        for name in crate::quant::TABLE1_FORMATS {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y_fused = vec![0.0f32; 16];
            let mut y_naive = vec![0.0f32; 16];
            lin.matvec(&x, &mut y_fused);
            lin.matvec_naive(&x, &mut y_naive);
            for (a, b) in y_fused.iter().zip(&y_naive) {
                assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn w3a8_matches_f32_fused_all_formats() {
        // The acceptance parity check: the integer path tracks the f32
        // fused path within the activation-quantization tolerance on
        // every Table-1 format.
        let w = test_weight(16, 512, 12);
        let mut rng = XorShift::new(13);
        let x: Vec<f32> = (0..512).map(|_| rng.next_f32() - 0.5).collect();
        for name in crate::quant::TABLE1_FORMATS {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y_f32 = vec![0.0f32; 16];
            let mut y_q8 = vec![0.0f32; 16];
            lin.matvec(&x, &mut y_f32);
            let mut scratch = MatvecScratch::new();
            lin.matvec_q8(&x, &mut y_q8, &mut scratch, 1);
            let rel = stats::rel_l2_err(&y_f32, &y_q8);
            assert!(rel < w3a8_tol(name), "{name}: rel={rel}");
        }
    }

    #[test]
    fn prop_w3a8_tracks_f32_on_heavy_tails() {
        // Property form of the parity check: heavy-tailed weights and
        // varied activations, all Table-1 formats, shared scratch.
        //
        // Tolerance audit (by inspection): `w3a8_tol` (see its
        // derivation comment) is a per-*draw* bound with ~3× headroom
        // over the analytic activation-resolution estimate, and every
        // draw here is seeded (`forall` runs a fixed deterministic seed
        // sequence), so this is 12 fixed cases × 8 formats, not a
        // sampling experiment — no additional multiple-comparison slack
        // is needed on top of the per-draw headroom.
        forall("W3A8 matches fused f32 per format", 12, |g| {
            let rows = 4;
            let cols = 512;
            let mut w = Tensor::zeros(vec![rows, cols]);
            for v in w.data_mut() {
                *v = g.gaussian_f32(0.02)
                    + if g.f32_in(0.0, 1.0) < 0.01 {
                        g.f32_in(5.0, 20.0) * 0.02 * g.sign()
                    } else {
                        0.0
                    };
            }
            let x = g.vec_f32(cols, -1.0, 1.0);
            let mut scratch = MatvecScratch::new();
            for name in crate::quant::TABLE1_FORMATS {
                let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
                let mut y_f32 = vec![0.0f32; rows];
                let mut y_q8 = vec![0.0f32; rows];
                lin.matvec(&x, &mut y_f32);
                lin.matvec_q8(&x, &mut y_q8, &mut scratch, 1);
                let rel = stats::rel_l2_err(&y_f32, &y_q8);
                assert!(rel < w3a8_tol(name), "{name}: rel={rel}");
            }
        });
    }

    #[test]
    fn parallel_matvec_bit_identical() {
        // Row sharding must not change a single bit of the output, for
        // both the f32 and the W3A8 integer paths.
        let w = test_weight(37, 1024, 21); // odd row count: uneven shards
        let mut rng = XorShift::new(22);
        let x: Vec<f32> = (0..1024).map(|_| rng.next_f32() - 0.5).collect();
        for name in ["itq3_s", "q8_0", "q4_k_m"] {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y1 = vec![0.0f32; 37];
            lin.matvec_par(&x, &mut y1, 1);
            for shards in [2usize, 3, 8] {
                let mut yn = vec![0.0f32; 37];
                lin.matvec_par(&x, &mut yn, shards);
                assert_eq!(y1, yn, "{name} f32 path, shards={shards}");
            }
            let mut scratch = MatvecScratch::new();
            let mut q1 = vec![0.0f32; 37];
            lin.matvec_q8(&x, &mut q1, &mut scratch, 1);
            for shards in [2usize, 5, 8] {
                let mut qn = vec![0.0f32; 37];
                lin.matvec_q8(&x, &mut qn, &mut scratch, shards);
                assert_eq!(q1, qn, "{name} q8 path, shards={shards}");
            }
        }
    }

    /// Forwards a format's storage methods but **not** its specialized
    /// dot/gemm kernels, so the `Format` trait defaults run on the same
    /// packed bytes — the reference the hand-specialized kernels are
    /// differential-tested against.
    struct GenericOnly(std::sync::Arc<dyn Format>);

    impl Format for GenericOnly {
        fn name(&self) -> &'static str {
            "generic-only"
        }
        fn block_elems(&self) -> usize {
            self.0.block_elems()
        }
        fn block_bytes(&self) -> usize {
            self.0.block_bytes()
        }
        fn quantize_block(&self, idx: u64, w: &[f32], out: &mut Vec<u8>) {
            self.0.quantize_block(idx, w, out)
        }
        fn dequantize_block(&self, idx: u64, bytes: &[u8], out: &mut [f32]) {
            self.0.dequantize_block(idx, bytes, out)
        }
        fn dequantize_block_raw(&self, idx: u64, bytes: &[u8], out: &mut [f32]) {
            self.0.dequantize_block_raw(idx, bytes, out)
        }
        fn rotate_activation_block(&self, idx: u64, x: &mut [f32]) {
            self.0.rotate_activation_block(idx, x)
        }
        fn is_rotated(&self) -> bool {
            self.0.is_rotated()
        }
    }

    #[test]
    fn gemm_block_q8_increments_match_dot_block_q8_all_formats() {
        // The batched-kernel contract, column by column: for EVERY
        // format (specialized or defaulted), gemm_block_q8's y[t]
        // increment is bit-identical to dot_block_q8 on that column —
        // driven by the shared seeded kernel fuzz loop (fixed
        // adversarial shapes first, then seeded randoms; failing seeds
        // replay via ITQ3S_PROP_SEED).
        let mut formats: Vec<&str> = crate::quant::TABLE1_FORMATS.to_vec();
        formats.push("itq3_s_sub");
        for name in formats {
            let be = format_by_name(name).unwrap().block_elems();
            let prop = format!("gemm_block_q8 == dot_block_q8 per column [{name}]");
            forall_kernel_cases(&prop, be, 12, |case, w, rows| {
                let fmt = format_by_name(name).unwrap();
                let mut bytes = Vec::new();
                fmt.quantize_block(case, w, &mut bytes);
                let cols = rows.len();
                let flat: Vec<f32> = rows.concat();
                let mut batch = crate::quant::act::QuantizedBatch::new();
                batch.quantize(&flat, cols, be);
                let bb = batch.block_at(0);
                let mut y = vec![0.0f32; cols];
                let mut tmp = Vec::new();
                fmt.gemm_block_q8(case, &bytes, bb, &mut y, &mut tmp);
                for t in 0..cols {
                    let mut tmp2 = Vec::new();
                    let want = fmt.dot_block_q8(case, &bytes, bb.col(t), &mut tmp2);
                    assert_eq!(
                        y[t].to_bits(),
                        want.to_bits(),
                        "{name} case {case} col {t}: {} vs {want}",
                        y[t]
                    );
                }
            });
        }
    }

    #[test]
    fn specialized_q8_kernels_track_generic_fallback() {
        // Differential test: the hand-specialized integer kernels vs the
        // trait-default f32 reconstruction path, on the same packed
        // bytes — the shared kernel fuzz loop's random and adversarial
        // blocks. They compute the same mathematical value along
        // different float paths, so agreement is bounded by accumulation
        // error (scaled to the block's absolute term mass), not bitwise.
        for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0"] {
            assert!(
                format_by_name(name).unwrap().has_q8_kernel(),
                "{name} must be specialized"
            );
            let be = format_by_name(name).unwrap().block_elems();
            let prop = format!("specialized q8 kernel tracks generic [{name}]");
            forall_kernel_cases(&prop, be, 12, |case, w, rows| {
                let fmt = format_by_name(name).unwrap();
                let generic = GenericOnly(fmt.clone());
                let mut bytes = Vec::new();
                fmt.quantize_block(case, w, &mut bytes);
                let cols = rows.len();
                let flat: Vec<f32> = rows.concat();
                let mut batch = crate::quant::act::QuantizedBatch::new();
                batch.quantize(&flat, cols, be);
                let bb = batch.block_at(0);
                // Absolute term mass |ŵ|·|x̂| per column bounds the
                // accumulation-order error of either path.
                let mut wbuf = vec![0.0f32; be];
                fmt.dequantize_block_raw(case, &bytes, &mut wbuf);
                let mut y_spec = vec![0.0f32; cols];
                let mut y_gen = vec![0.0f32; cols];
                let mut tmp = Vec::new();
                fmt.gemm_block_q8(case, &bytes, bb, &mut y_spec, &mut tmp);
                generic.gemm_block_q8(case, &bytes, bb, &mut y_gen, &mut tmp);
                for t in 0..cols {
                    let ab = bb.col(t);
                    let mass: f64 = wbuf
                        .iter()
                        .zip(ab.codes)
                        .map(|(&wv, &c)| (wv as f64 * (c as f64 * ab.scale as f64)).abs())
                        .sum();
                    let tol = 1e-4 * mass + 1e-5;
                    let (a, b) = (y_spec[t] as f64, y_gen[t] as f64);
                    assert!(
                        (a - b).abs() <= tol,
                        "{name} case {case} col {t}: {a} vs {b} (tol {tol})"
                    );
                    // And the single-column kernels agree the same way.
                    let mut tmp2 = Vec::new();
                    let ds = fmt.dot_block_q8(case, &bytes, ab, &mut tmp2) as f64;
                    let dg = generic.dot_block_q8(case, &bytes, ab, &mut tmp2) as f64;
                    assert!(
                        (ds - dg).abs() <= tol,
                        "{name} case {case} col {t} dot: {ds} vs {dg} (tol {tol})"
                    );
                }
            });
        }
    }

    #[test]
    fn gemm_q8_matches_matvec_q8_bitwise() {
        // Linear-level acceptance: the batched GEMM reproduces the
        // sequential integer matvec bit-for-bit for every row of every
        // batch size, specialized and generic formats alike, and row
        // sharding changes nothing.
        let w = test_weight(37, 512, 41); // odd row count: uneven shards
        let mut rng = XorShift::new(42);
        for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0", "fp16", "quip3"] {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut scratch = MatvecScratch::new();
            for batch in [1usize, 2, 5, 8] {
                let x: Vec<f32> =
                    (0..batch * 512).map(|_| rng.next_f32() - 0.5).collect();
                let mut y = vec![0.0f32; batch * 37];
                lin.gemm_q8(&x, batch, &mut y, &mut scratch, 1);
                for t in 0..batch {
                    let mut yt = vec![0.0f32; 37];
                    lin.matvec_q8(&x[t * 512..(t + 1) * 512], &mut yt, &mut scratch, 1);
                    assert_eq!(
                        &y[t * 37..(t + 1) * 37],
                        &yt[..],
                        "{name} batch={batch} row {t}"
                    );
                }
                for shards in [2usize, 3, 8] {
                    let mut ys = vec![0.0f32; batch * 37];
                    lin.gemm_q8(&x, batch, &mut ys, &mut scratch, shards);
                    assert_eq!(y, ys, "{name} batch={batch} shards={shards}");
                }
            }
        }
    }

    #[test]
    fn matmul_sharded_bit_identical() {
        let w = test_weight(24, 512, 31);
        let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
        let mut rng = XorShift::new(32);
        let mut x = Tensor::zeros(vec![3, 512]);
        for v in x.data_mut() {
            *v = rng.next_f32() - 0.5;
        }
        let y1 = lin.matmul_sharded(&x, 1);
        for shards in [2usize, 4, 7] {
            let yn = lin.matmul_sharded(&x, shards);
            assert_eq!(y1.data(), yn.data(), "shards={shards}");
        }
    }

    #[test]
    fn quantized_matvec_approximates_dense() {
        // Tolerance derivation (by inspection): here the *weight*
        // reconstruction error dominates (the reference is the dense
        // f32 matvec, not the fused path), so budgets scale with each
        // format's per-element RMSE on Student-t(5) weights — ≈ 0.03%
        // fp16, ≈ 0.4% q8_0, ≈ 5% q4_k_m, ≈ 30-50% for the 3-bit grid —
        // amplified by row cancellation on Gaussian activations (rows
        // sum 512 terms; relative error grows when the sum is small).
        // Budgets are ~2-3× the observed fixture margins: 0.01, 0.02,
        // 0.2, 0.8. The W3A8 leg adds the ≤ 0.7% activation-resolution
        // term (see `w3a8_tol`), covered by the flat +0.02.
        let w = test_weight(32, 512, 4);
        let mut rng = XorShift::new(5);
        let x: Vec<f32> = (0..512).map(|_| rng.next_gaussian() as f32).collect();
        // Dense reference.
        let mut y_ref = vec![0.0f32; 32];
        crate::tensor::matvec_accum(&w, &x, &mut y_ref);
        for (name, tol) in
            [("fp16", 0.01), ("q8_0", 0.02), ("q4_k_m", 0.2), ("itq3_s", 0.8)]
        {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y = vec![0.0f32; 32];
            lin.matvec(&x, &mut y);
            let rel = stats::rel_l2_err(&y_ref, &y);
            assert!(rel < tol, "{name}: rel={rel}");
            // The W3A8 path must stay within the same budget.
            let mut yq = vec![0.0f32; 32];
            let mut scratch = MatvecScratch::new();
            lin.matvec_q8(&x, &mut yq, &mut scratch, 1);
            let relq = stats::rel_l2_err(&y_ref, &yq);
            assert!(relq < tol + 0.02, "{name} q8: rel={relq}");
        }
    }

    #[test]
    fn batched_matmul_matches_matvec() {
        let w = test_weight(24, 256, 6);
        let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
        let mut rng = XorShift::new(7);
        let batch = 5;
        let mut x = Tensor::zeros(vec![batch, 256]);
        for v in x.data_mut() {
            *v = rng.next_f32() - 0.5;
        }
        let y = lin.matmul(&x);
        for t in 0..batch {
            let mut yt = vec![0.0f32; 24];
            lin.matvec(x.row(t), &mut yt);
            for (a, b) in y.row(t).iter().zip(&yt) {
                assert!((a - b).abs() < 1e-3, "row {t}");
            }
        }
    }

    #[test]
    fn empty_batch_matmul() {
        let w = test_weight(8, 256, 8);
        let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
        let y = lin.matmul(&Tensor::zeros(vec![0, 256]));
        assert_eq!(y.shape(), &[0, 8]);
    }

    #[test]
    fn rotation_is_per_column_block_consistent() {
        // Two different rows of W must be usable with a single rotated x.
        let w = test_weight(2, 256, 8);
        let lin = QuantizedLinear::new(format_by_name("quip3").unwrap(), &w);
        let mut rng = XorShift::new(9);
        let x: Vec<f32> = (0..256).map(|_| rng.next_f32() - 0.5).collect();
        let mut y_fused = vec![0.0f32; 2];
        let mut y_naive = vec![0.0f32; 2];
        lin.matvec(&x, &mut y_fused);
        lin.matvec_naive(&x, &mut y_naive);
        for (a, b) in y_fused.iter().zip(&y_naive) {
            assert!((a - b).abs() < 2e-3 * b.abs().max(1.0));
        }
    }
}
