//! Quantized linear algebra — the serving hot path (L3's analog of the
//! paper's fused MMQ/MMVQ CUDA kernels, §5.2/§5.4).
//!
//! Two evaluation strategies:
//!
//! - **naive**: dequantize every weight block to the original domain
//!   (inverse FWHT per block per use) and dot with raw activations — the
//!   paper's Alg 2 executed literally. O(rows·blocks·(n + n·log n)).
//! - **fused** (default): exploit `dot(Hw, Hx) = dot(w, x)` — rotate each
//!   *activation* block once per matvec, then dot raw (still-rotated)
//!   weight grids against rotated activations. The inverse transform
//!   disappears from the per-row loop entirely: O(cols·log n) once plus
//!   O(rows·cols) of pure dot products. This is the CPU realization of
//!   "fusing the IFWHT into the load stage" and is benchmarked against
//!   naive in `benches/micro_kernels.rs` and EXPERIMENTS.md §Perf.

use super::{Format, QuantizedMatrix};
use crate::tensor::Tensor;
use std::sync::Arc;

/// A quantized weight matrix `(out_dim, in_dim)` with the scratch needed
/// to apply it. Cloneable view — scratch is allocated per call site.
pub struct QuantizedLinear {
    pub w: QuantizedMatrix,
}

/// Dot product with 4-way accumulator splitting (helps the autovectorizer
/// and breaks the dependency chain; see §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

impl QuantizedLinear {
    pub fn new(fmt: Arc<dyn Format>, dense: &Tensor) -> Self {
        QuantizedLinear { w: QuantizedMatrix::quantize(fmt, dense) }
    }

    pub fn out_dim(&self) -> usize {
        self.w.rows
    }

    pub fn in_dim(&self) -> usize {
        self.w.cols
    }

    /// Rotate a full activation vector into the storage domain, block by
    /// block (no-op for unrotated formats). The block ordinal passed to
    /// the format is the *column* block index: every weight row uses the
    /// same rotation per column block, which is why activations can be
    /// rotated once. (QuIP#-sim derives its signs from this index, so
    /// its per-block transforms also match across rows — see
    /// `quip3::tests::fused_rotation_identity`.)
    pub fn rotate_activations(&self, x: &mut [f32]) {
        if !self.w.fmt.is_rotated() {
            return;
        }
        let be = self.w.fmt.block_elems();
        for (b, chunk) in x.chunks_exact_mut(be).enumerate() {
            self.w.fmt.rotate_activation_block(b as u64, chunk);
        }
    }

    /// Fused matvec: `y = W x`. `x` is consumed in the *rotated* domain —
    /// call [`Self::rotate_activations`] first (or use [`Self::matvec`]).
    pub fn matvec_rotated(&self, x_rot: &[f32], y: &mut [f32], scratch: &mut Vec<f32>) {
        assert_eq!(x_rot.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        let be = self.w.fmt.block_elems();
        let bb = self.w.fmt.block_bytes();
        let bpr = self.w.blocks_per_row();
        // Per-block activation sums, shared by every weight row (the
        // zero-point contribution of a block is z * sum(x_block)).
        let xsums: Vec<f32> = x_rot
            .chunks_exact(be)
            .map(|c| c.iter().sum::<f32>())
            .collect();
        for (r, yo) in y.iter_mut().enumerate() {
            let row_bytes = &self.w.data[r * bpr * bb..(r + 1) * bpr * bb];
            let mut acc = 0.0f32;
            for b in 0..bpr {
                // Fused unpack+dot per block (formats specialize this —
                // the MMVQ hot loop; see §Perf).
                acc += self.w.fmt.dot_block_raw(
                    b as u64,
                    &row_bytes[b * bb..(b + 1) * bb],
                    &x_rot[b * be..(b + 1) * be],
                    xsums[b],
                    scratch,
                );
            }
            *yo = acc;
        }
    }

    /// Convenience fused matvec on raw activations.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let mut xr = x.to_vec();
        self.rotate_activations(&mut xr);
        let mut scratch = Vec::new();
        self.matvec_rotated(&xr, y, &mut scratch);
    }

    /// Naive matvec: dequantize each block to the original domain
    /// (inverse rotation per block) and dot raw activations. Kept for
    /// correctness cross-checks and the §Perf before/after.
    pub fn matvec_naive(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(y.len(), self.out_dim());
        let be = self.w.fmt.block_elems();
        let mut buf = vec![0.0f32; be];
        let bpr = self.w.blocks_per_row();
        for (r, yo) in y.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for b in 0..bpr {
                let idx = self.w.block_idx(r, b);
                self.w.fmt.dequantize_block(idx, self.w.block_bytes(r, b), &mut buf);
                acc += dot(&buf, &x[b * be..(b + 1) * be]);
            }
            *yo = acc;
        }
    }

    /// Fused batched matmul: `Y = X Wᵀ` for `X: (batch, in)`, returning
    /// `(batch, out)`. Each weight block is dequantized **once** and
    /// reused across the whole batch — the prefill-path optimization that
    /// Table 2 attributes to the interleaved layout.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols(), self.in_dim());
        let batch = x.rows();
        let be = self.w.fmt.block_elems();
        let bpr = self.w.blocks_per_row();
        // Rotate all activation rows once.
        let mut xr = x.clone();
        for t in 0..batch {
            self.rotate_activations(xr.row_mut(t));
        }
        let mut out = Tensor::zeros(vec![batch, self.out_dim()]);
        let mut buf = vec![0.0f32; be];
        let bb = self.w.fmt.block_bytes();
        for r in 0..self.w.rows {
            for b in 0..bpr {
                let idx = b as u64;
                self.w.fmt.dequantize_block_raw(
                    idx,
                    &self.w.data[(r * bpr + b) * bb..(r * bpr + b + 1) * bb],
                    &mut buf,
                );
                for t in 0..batch {
                    let xa = &xr.row(t)[b * be..(b + 1) * be];
                    out.row_mut(t)[r] += dot(&buf, xa);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name;
    use crate::util::{stats, XorShift};

    fn test_weight(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = XorShift::new(seed);
        let mut t = Tensor::zeros(vec![rows, cols]);
        for x in t.data_mut() {
            *x = (rng.next_student_t(5.0) as f32) * 0.02;
        }
        t
    }

    #[test]
    fn dot_matches_reference() {
        let mut rng = XorShift::new(1);
        for n in [1usize, 3, 4, 7, 256, 511] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn fused_equals_naive_all_formats() {
        let w = test_weight(16, 512, 2);
        let mut rng = XorShift::new(3);
        let x: Vec<f32> = (0..512).map(|_| rng.next_f32() - 0.5).collect();
        for name in crate::quant::TABLE1_FORMATS {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y_fused = vec![0.0f32; 16];
            let mut y_naive = vec![0.0f32; 16];
            lin.matvec(&x, &mut y_fused);
            lin.matvec_naive(&x, &mut y_naive);
            for (a, b) in y_fused.iter().zip(&y_naive) {
                assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn quantized_matvec_approximates_dense() {
        let w = test_weight(32, 512, 4);
        let mut rng = XorShift::new(5);
        let x: Vec<f32> = (0..512).map(|_| rng.next_gaussian() as f32).collect();
        // Dense reference.
        let mut y_ref = vec![0.0f32; 32];
        crate::tensor::matvec_accum(&w, &x, &mut y_ref);
        for (name, tol) in
            [("fp16", 0.01), ("q8_0", 0.02), ("q4_k_m", 0.2), ("itq3_s", 0.8)]
        {
            let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
            let mut y = vec![0.0f32; 32];
            lin.matvec(&x, &mut y);
            let rel = stats::rel_l2_err(&y_ref, &y);
            assert!(rel < tol, "{name}: rel={rel}");
        }
    }

    #[test]
    fn batched_matmul_matches_matvec() {
        let w = test_weight(24, 256, 6);
        let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
        let mut rng = XorShift::new(7);
        let batch = 5;
        let mut x = Tensor::zeros(vec![batch, 256]);
        for v in x.data_mut() {
            *v = rng.next_f32() - 0.5;
        }
        let y = lin.matmul(&x);
        for t in 0..batch {
            let mut yt = vec![0.0f32; 24];
            lin.matvec(x.row(t), &mut yt);
            for (a, b) in y.row(t).iter().zip(&yt) {
                assert!((a - b).abs() < 1e-3, "row {t}");
            }
        }
    }

    #[test]
    fn rotation_is_per_column_block_consistent() {
        // Two different rows of W must be usable with a single rotated x.
        let w = test_weight(2, 256, 8);
        let lin = QuantizedLinear::new(format_by_name("quip3").unwrap(), &w);
        let mut rng = XorShift::new(9);
        let x: Vec<f32> = (0..256).map(|_| rng.next_f32() - 0.5).collect();
        let mut y_fused = vec![0.0f32; 2];
        let mut y_naive = vec![0.0f32; 2];
        lin.matvec(&x, &mut y_fused);
        lin.matvec_naive(&x, &mut y_naive);
        for (a, b) in y_fused.iter().zip(&y_naive) {
            assert!((a - b).abs() < 2e-3 * b.abs().max(1.0));
        }
    }
}
