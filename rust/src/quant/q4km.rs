//! Q4_K_M-style 4-bit baseline (Table 1 row "Q4_K_M"), modeled on
//! llama.cpp's Q4_K super-block: 256 weights = 8 sub-blocks of 32, each
//! with an asymmetric uint4 grid whose (scale, min) pair is itself
//! quantized to 6 bits against two global f16s.
//!
//! Layout per 256-weight block (144 bytes = 4.5 b/w, the paper's figure):
//!
//! ```text
//! [ d: f16 ][ dmin: f16 ][ 16 x 6-bit sc/mc: 12 bytes ][ codes: 128 bytes ]
//! ```
//!
//! Reconstruction: `x̂ = (d·sc_s)·code − (dmin·mc_s)` for sub-block `s`.

use super::packing::*;
use super::Format;

pub struct Q4KM {
    n: usize,
    sub: usize,
}

impl Q4KM {
    pub fn new() -> Self {
        Q4KM { n: 256, sub: 32 }
    }

    fn nsub(&self) -> usize {
        self.n / self.sub
    }
}

impl Default for Q4KM {
    fn default() -> Self {
        Self::new()
    }
}

/// Pack 16 six-bit values into 12 bytes (little-endian bit stream).
fn pack_6bit(vals: &[u8; 16], out: &mut Vec<u8>) {
    let mut acc: u64 = 0;
    let mut nbits = 0;
    for &v in vals {
        debug_assert!(v < 64);
        acc |= (v as u64) << nbits;
        nbits += 6;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    debug_assert_eq!(nbits, 0);
}

/// Read the i-th 6-bit value from a 12-byte stream.
fn get_6bit(bytes: &[u8], i: usize) -> u8 {
    let bit = i * 6;
    let byte = bit / 8;
    let off = bit % 8;
    let lo = bytes[byte] as u16;
    let hi = if byte + 1 < bytes.len() { bytes[byte + 1] as u16 } else { 0 };
    (((lo | (hi << 8)) >> off) & 0x3F) as u8
}

impl Format for Q4KM {
    fn name(&self) -> &'static str {
        "q4_k_m"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // 2 + 2 + 12 + 128 = 144 bytes -> 4.5 b/w.
        4 + (self.nsub() * 2 * 6) / 8 + self.n / 2
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        // Per-sub asymmetric fit: scale = (max-min)/15, min clamped <= 0
        // (llama.cpp stores the min as a positive magnitude subtracted).
        let mut scales = [0.0f32; 8];
        let mut mins = [0.0f32; 8];
        for (s, chunk) in w.chunks_exact(self.sub).enumerate() {
            let mx = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mn = chunk.iter().cloned().fold(f32::INFINITY, f32::min).min(0.0);
            scales[s] = ((mx - mn) / 15.0).max(1e-10);
            mins[s] = -mn; // stored magnitude, >= 0
        }
        let d = crate::f16::f16_round(
            scales.iter().cloned().fold(0.0f32, f32::max) / 63.0,
        )
        .max(1e-10);
        let dmin = crate::f16::f16_round(
            mins.iter().cloned().fold(0.0f32, f32::max) / 63.0,
        )
        .max(1e-10);
        let mut six = [0u8; 16];
        for s in 0..8 {
            six[s] = ((scales[s] / d).round() as i64).clamp(0, 63) as u8;
            six[8 + s] = ((mins[s] / dmin).round() as i64).clamp(0, 63) as u8;
        }
        push_f16(out, d);
        push_f16(out, dmin);
        pack_6bit(&six, out);
        let mut codes = vec![0u8; self.n];
        for (s, chunk) in w.chunks_exact(self.sub).enumerate() {
            let sc = d * six[s] as f32;
            let m = dmin * six[8 + s] as f32;
            for (j, &x) in chunk.iter().enumerate() {
                let c = if sc > 0.0 { ((x + m) / sc).round() } else { 0.0 };
                codes[s * self.sub + j] = (c as i64).clamp(0, 15) as u8;
            }
        }
        pack_4bit(&codes, out);
    }

    fn dequantize_block(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let d = read_f16(bytes, 0);
        let dmin = read_f16(bytes, 2);
        let six = &bytes[4..16];
        let codes = &bytes[16..];
        for s in 0..self.nsub() {
            let sc = d * get_6bit(six, s) as f32;
            let m = dmin * get_6bit(six, 8 + s) as f32;
            for j in 0..self.sub {
                let i = s * self.sub + j;
                let c = (codes[i / 2] >> ((i % 2) * 4)) & 0xF;
                out[i] = sc * c as f32 - m;
            }
        }
    }

    fn has_q8_kernel(&self) -> bool {
        true
    }

    /// W4A8 integer fused dot. Per sub-block `s` the reconstruction is
    /// `sc_s·code − m_s`, so the dot factors into two integer sums per
    /// sub-block: `Σ code_i·x_i` and `Σ x_i` (the min term), combined in
    /// f32 with the activation scale folded in once at the end. Nibbles
    /// are unpacked once into an aligned i8 block and both sums come
    /// from the runtime-dispatched fused [`super::simd::dot_i8_xsum`]
    /// (i32 sums are regrouping-invariant, f32 expressions unchanged —
    /// bit-identical to the original inline loop).
    /// |dotc| ≤ 32·15·127 ≈ 6.1e4 per sub-block: no overflow.
    fn dot_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        act: super::act::ActBlock<'_>,
        _scratch: &mut Vec<f32>,
    ) -> f32 {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(act.codes.len(), n);
        let d = read_f16(bytes, 0);
        let dmin = read_f16(bytes, 2);
        let six = &bytes[4..16];
        let codes = &bytes[16..];
        let mut wv = crate::util::align::AlignedBlockI8::zeroed();
        let wv = &mut wv.0[..n];
        for i in (0..n).step_by(2) {
            let byte = codes[i / 2];
            wv[i] = (byte & 0xF) as i8;
            wv[i + 1] = (byte >> 4) as i8;
        }
        let mut total = 0.0f32;
        for s in 0..self.nsub() {
            let sc = get_6bit(six, s) as f32;
            let mc = get_6bit(six, 8 + s) as f32;
            let (dotc, xsum) = super::simd::dot_i8_xsum(
                &wv[s * self.sub..(s + 1) * self.sub],
                &act.codes[s * self.sub..(s + 1) * self.sub],
            );
            total += (d * sc) * dotc as f32 - (dmin * mc) * xsum as f32;
        }
        total * act.scale
    }

    /// Batched W4A8 fused dot: nibbles unpacked to i8 and the per-sub
    /// effective scales (`d·sc_s`, `dmin·mc_s`) computed once, then one
    /// integer inner loop per (column, sub-block). Per column the
    /// sub-block combination replays [`Format::dot_block_q8`] exactly,
    /// so each `y[t]` increment is bit-identical to the sequential path.
    fn gemm_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        acts: super::act::BatchBlock<'_>,
        y: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(acts.block, n);
        debug_assert_eq!(y.len(), acts.cols());
        let d = read_f16(bytes, 0);
        let dmin = read_f16(bytes, 2);
        let six = &bytes[4..16];
        let codes = &bytes[16..];
        let mut wv = crate::util::align::AlignedBlockI8::zeroed();
        let wv = &mut wv.0[..n];
        for i in (0..n).step_by(2) {
            let byte = codes[i / 2];
            wv[i] = (byte & 0xF) as i8;
            wv[i + 1] = (byte >> 4) as i8;
        }
        let nsub = self.nsub();
        let mut dsc = [0.0f32; 16];
        let mut dmm = [0.0f32; 16];
        for s in 0..nsub {
            dsc[s] = d * get_6bit(six, s) as f32;
            dmm[s] = dmin * get_6bit(six, 8 + s) as f32;
        }
        for (t, yo) in y.iter_mut().enumerate() {
            let ab = acts.col(t);
            let mut total = 0.0f32;
            for s in 0..nsub {
                let (dotc, xsum) = super::simd::dot_i8_xsum(
                    &wv[s * self.sub..(s + 1) * self.sub],
                    &ab.codes[s * self.sub..(s + 1) * self.sub],
                );
                total += dsc[s] * dotc as f32 - dmm[s] * xsum as f32;
            }
            *yo += total * ab.scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift};

    #[test]
    fn six_bit_pack_roundtrip() {
        let vals: [u8; 16] = core::array::from_fn(|i| (i * 4 + 1) as u8);
        let mut out = Vec::new();
        pack_6bit(&vals, &mut out);
        assert_eq!(out.len(), 12);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(get_6bit(&out, i), v, "i={i}");
        }
    }

    #[test]
    fn bits_per_weight_is_4_5() {
        assert!((Q4KM::new().bits_per_weight() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_beats_3bit() {
        let mut rng = XorShift::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_student_t(4.0) as f32 * 0.02).collect();
        let q4 = Q4KM::new();
        let q3 = crate::quant::itq3s::Itq3S::new(256);
        let mut b4 = Vec::new();
        let mut b3 = Vec::new();
        q4.quantize_block(0, &w, &mut b4);
        q3.quantize_block(0, &w, &mut b3);
        let mut o4 = vec![0.0f32; 256];
        let mut o3 = vec![0.0f32; 256];
        q4.dequantize_block(0, &b4, &mut o4);
        q3.dequantize_block(0, &b3, &mut o3);
        assert!(stats::mse(&w, &o4) < stats::mse(&w, &o3));
    }

    #[test]
    fn asymmetric_grid_handles_shifted_blocks() {
        // All-positive block: the asymmetric grid must not waste levels.
        let mut rng = XorShift::new(2);
        let w: Vec<f32> = (0..256).map(|_| rng.next_f32() * 0.1 + 0.05).collect();
        let f = Q4KM::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![0.0f32; 256];
        f.dequantize_block(0, &bytes, &mut out);
        assert!(stats::rel_l2_err(&w, &out) < 0.06);
    }

    #[test]
    fn exact_block_size() {
        let f = Q4KM::new();
        let w = vec![0.1f32; 256];
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        assert_eq!(bytes.len(), 144);
    }
}
