//! Reconstruction-error analysis (paper Theorem 2 / Proposition 1).
//!
//! Theorem 2 bounds `‖ŵ − w‖₂²` by the ternary grid alone, using the
//! isometry of `H`: quantization error in the rotated domain transfers
//! unchanged through the inverse transform. These helpers compute the
//! bound for a given block so tests (and the `quantize_inspect` example)
//! can verify it holds on every block of a real checkpoint.

use crate::fwht;
use crate::util::stats;

/// Per-element worst-case error of the dual-ternary grid `{0, ±d, ±3d}`
/// for an input `x` (already rotated and mean-removed):
/// - inside the grid (`|x| ≤ 3d`): at most `d` (half the largest gap,
///   which is `2d` between `d` and `3d`),
/// - beyond the grid: clamping error `|x| − 3d`.
#[inline]
pub fn dual_grid_elem_bound(x: f64, d: f64) -> f64 {
    let a = x.abs();
    if a <= 0.5 * d {
        0.5 * d
    } else {
        d.max(a - 3.0 * d)
    }
}

/// Theorem-2-style ℓ2² bound for an ITQ3_S block: rotate `w`, remove the
/// (f16-rounded) mean, and sum per-element grid bounds. The FWHT rounding
/// term `ε_FWHT` of the paper is O(n·log n·u) and is absorbed by callers
/// as a ~1% slack.
pub fn thm2_bound_l2sq(w: &[f32], d: f64, n: usize) -> f64 {
    assert_eq!(w.len(), n);
    let mut rot = w.to_vec();
    fwht::fwht_inplace(&mut rot);
    let z = crate::f16::f16_round(stats::mean(&rot) as f32) as f64;
    rot.iter()
        .map(|&x| dual_grid_elem_bound(x as f64 - z, d).powi(2))
        .sum()
}

/// The paper's headline bound shape (Eq. 6): `n·d²/4 + ε` — valid when no
/// element clamps. Returns `None` when clamping occurs (outliers beyond
/// `3d` survive rotation), in which case [`thm2_bound_l2sq`] is the tight
/// form.
pub fn thm2_bound_unclamped(w: &[f32], d: f64, n: usize) -> Option<f64> {
    let mut rot = w.to_vec();
    fwht::fwht_inplace(&mut rot);
    let z = crate::f16::f16_round(stats::mean(&rot) as f32) as f64;
    if rot.iter().any(|&x| (x as f64 - z).abs() > 3.0 * d) {
        return None;
    }
    // Largest per-element error inside the grid is d (not d/2) for the
    // dual grid; the paper's n·d²/4 applies to its plain-ternary analysis.
    Some(n as f64 * d * d)
}

/// Worst-case ℓ2 error of one per-row Q8 quantization — the `amax/127`
/// scale with round-to-nearest that [`crate::quant::act::quantize_block_q8`]
/// uses for both W3A8 activations and Q8 KV-cache rows
/// ([`crate::kvpaged`]): every element errs by at most half a step
/// (`amax/254`; clamping never binds because `|x| ≤ amax` maps inside
/// `±127`), so `‖x − x̂‖₂ ≤ (amax/254)·√n`. Deterministic, not
/// probabilistic — the Q8 KV accuracy test asserts it on every stored
/// row.
pub fn q8_row_l2_bound(row: &[f32]) -> f64 {
    let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    amax / 254.0 * (row.len() as f64).sqrt()
}

/// Empirical MSE improvement factor of rotating before quantization,
/// reported by the `quantize_inspect` example (reproduces the paper's §3
/// motivation numbers).
pub fn rotation_gain(w: &[f32], block: usize) -> f64 {
    use crate::quant::{iq3s::Iq3S, itq3s::Itq3S, Format};
    let rot = Itq3S::new(block);
    let raw = Iq3S::new();
    let mut mse_rot = 0.0;
    let mut mse_raw = 0.0;
    let mut out = vec![0.0f32; block];
    for (bi, chunk) in w.chunks_exact(block).enumerate() {
        let mut bytes = Vec::new();
        rot.quantize_block(bi as u64, chunk, &mut bytes);
        rot.dequantize_block(bi as u64, &bytes, &mut out);
        mse_rot += stats::mse(chunk, &out);
        bytes.clear();
        raw.quantize_block(bi as u64, chunk, &mut bytes);
        raw.dequantize_block(bi as u64, &bytes, &mut out);
        mse_raw += stats::mse(chunk, &out);
    }
    mse_raw / mse_rot.max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_bound_cases() {
        let d = 1.0;
        assert_eq!(dual_grid_elem_bound(0.0, d), 0.5);
        assert_eq!(dual_grid_elem_bound(1.5, d), 1.0);
        assert_eq!(dual_grid_elem_bound(2.9, d), 1.0);
        assert!((dual_grid_elem_bound(5.0, d) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn unclamped_bound_on_tame_block() {
        let mut rng = crate::util::XorShift::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.01).collect();
        // Generous d so nothing clamps.
        let d = 0.02;
        let b = thm2_bound_unclamped(&w, d, 256).expect("should not clamp");
        assert!((b - 256.0 * d * d).abs() < 1e-12);
    }

    #[test]
    fn clamped_block_detected() {
        let mut w = vec![0.0f32; 256];
        // Index 1 (not 0): an impulse at index 0 rotates to an all-equal
        // block whose mean removal cancels it; index 1 gives ±6.25 coeffs
        // with zero mean, far beyond 3d for small d.
        w[1] = 100.0;
        assert!(thm2_bound_unclamped(&w, 0.01, 256).is_none());
    }

    #[test]
    fn q8_row_bound_holds_on_roundtrip() {
        // The bound is worst-case, so it must hold deterministically for
        // any row — Gaussian, heavy-tailed, spiky, or zero.
        let mut rng = crate::util::XorShift::new(3);
        let mut rows: Vec<Vec<f32>> = vec![
            vec![0.0; 64],
            (0..256).map(|i| if i == 7 { 100.0 } else { 0.001 }).collect(),
        ];
        rows.push((0..256).map(|_| rng.next_gaussian() as f32).collect());
        rows.push((0..128).map(|_| rng.next_student_t(3.0) as f32).collect());
        for row in rows {
            let mut codes = vec![0i8; row.len()];
            let (scale, _) = crate::quant::act::quantize_block_q8(&row, &mut codes);
            let err_sq: f64 = row
                .iter()
                .zip(&codes)
                .map(|(&x, &c)| ((x - c as f32 * scale) as f64).powi(2))
                .sum();
            // Tiny multiplicative slack: scale/inv are f32-rounded, so a
            // code's reconstruction can sit a few ulps past the exact
            // half-step bound.
            let bound = q8_row_l2_bound(&row) * (1.0 + 1e-5) + 1e-9;
            assert!(
                err_sq.sqrt() <= bound,
                "err {} > bound {bound} (n={})",
                err_sq.sqrt(),
                row.len()
            );
        }
    }

    #[test]
    fn rotation_gain_exceeds_one_on_outlier_weights() {
        let mut rng = crate::util::XorShift::new(2);
        let mut w: Vec<f32> = (0..2048).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        for i in (0..2048).step_by(97) {
            w[i] = 0.4 * rng.next_sign();
        }
        let gain = rotation_gain(&w, 256);
        assert!(gain > 1.3, "gain={gain}");
    }
}
