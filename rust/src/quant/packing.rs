//! Bit-packing primitives for the quantized formats.
//!
//! ITQ3_S stores 256 weights in 96 bytes (= exactly 3 bits/weight) as two
//! interleaved planes, the coherent realization of the paper's Eq. (9)
//! "interleaved nibble streams" (see DESIGN.md — the paper's packing
//! description is internally inconsistent; the two-plane layout below
//! preserves its stated size, alignment, and single-32-bit-load decode
//! property):
//!
//! - **base plane** (64 bytes): 2-bit ternary codes, 16 codes per `u32`
//!   little-endian word, code `c ∈ {0,1,2}` ≘ ternary digit `c−1`.
//! - **selector plane** (32 bytes): 1 bit per weight choosing the fine
//!   (×1) or coarse (×3) sub-grid — the "interleave selector" that turns
//!   two ternary sub-grids into a 3-bit code.
//!
//! Decoding a weight touches one aligned `u32` from each plane — the CPU
//! analog of the paper's "single 32-bit load and bitfield extraction".

/// Pack 2-bit codes (values 0..=3) into little-endian bytes, 4 per byte.
pub fn pack_2bit(codes: &[u8], out: &mut Vec<u8>) {
    assert_eq!(codes.len() % 4, 0, "2-bit pack length must be a multiple of 4");
    for chunk in codes.chunks_exact(4) {
        debug_assert!(chunk.iter().all(|&c| c < 4));
        out.push(chunk[0] | (chunk[1] << 2) | (chunk[2] << 4) | (chunk[3] << 6));
    }
}

/// Unpack 2-bit codes; `n` values from `bytes`.
pub fn unpack_2bit(bytes: &[u8], n: usize, out: &mut [u8]) {
    assert!(out.len() >= n);
    assert!(bytes.len() * 4 >= n);
    for i in 0..n {
        out[i] = (bytes[i / 4] >> ((i % 4) * 2)) & 0x3;
    }
}

/// Pack single bits into little-endian bytes, 8 per byte.
pub fn pack_bits(bits: &[bool], out: &mut Vec<u8>) {
    assert_eq!(bits.len() % 8, 0, "bit pack length must be a multiple of 8");
    for chunk in bits.chunks_exact(8) {
        let mut b = 0u8;
        for (j, &bit) in chunk.iter().enumerate() {
            if bit {
                b |= 1 << j;
            }
        }
        out.push(b);
    }
}

/// Read bit `i` of a packed bit plane.
#[inline]
pub fn get_bit(bytes: &[u8], i: usize) -> bool {
    (bytes[i / 8] >> (i % 8)) & 1 == 1
}

/// Pack 4-bit codes (values 0..=15), 2 per byte, low nibble first.
pub fn pack_4bit(codes: &[u8], out: &mut Vec<u8>) {
    assert_eq!(codes.len() % 2, 0);
    for chunk in codes.chunks_exact(2) {
        debug_assert!(chunk.iter().all(|&c| c < 16));
        out.push(chunk[0] | (chunk[1] << 4));
    }
}

/// Unpack 4-bit codes; `n` values.
pub fn unpack_4bit(bytes: &[u8], n: usize, out: &mut [u8]) {
    assert!(out.len() >= n);
    for i in 0..n {
        out[i] = (bytes[i / 2] >> ((i % 2) * 4)) & 0xF;
    }
}

/// Write an f16 scale into a byte stream.
pub fn push_f16(out: &mut Vec<u8>, x: f32) {
    let bits = crate::f16::f32_to_f16_bits(x);
    out.extend_from_slice(&bits.to_le_bytes());
}

/// Read an f16 at byte offset `off`.
pub fn read_f16(bytes: &[u8], off: usize) -> f32 {
    let bits = u16::from_le_bytes([bytes[off], bytes[off + 1]]);
    crate::f16::f16_bits_to_f32(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn pack_unpack_2bit_roundtrip() {
        let codes: Vec<u8> = (0..64).map(|i| (i % 3) as u8).collect();
        let mut packed = Vec::new();
        pack_2bit(&codes, &mut packed);
        assert_eq!(packed.len(), 16);
        let mut out = vec![0u8; 64];
        unpack_2bit(&packed, 64, &mut out);
        assert_eq!(out, codes);
    }

    #[test]
    fn pack_unpack_4bit_roundtrip() {
        let codes: Vec<u8> = (0..32).map(|i| (i % 16) as u8).collect();
        let mut packed = Vec::new();
        pack_4bit(&codes, &mut packed);
        assert_eq!(packed.len(), 16);
        let mut out = vec![0u8; 32];
        unpack_4bit(&packed, 32, &mut out);
        assert_eq!(out, codes);
    }

    #[test]
    fn bit_plane_roundtrip() {
        let bits: Vec<bool> = (0..256).map(|i| i % 3 == 0).collect();
        let mut packed = Vec::new();
        pack_bits(&bits, &mut packed);
        assert_eq!(packed.len(), 32);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(get_bit(&packed, i), b);
        }
    }

    #[test]
    fn f16_stream_roundtrip() {
        let mut out = Vec::new();
        push_f16(&mut out, 0.0625);
        push_f16(&mut out, -3.5);
        assert_eq!(out.len(), 4);
        assert_eq!(read_f16(&out, 0), 0.0625);
        assert_eq!(read_f16(&out, 2), -3.5);
    }

    #[test]
    fn prop_random_codes_roundtrip() {
        forall("2/4-bit packing round-trips", 100, |g| {
            let n = 4 * g.usize_in(1, 64);
            let codes: Vec<u8> = (0..n).map(|_| g.usize_in(0, 3) as u8).collect();
            let mut packed = Vec::new();
            pack_2bit(&codes, &mut packed);
            let mut out = vec![0u8; n];
            unpack_2bit(&packed, n, &mut out);
            assert_eq!(out, codes);
        });
    }

    #[test]
    fn itq3s_plane_sizes() {
        // 256 weights: base plane 64 B + selector plane 32 B = 96 B = 3 b/w.
        let codes = vec![1u8; 256];
        let bits = vec![false; 256];
        let mut base = Vec::new();
        let mut sel = Vec::new();
        pack_2bit(&codes, &mut base);
        pack_bits(&bits, &mut sel);
        assert_eq!(base.len() + sel.len(), 96);
    }
}
