//! ITQ3_S — the paper's format (§4): FWHT rotation + interleaved ternary
//! 3-bit coding, 3.125 bits/weight (3.625 for the sub-scale variant).
//!
//! Per block of `n` (default 256, ablatable 32..512 — Table 3):
//!
//! ```text
//! [ base plane: n/4 bytes ][ selector plane: n/8 bytes ][ d: f16 ][ z: f16 ]
//! ```
//!
//! Encoding (paper Alg 1, with the §3.3 scale erratum fixed — see
//! `ternary::block_scale_ternary`):
//! 1. `w' = H_n w` (forward FWHT; Gaussianizes the block, Thm 1),
//! 2. `z = mean(w')`, `d = 0.5505·σ(w')` (MSE-optimal dual-ternary step
//!    for the Gaussianized block),
//! 3. each `x = w'_i − z` is coded to the nearest level of
//!    `{0, ±d, ±3d}` as (ternary digit, coarse-selector bit) — the
//!    "interleaved ternary" 3-bit code.
//!
//! Decoding (paper Alg 2 / Listing 2): reconstruct grid values, add `z`,
//! apply the inverse FWHT (involution: `H⁻¹ = H`). The serving fast path
//! skips the inverse and rotates activations instead
//! ([`Format::rotate_activation_block`]), which is algebraically identical
//! because `H` is orthogonal and symmetric — this is the CPU/TPU analog of
//! the paper's "fused into the shared-memory loading stage".

use super::packing::*;
use super::ternary;
use super::Format;
use crate::fwht;

/// ITQ3_S with configurable rotation block size (Table 3 ablation knob).
pub struct Itq3S {
    n: usize,
}

impl Itq3S {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && (32..=512).contains(&n), "block {n}");
        Itq3S { n }
    }

    /// Shared encode core (also used by the sub-scale variant for its
    /// rotated, mean-removed input).
    fn encode_codes(x: &[f32], d: f32, out: &mut Vec<u8>) {
        let n = x.len();
        let mut codes = vec![0u8; n];
        let mut sel = vec![false; n];
        for (i, &v) in x.iter().enumerate() {
            let (digit, coarse) = ternary::dual_ternary_digit(v, d);
            codes[i] = (digit + 1) as u8; // {-1,0,1} -> {0,1,2}
            sel[i] = coarse;
        }
        pack_2bit(&codes, out);
        pack_bits(&sel, out);
    }

    /// 8-entry value LUT for one block: index `(sel << 2) | code`.
    /// Codes {0,1,2} map to digits {-1,0,1}; sel selects the x3 sub-grid.
    #[inline]
    fn value_lut(d: f32) -> [f32; 8] {
        [-d, 0.0, d, 0.0, -3.0 * d, 0.0, 3.0 * d, 0.0]
    }

    /// Shared decode core: grid values (rotated domain, mean-removed).
    /// Branchless word-at-a-time unpack + LUT (§Perf: ~3x over the
    /// original per-element bit/branch decode).
    fn decode_codes(bytes: &[u8], n: usize, d: f32, out: &mut [f32]) {
        let lut = Self::value_lut(d);
        let base = &bytes[..n / 4];
        let sel = &bytes[n / 4..n / 4 + n / 8];
        // 8 codes per base byte-pair, 8 sel bits per sel byte: process 8
        // elements per iteration from one u16 of codes and one u8 of sel.
        for g in 0..n / 8 {
            let codes = u16::from_le_bytes([base[2 * g], base[2 * g + 1]]) as usize;
            let s = sel[g] as usize;
            let o = &mut out[g * 8..g * 8 + 8];
            for j in 0..8 {
                let idx = ((codes >> (2 * j)) & 3) | (((s >> j) & 1) << 2);
                o[j] = lut[idx];
            }
        }
    }
}

impl Format for Itq3S {
    fn name(&self) -> &'static str {
        "itq3_s"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // 3 bits/weight of planes + d + z.
        self.n * 3 / 8 + 4
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        let mut rot = w.to_vec();
        fwht::fwht_inplace(&mut rot);
        // Round z and d through f16 *before* coding so encode and decode
        // use the identical grid (both are stored as f16).
        let z = crate::f16::f16_round(crate::util::stats::mean(&rot) as f32);
        for v in rot.iter_mut() {
            *v -= z;
        }
        let d = crate::f16::f16_round(ternary::block_scale_dual(&rot)).max(1e-8);
        Self::encode_codes(&rot, d, out);
        push_f16(out, d);
        push_f16(out, z);
    }

    fn dequantize_block_raw(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        assert_eq!(out.len(), self.n);
        let d = read_f16(bytes, self.n * 3 / 8);
        let z = read_f16(bytes, self.n * 3 / 8 + 2);
        Self::decode_codes(bytes, self.n, d, out);
        for v in out.iter_mut() {
            *v += z;
        }
    }

    fn dequantize_block(&self, idx: u64, bytes: &[u8], out: &mut [f32]) {
        self.dequantize_block_raw(idx, bytes, out);
        // Inverse rotation (H is an involution) — paper Alg 2 step 6-12.
        if self.n == 256 {
            fwht::fwht_256(out.try_into().unwrap());
        } else {
            fwht::ifwht_inplace(out);
        }
    }

    fn rotate_activation_block(&self, _idx: u64, x: &mut [f32]) {
        // dot(Hw, Hx) == dot(w, x): rotate the activation once instead of
        // inverse-rotating every weight block that touches it.
        if x.len() == 256 {
            fwht::fwht_256(x.try_into().unwrap());
        } else {
            fwht::fwht_inplace(x);
        }
    }

    fn is_rotated(&self) -> bool {
        true
    }

    fn grid_step(&self, bytes: &[u8]) -> Option<f32> {
        debug_assert_eq!(bytes.len(), self.block_bytes());
        Some(read_f16(bytes, self.n * 3 / 8))
    }

    /// Single-pass fused dot: unpack -> LUT -> FMA without materializing
    /// the block (the MMVQ hot loop; paper §5.4). The zero-point term
    /// factors out: `dot = Σ lut[c_i]·x_i + z·Σ x_i`.
    fn dot_block_raw(
        &self,
        _idx: u64,
        bytes: &[u8],
        x: &[f32],
        x_sum: f32,
        _scratch: &mut Vec<f32>,
    ) -> f32 {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(x.len(), n);
        let d = read_f16(bytes, n * 3 / 8);
        let z = read_f16(bytes, n * 3 / 8 + 2);
        let lut = Self::value_lut(d);
        let base = &bytes[..n / 4];
        let sel = &bytes[n / 4..n * 3 / 8];
        let mut acc = [0.0f32; 2];
        for g in 0..n / 8 {
            let codes = u16::from_le_bytes([base[2 * g], base[2 * g + 1]]) as usize;
            let s = sel[g] as usize;
            let xs = &x[g * 8..g * 8 + 8];
            // Two interleaved accumulators break the FMA dependency chain.
            for j in 0..8 {
                let idx = ((codes >> (2 * j)) & 3) | (((s >> j) & 1) << 2);
                acc[j & 1] += lut[idx] * xs[j];
            }
        }
        // Zero-point term via the precomputed activation sum (O(1)).
        acc[0] + acc[1] + z * x_sum
    }

    fn has_q8_kernel(&self) -> bool {
        true
    }

    /// W3A8 integer fused dot (the DP4A analog, §5.4): the 2-bit ternary
    /// digits + selector bits decode to i8 levels `{0,±1,±3}` which
    /// multiply-accumulate in i32 against the i8 activation codes; the
    /// grid step `d` and activation scale fold into one final f32
    /// multiply, and the zero-point term reuses the precomputed code
    /// sum. Two phases — scalar unpack into an aligned i8 block, then
    /// the runtime-dispatched [`super::simd::dot_i8`] (scalar tier =
    /// [`super::act::dot_i8`] verbatim; all tiers bit-identical).
    /// Worst-case |acc| = n·3·127·127 ≈ 2.5e7 at n=512: no i32 overflow.
    fn dot_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        act: super::act::ActBlock<'_>,
        _scratch: &mut Vec<f32>,
    ) -> f32 {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(act.codes.len(), n);
        let d = read_f16(bytes, n * 3 / 8);
        let z = read_f16(bytes, n * 3 / 8 + 2);
        let mut lv = crate::util::align::AlignedBlockI8::zeroed();
        let lv = &mut lv.0[..n];
        ternary::unpack_dual_ternary_levels(&bytes[..n / 4], &bytes[n / 4..n * 3 / 8], lv);
        let acc = super::simd::dot_i8(lv, act.codes);
        acc as f32 * (d * act.scale) + z * (act.scale * act.sum as f32)
    }

    /// Batched W3A8 fused dot: the 3-bit planes are unpacked to i8
    /// levels **once**, then dotted against every activation column —
    /// the weights-stationary amortization the batched decode path is
    /// built on. Per column the final expression is literally
    /// [`Format::dot_block_q8`]'s, so each `y[t]` increment is
    /// bit-identical to the sequential path.
    fn gemm_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        acts: super::act::BatchBlock<'_>,
        y: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(acts.block, n);
        debug_assert_eq!(y.len(), acts.cols());
        let d = read_f16(bytes, n * 3 / 8);
        let z = read_f16(bytes, n * 3 / 8 + 2);
        let mut lv = crate::util::align::AlignedBlockI8::zeroed();
        let lv = &mut lv.0[..n];
        ternary::unpack_dual_ternary_levels(&bytes[..n / 4], &bytes[n / 4..n * 3 / 8], lv);
        for (t, yo) in y.iter_mut().enumerate() {
            let ab = acts.col(t);
            let acc = super::simd::dot_i8(lv, ab.codes);
            *yo += acc as f32 * (d * ab.scale) + z * (ab.scale * ab.sum as f32);
        }
    }
}

/// ITQ3_S sub-scale variant (paper §4.1 "Sub-block scales"): adds eight
/// per-32-element f16 scale refinements, 3.625 bits/weight at n=256.
pub struct Itq3SSub {
    n: usize,
    sub: usize,
}

impl Itq3SSub {
    pub fn new() -> Self {
        Itq3SSub { n: 256, sub: 32 }
    }

    fn nsub(&self) -> usize {
        self.n / self.sub
    }
}

impl Default for Itq3SSub {
    fn default() -> Self {
        Self::new()
    }
}

impl Format for Itq3SSub {
    fn name(&self) -> &'static str {
        "itq3_s_sub"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // planes + d + z + 8 sub-scale f16s = 96 + 4 + 16 = 116 @ n=256.
        self.n * 3 / 8 + 4 + 2 * self.nsub()
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        let mut rot = w.to_vec();
        fwht::fwht_inplace(&mut rot);
        let z = crate::f16::f16_round(crate::util::stats::mean(&rot) as f32);
        for v in rot.iter_mut() {
            *v -= z;
        }
        let d = crate::f16::f16_round(ternary::block_scale_dual(&rot)).max(1e-8);
        // Per-sub-block refinement factor, quantized to f16.
        let mut subs = Vec::with_capacity(self.nsub());
        for chunk in rot.chunks_exact(self.sub) {
            let ds = ternary::block_scale_dual(chunk);
            subs.push(crate::f16::f16_round((ds / d).clamp(0.25, 4.0)));
        }
        // Code each sub-block against its refined step.
        let mut codes = vec![0u8; self.n];
        let mut sel = vec![false; self.n];
        for (s, chunk) in rot.chunks_exact(self.sub).enumerate() {
            let ds = d * subs[s];
            for (j, &v) in chunk.iter().enumerate() {
                let (digit, coarse) = ternary::dual_ternary_digit(v, ds);
                codes[s * self.sub + j] = (digit + 1) as u8;
                sel[s * self.sub + j] = coarse;
            }
        }
        pack_2bit(&codes, out);
        pack_bits(&sel, out);
        push_f16(out, d);
        push_f16(out, z);
        for &f in &subs {
            push_f16(out, f);
        }
    }

    fn dequantize_block_raw(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let planes = self.n * 3 / 8;
        let d = read_f16(bytes, planes);
        let z = read_f16(bytes, planes + 2);
        let base = &bytes[..self.n / 4];
        let sel = &bytes[self.n / 4..planes];
        for s in 0..self.nsub() {
            let ds = d * read_f16(bytes, planes + 4 + 2 * s);
            for j in 0..self.sub {
                let i = s * self.sub + j;
                let code = (base[i / 4] >> ((i % 4) * 2)) & 0x3;
                let coarse = get_bit(sel, i);
                out[i] = ternary::dual_ternary_value(code as i8 - 1, coarse, ds) + z;
            }
        }
    }

    fn dequantize_block(&self, idx: u64, bytes: &[u8], out: &mut [f32]) {
        self.dequantize_block_raw(idx, bytes, out);
        fwht::fwht_256(out.try_into().unwrap());
    }

    fn rotate_activation_block(&self, _idx: u64, x: &mut [f32]) {
        fwht::fwht_256(x.try_into().unwrap());
    }

    fn is_rotated(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::thm2_bound_l2sq;
    use crate::util::prop::forall;
    use crate::util::{stats, XorShift};

    fn roundtrip(fmt: &dyn Format, w: &[f32]) -> Vec<f32> {
        let mut bytes = Vec::new();
        fmt.quantize_block(0, w, &mut bytes);
        assert_eq!(bytes.len(), fmt.block_bytes());
        let mut out = vec![0.0f32; w.len()];
        fmt.dequantize_block(0, &bytes, &mut out);
        out
    }

    #[test]
    fn bits_per_weight() {
        assert_eq!(Itq3S::new(256).bits_per_weight(), 3.125);
        assert_eq!(Itq3SSub::new().bits_per_weight(), 3.625);
        // Smaller rotation blocks amortize metadata worse (Table 3).
        assert!(Itq3S::new(32).bits_per_weight() > Itq3S::new(256).bits_per_weight());
    }

    #[test]
    fn roundtrip_reconstruction_error_small_on_gaussian() {
        let mut rng = XorShift::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.03).collect();
        let fmt = Itq3S::new(256);
        let out = roundtrip(&fmt, &w);
        let rel = stats::rel_l2_err(&w, &out);
        // Dual-ternary on a Gaussian has MSE ≈ 0.29 σ² → rel ≈ 0.54.
        assert!(rel < 0.62, "rel={rel}");
    }

    #[test]
    fn sub_variant_at_least_as_good() {
        let mut rng = XorShift::new(2);
        let mut worse = 0;
        for _ in 0..30 {
            let w: Vec<f32> =
                (0..256).map(|_| rng.next_student_t(4.0) as f32 * 0.02).collect();
            let base = stats::mse(&w, &roundtrip(&Itq3S::new(256), &w));
            let sub = stats::mse(&w, &roundtrip(&Itq3SSub::new(), &w));
            if sub > base * 1.02 {
                worse += 1;
            }
        }
        assert!(worse <= 6, "sub variant worse on {worse}/30 heavy-tailed blocks");
    }

    #[test]
    fn rotation_beats_no_rotation_on_outlier_blocks() {
        // The core claim: on blocks with planted outliers, ITQ3_S (with
        // FWHT) reconstructs much better than the identical grid applied
        // in the raw domain (= IQ3_S-style).
        let mut rng = XorShift::new(3);
        let mut wins = 0;
        let trials = 50;
        for _ in 0..trials {
            let mut w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
            let oi = (rng.next_below(256)) as usize;
            w[oi] = 0.5 * rng.next_sign(); // 25-sigma outlier
            let rot = stats::mse(&w, &roundtrip(&Itq3S::new(256), &w));
            let raw = stats::mse(&w, &roundtrip(&crate::quant::iq3s::Iq3S::new(), &w));
            if rot < raw {
                wins += 1;
            }
        }
        assert!(wins >= 40, "rotation won only {wins}/{trials}");
    }

    #[test]
    fn thm2_bound_holds() {
        // ‖ŵ−w‖² ≤ n·(3d)²/4·(grid clamp caveat) — we assert the paper's
        // bound with the dual-grid step: max per-element error inside the
        // representable range is d/2 (fine region) or d (between d..3d),
        // and the isometry transfers it through H⁻¹ exactly.
        forall("Theorem 2 reconstruction bound", 40, |g| {
            let w = g.weight_block(256);
            let fmt = Itq3S::new(256);
            let mut bytes = Vec::new();
            fmt.quantize_block(0, &w, &mut bytes);
            let mut out = vec![0.0f32; 256];
            fmt.dequantize_block(0, &bytes, &mut out);
            let d = read_f16(&bytes, 96) as f64;
            let err_sq: f64 = w
                .iter()
                .zip(&out)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            let bound = thm2_bound_l2sq(&w, d, 256);
            assert!(err_sq <= bound * 1.01 + 1e-9, "err²={err_sq} bound={bound}");
        });
    }

    #[test]
    fn grid_step_reads_the_stored_d() {
        // The weight audit reads `d` back out of packed blocks through
        // `Format::grid_step`; it must agree with the layout the bound
        // test above reads by offset. The sub-scale variant opts out
        // (its per-sub-block refinement voids the single-step bound).
        let mut rng = XorShift::new(6);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        let fmt = Itq3S::new(256);
        let mut bytes = Vec::new();
        fmt.quantize_block(0, &w, &mut bytes);
        assert_eq!(fmt.grid_step(&bytes), Some(read_f16(&bytes, 96)));
        assert!(fmt.grid_step(&bytes).unwrap() > 0.0);
        let mut sub_bytes = Vec::new();
        Itq3SSub::new().quantize_block(0, &w, &mut sub_bytes);
        assert_eq!(Format::grid_step(&Itq3SSub::new(), &sub_bytes), None);
    }

    #[test]
    fn all_block_sizes_roundtrip() {
        let mut rng = XorShift::new(4);
        for &n in &[32usize, 64, 128, 256, 512] {
            let w: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32 * 0.05).collect();
            let fmt = Itq3S::new(n);
            let out = roundtrip(&fmt, &w);
            let rel = stats::rel_l2_err(&w, &out);
            assert!(rel < 0.8, "n={n} rel={rel}");
        }
    }

    #[test]
    fn raw_plus_activation_rotation_equals_full_dequant_dot() {
        // The fast-path identity: dot(raw(q), H x) == dot(dequant(q), x).
        forall("fused rotation identity", 60, |g| {
            let w = g.weight_block(256);
            let x = g.vec_f32(256, -1.0, 1.0);
            let fmt = Itq3S::new(256);
            let mut bytes = Vec::new();
            fmt.quantize_block(0, &w, &mut bytes);

            let mut full = vec![0.0f32; 256];
            fmt.dequantize_block(0, &bytes, &mut full);
            let slow: f64 = full.iter().zip(&x).map(|(&a, &b)| (a * b) as f64).sum();

            let mut raw = vec![0.0f32; 256];
            fmt.dequantize_block_raw(0, &bytes, &mut raw);
            let mut xr = x.clone();
            fmt.rotate_activation_block(0, &mut xr);
            let fast: f64 = raw.iter().zip(&xr).map(|(&a, &b)| (a * b) as f64).sum();

            assert!((slow - fast).abs() <= 1e-3 * slow.abs().max(1.0), "{slow} vs {fast}");
        });
    }

    #[test]
    fn deterministic_encoding() {
        let mut rng = XorShift::new(5);
        let w: Vec<f32> = (0..256).map(|_| rng.next_f32() - 0.5).collect();
        let fmt = Itq3S::new(256);
        let mut a = Vec::new();
        let mut b = Vec::new();
        fmt.quantize_block(7, &w, &mut a);
        fmt.quantize_block(7, &w, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn zero_block_roundtrips_to_zero() {
        let w = vec![0.0f32; 256];
        let out = roundtrip(&Itq3S::new(256), &w);
        for &x in &out {
            assert!(x.abs() < 1e-6);
        }
    }
}
