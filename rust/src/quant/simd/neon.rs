//! NEON backend: exact 16-lane i8·i8 → i32 dot products.
//!
//! Exactness argument: `vmull_s8` widens i8×i8 products to i16 with no
//! rounding (|p| ≤ 128·127 < i16::MAX per lane), and `vpadalq_s16`
//! pairwise-adds those i16 lanes into i32 accumulators with a
//! non-saturating widening add. Every operation is an exact integer op,
//! so any regrouping matches the scalar oracle bit-for-bit (i32 sums
//! stay ≤ ~2.5e7 by the kernels' documented block bounds). The `xsum`
//! companion widens activation codes alone via `vpaddlq_s8` — same
//! argument with smaller magnitudes.
use std::arch::aarch64::*;

/// Exact i8 dot product; bit-identical to
/// [`crate::quant::act::dot_i8`].
///
/// # Safety
/// Caller must ensure NEON is available (mandatory on aarch64; the
/// dispatch table in [`super`] only routes here on that arch).
#[target_feature(enable = "neon")]
pub unsafe fn dot_i8(w: &[i8], x: &[i8]) -> i32 {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let chunks = n / 16;
    let mut acc = vdupq_n_s32(0);
    for i in 0..chunks {
        let vw = vld1q_s8(w.as_ptr().add(16 * i));
        let vx = vld1q_s8(x.as_ptr().add(16 * i));
        let lo = vmull_s8(vget_low_s8(vw), vget_low_s8(vx)); // exact i16
        let hi = vmull_high_s8(vw, vx);
        acc = vpadalq_s16(acc, lo); // widening pairwise add, exact
        acc = vpadalq_s16(acc, hi);
    }
    let mut s = vaddvq_s32(acc);
    for j in 16 * chunks..n {
        s += w[j] as i32 * x[j] as i32;
    }
    s
}

/// Exact fused `(Σ w·x, Σ x)`; bit-identical to
/// [`super::dot_i8_xsum_scalar`].
///
/// # Safety
/// Same precondition as [`dot_i8`].
#[target_feature(enable = "neon")]
pub unsafe fn dot_i8_xsum(w: &[i8], x: &[i8]) -> (i32, i32) {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let chunks = n / 16;
    let mut acc_dot = vdupq_n_s32(0);
    let mut acc_sum = vdupq_n_s32(0);
    for i in 0..chunks {
        let vw = vld1q_s8(w.as_ptr().add(16 * i));
        let vx = vld1q_s8(x.as_ptr().add(16 * i));
        let lo = vmull_s8(vget_low_s8(vw), vget_low_s8(vx));
        let hi = vmull_high_s8(vw, vx);
        acc_dot = vpadalq_s16(acc_dot, lo);
        acc_dot = vpadalq_s16(acc_dot, hi);
        acc_sum = vpadalq_s16(acc_sum, vpaddlq_s8(vx)); // Σx, exact widening
    }
    let mut d = vaddvq_s32(acc_dot);
    let mut s = vaddvq_s32(acc_sum);
    for j in 16 * chunks..n {
        d += w[j] as i32 * x[j] as i32;
        s += x[j] as i32;
    }
    (d, s)
}
