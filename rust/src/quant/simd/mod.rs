//! Runtime-dispatched SIMD integer kernels (§5.4 DP4A analog, CPU side).
//!
//! The W3A8 hot loop is integer end-to-end: decoded i8 weight lanes ×
//! clamped i8 activation codes accumulated in i32, with every f32 scale
//! folded into a single epilogue multiply *outside* this module. Integer
//! addition is associative, so any lane-width regrouping of the i32
//! multiply-accumulate is **bit-identical** to the scalar loop — which is
//! the repo's contract: the SIMD tiers below are not "close to" the
//! scalar kernel, they are required to produce the same bits, and
//! `tests/simd_parity.rs` plus the in-module property tests enforce it
//! differentially (scalar kernel = oracle, exactly as the generic f32
//! fallback is the oracle for the scalar kernels one level up).
//!
//! Dispatch model:
//! * [`detected_tier`] probes the CPU once (`OnceLock`): AVX2 on x86_64
//!   via `is_x86_feature_detected!`, NEON on aarch64 (baseline,
//!   mandatory), scalar otherwise.
//! * `ITQ3S_NO_SIMD` (set and not `"0"`/empty) is a hard kill switch: it
//!   makes every non-scalar tier unavailable, so both the detection and
//!   [`try_force`] land on scalar — the CI matrix runs the whole suite
//!   once with it set and the suite must pass identically.
//! * `--no-simd` (CLI) routes to [`set_enabled`], an in-process override
//!   on top of detection.
//! * [`try_force`] / [`clear_force`] are the test hooks the differential
//!   harness uses to pin a tier; forcing an unavailable tier fails
//!   instead of silently falling back, so a bad probe cannot hide.
//! * Probe counters (enabled only between [`probe_begin`] /
//!   [`probe_end`]) count dispatched calls per tier, letting the harness
//!   assert that the tier it forced is the tier that actually ran.
//!
//! Because the tiers are bit-identical, flipping the override while
//! other threads compute cannot change any result — only the probe
//! counters are order-sensitive, and the harness serializes around them.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// One dispatch tier of the integer kernels. All tiers are bit-identical
/// by contract; they differ only in throughput.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum SimdTier {
    /// The scalar i32 loops in [`crate::quant::act::dot_i8`] — the
    /// differential oracle, kept verbatim from the original kernels.
    Scalar = 0,
    /// x86_64 AVX2 (`maddubs`/`madd` 32-lane i8 dot).
    Avx2 = 1,
    /// aarch64 NEON (`smull`/`sadalp` 16-lane i8 dot).
    Neon = 2,
}

impl SimdTier {
    pub const ALL: [SimdTier; 3] = [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon];

    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Stable index into the probe-counter array ([`probe_end`]).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// `ITQ3S_NO_SIMD` kill switch, read once. Any non-empty value other
/// than `"0"` disables every non-scalar tier for the whole process.
fn env_disabled() -> bool {
    static ENV_DISABLED: OnceLock<bool> = OnceLock::new();
    *ENV_DISABLED.get_or_init(|| {
        matches!(std::env::var("ITQ3S_NO_SIMD"), Ok(v) if !v.is_empty() && v != "0")
    })
}

/// Whether `tier` can run on this host *right now* (CPU capability and
/// the `ITQ3S_NO_SIMD` kill switch both considered). Scalar is
/// always available.
pub fn tier_available(tier: SimdTier) -> bool {
    match tier {
        SimdTier::Scalar => true,
        SimdTier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                !env_disabled() && std::arch::is_x86_feature_detected!("avx2")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        SimdTier::Neon => {
            // NEON (ASIMD) is mandatory in AArch64; presence of the
            // target_arch is the feature probe.
            #[cfg(target_arch = "aarch64")]
            {
                !env_disabled()
            }
            #[cfg(not(target_arch = "aarch64"))]
            {
                false
            }
        }
    }
}

/// Best available tier, probed once and cached.
pub fn detected_tier() -> SimdTier {
    static DETECTED: OnceLock<SimdTier> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if tier_available(SimdTier::Avx2) {
            SimdTier::Avx2
        } else if tier_available(SimdTier::Neon) {
            SimdTier::Neon
        } else {
            SimdTier::Scalar
        }
    })
}

// In-process override on top of detection: 0 = follow detected tier,
// 1/2/3 = force scalar/avx2/neon. Relaxed ordering is sufficient —
// whichever tier a racing reader picks, the numerics are identical.
const FOLLOW: u8 = 0;
static OVERRIDE: AtomicU8 = AtomicU8::new(FOLLOW);

fn force_code(tier: SimdTier) -> u8 {
    tier as u8 + 1
}

/// The tier the next dispatched call will take.
pub fn active_tier() -> SimdTier {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdTier::Scalar,
        2 => SimdTier::Avx2,
        3 => SimdTier::Neon,
        _ => detected_tier(),
    }
}

/// CLI plumbing for `--no-simd`: `false` pins the scalar tier, `true`
/// returns to detection.
pub fn set_enabled(enabled: bool) {
    OVERRIDE.store(
        if enabled { FOLLOW } else { force_code(SimdTier::Scalar) },
        Ordering::Relaxed,
    );
}

/// Pin dispatch to `tier`. Returns `false` (and changes nothing) if the
/// tier is unavailable on this host — the differential harness uses that
/// to self-skip instead of silently testing scalar against itself.
pub fn try_force(tier: SimdTier) -> bool {
    if !tier_available(tier) {
        return false;
    }
    OVERRIDE.store(force_code(tier), Ordering::Relaxed);
    true
}

/// Undo [`try_force`] / [`set_enabled`]: follow detection again.
pub fn clear_force() {
    OVERRIDE.store(FOLLOW, Ordering::Relaxed);
}

// Probe counters: per-tier dispatched-call counts, live only while a
// probe window is open. The flag check is one relaxed load on the hot
// path when no probe is running.
static PROBING: AtomicBool = AtomicBool::new(false);
static CALLS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Reset the per-tier call counters and start counting.
pub fn probe_begin() {
    for c in &CALLS {
        c.store(0, Ordering::Relaxed);
    }
    PROBING.store(true, Ordering::Relaxed);
}

/// Stop counting and return calls per tier, indexed by
/// [`SimdTier::index`].
pub fn probe_end() -> [u64; 3] {
    PROBING.store(false, Ordering::Relaxed);
    [
        CALLS[0].load(Ordering::Relaxed),
        CALLS[1].load(Ordering::Relaxed),
        CALLS[2].load(Ordering::Relaxed),
    ]
}

#[inline]
fn note(tier: SimdTier) {
    if PROBING.load(Ordering::Relaxed) {
        CALLS[tier.index()].fetch_add(1, Ordering::Relaxed);
    }
}

/// Reinterpret packed weight bytes as i8 lanes (same size/alignment;
/// two's-complement reinterpret is exactly the `byte as i8` the scalar
/// kernels perform per element). Lets `q8_0` feed its stored codes to
/// the dispatched dot without a copy.
#[inline]
pub fn bytes_as_i8(bytes: &[u8]) -> &[i8] {
    // SAFETY: u8 and i8 have identical size, alignment, and validity.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
}

/// Dispatched exact i8·i8 → i32 dot product. Bit-identical across tiers
/// (i32 accumulation is exact; see module docs), scalar tier is
/// [`crate::quant::act::dot_i8`] verbatim.
///
/// `x` must hold activation codes clamped to ±127 (guaranteed by
/// `quantize_block_q8`); the AVX2 tier's `maddubs` exactness bound
/// depends on it.
#[inline]
pub fn dot_i8(w: &[i8], x: &[i8]) -> i32 {
    let tier = active_tier();
    note(tier);
    match tier {
        SimdTier::Scalar => super::act::dot_i8(w, x),
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::dot_i8(w, x) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::dot_i8(w, x) },
        // A tier this build has no backend for can only be reached if
        // the probe lied; fall back to the oracle rather than UB.
        #[allow(unreachable_patterns)]
        _ => super::act::dot_i8(w, x),
    }
}

/// Dispatched fused `(Σ w·x, Σ x)` in one pass — the q4_k_m inner loop,
/// which needs the raw activation-code sum per sub-block for its minima
/// term. Same bit-identity contract as [`dot_i8`].
#[inline]
pub fn dot_i8_xsum(w: &[i8], x: &[i8]) -> (i32, i32) {
    let tier = active_tier();
    note(tier);
    match tier {
        SimdTier::Scalar => dot_i8_xsum_scalar(w, x),
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::dot_i8_xsum(w, x) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::dot_i8_xsum(w, x) },
        #[allow(unreachable_patterns)]
        _ => dot_i8_xsum_scalar(w, x),
    }
}

/// Scalar oracle for [`dot_i8_xsum`]: the exact integer arithmetic the
/// q4_k_m kernels performed inline before dispatch existed (i32 sums are
/// order-insensitive, so the 4-accumulator layout mirrors
/// [`crate::quant::act::dot_i8`] without changing any result).
#[inline]
pub fn dot_i8_xsum_scalar(w: &[i8], x: &[i8]) -> (i32, i32) {
    debug_assert_eq!(w.len(), x.len());
    let mut dot = [0i32; 4];
    let mut sum = [0i32; 4];
    let chunks = w.len() / 4;
    for i in 0..chunks {
        let j = 4 * i;
        dot[0] += w[j] as i32 * x[j] as i32;
        dot[1] += w[j + 1] as i32 * x[j + 1] as i32;
        dot[2] += w[j + 2] as i32 * x[j + 2] as i32;
        dot[3] += w[j + 3] as i32 * x[j + 3] as i32;
        sum[0] += x[j] as i32;
        sum[1] += x[j + 1] as i32;
        sum[2] += x[j + 2] as i32;
        sum[3] += x[j + 3] as i32;
    }
    let mut d = dot[0] + dot[1] + dot[2] + dot[3];
    let mut s = sum[0] + sum[1] + sum[2] + sum[3];
    for j in chunks * 4..w.len() {
        d += w[j] as i32 * x[j] as i32;
        s += x[j] as i32;
    }
    (d, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_indexed;
    use std::sync::Mutex;

    // In-module tests that pin a tier serialize among themselves; tests
    // elsewhere in the lib binary may race a tier flip, but bit-identity
    // makes that observationally irrelevant (probe counters, the only
    // order-sensitive state, are asserted solely in tests/simd_parity.rs,
    // a separate process).
    static FORCE: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        FORCE.lock().unwrap_or_else(|e| e.into_inner())
    }

    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            clear_force();
        }
    }

    /// Adversarial i8 lane patterns: zeros, ±127 alternation (max
    /// cancellation), all +127 vs all ±127 (monotone accumulator — the
    /// maddubs pair bound 2·127² and the i16-widening worst case), and
    /// a -128 weight edge (activations never hold -128, weights may).
    fn lanes(case: u64, n: usize, g: &mut crate::util::prop::Gen) -> (Vec<i8>, Vec<i8>) {
        let w: Vec<i8> = match case % 5 {
            0 => vec![0; n],
            1 => (0..n).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect(),
            2 => vec![127; n],
            3 => (0..n).map(|i| if i % 3 == 0 { -128 } else { 127 }).collect(),
            _ => (0..n).map(|_| g.usize_in(0, 255) as i64 as i8).collect(),
        };
        let x: Vec<i8> = match case % 3 {
            0 => (0..n).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect(),
            1 => vec![127; n],
            _ => (0..n)
                .map(|_| (g.usize_in(0, 254) as i64 - 127) as i8)
                .collect(),
        };
        (w, x)
    }

    #[test]
    fn scalar_xsum_matches_naive_reference() {
        forall_indexed("xsum scalar == naive", 32, |case, g| {
            let n = g.usize_in(0, 96);
            let (w, x) = lanes(case, n, g);
            let (d, s) = dot_i8_xsum_scalar(&w, &x);
            let dn: i32 = w.iter().zip(&x).map(|(&a, &b)| a as i32 * b as i32).sum();
            let sn: i32 = x.iter().map(|&b| b as i32).sum();
            assert_eq!((d, s), (dn, sn));
        });
    }

    #[test]
    fn every_available_tier_is_bitwise_equal_to_scalar() {
        let _g = lock();
        let _r = Restore;
        let tiers: Vec<SimdTier> = [SimdTier::Avx2, SimdTier::Neon]
            .into_iter()
            .filter(|&t| tier_available(t))
            .collect();
        if tiers.is_empty() {
            eprintln!("no SIMD tier available on this host; scalar-only — skipping");
            return;
        }
        // Lengths straddle every vector width boundary (32-lane AVX2,
        // 16-lane NEON) plus the scalar tail.
        for n in [0usize, 1, 3, 7, 15, 16, 17, 31, 32, 33, 63, 64, 96, 255, 256, 512] {
            forall_indexed(&format!("simd dot == scalar [n={n}]"), 12, |case, g| {
                let (w, x) = lanes(case, n, g);
                assert!(try_force(SimdTier::Scalar));
                let want = dot_i8(&w, &x);
                let want2 = dot_i8_xsum(&w, &x);
                for &t in &tiers {
                    assert!(try_force(t));
                    assert_eq!(dot_i8(&w, &x), want, "{t:?} dot n={n} case={case}");
                    assert_eq!(dot_i8_xsum(&w, &x), want2, "{t:?} xsum n={n} case={case}");
                }
            });
        }
    }

    #[test]
    fn force_and_enable_override_detection() {
        let _g = lock();
        let _r = Restore;
        assert!(try_force(SimdTier::Scalar), "scalar must always force");
        assert_eq!(active_tier(), SimdTier::Scalar);
        clear_force();
        assert_eq!(active_tier(), detected_tier());
        set_enabled(false);
        assert_eq!(active_tier(), SimdTier::Scalar);
        set_enabled(true);
        assert_eq!(active_tier(), detected_tier());
        // Forcing an unavailable tier must fail and leave dispatch alone.
        for t in SimdTier::ALL {
            if !tier_available(t) {
                assert!(!try_force(t));
                assert_eq!(active_tier(), detected_tier());
            }
        }
    }

    #[test]
    fn bytes_reinterpret_matches_per_element_cast() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        let lanes = bytes_as_i8(&bytes);
        assert_eq!(lanes.len(), bytes.len());
        for (i, &b) in bytes.iter().enumerate() {
            assert_eq!(lanes[i], b as i8);
        }
    }
}
