//! AVX2 backend: exact 32-lane i8·i8 → i32 dot products.
//!
//! Exactness argument (why `maddubs`' saturating i16 adds never
//! saturate, making the whole pipeline bit-identical to the scalar
//! oracle):
//!
//! * `_mm256_maddubs_epi16(a, b)` computes `a[2j]·b[2j] + a[2j+1]·b[2j+1]`
//!   per i16 lane with **unsigned** `a` and signed `b`, saturating. We
//!   feed it `a = |w|` (via `_mm256_sign_epi8(w, w)`, so `a ≤ 128`) and
//!   `b = sign(w)·x` (via `_mm256_sign_epi8(x, w)`). Activation codes
//!   are clamped to ±127 by `quantize_block_q8`, so `|b| ≤ 127` always
//!   (sign-flipping x never overflows because x is never -128), and each
//!   pair sum is bounded by `2·128·127 = 32512 < i16::MAX` — no
//!   saturation, every lane exact. A weight lane of -128 maps to
//!   `a = 128` (the unsigned side, where 128 is representable) and its
//!   product term `128·|x| ≤ 16256`, still inside the bound.
//! * `_mm256_madd_epi16(·, 1)` widens the exact i16 pairs to i32 with a
//!   non-saturating add; i32 accumulation is exact by the kernels'
//!   documented magnitude bounds (≤ n·3·127·127 ≈ 2.5e7 for the widest
//!   block — 77x under i32::MAX).
//! * Lane regrouping changes only the order of exact i32 additions,
//!   which is associative — same bits as the scalar loop.
//!
//! The `xsum` companion feeds `maddubs` the constant `1` as its unsigned
//! side (pair sums bounded by 254), same argument.
use std::arch::x86_64::*;

/// Horizontal i32 sum of one 256-bit accumulator (exact adds only).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256::<1>(v);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s)); // swap 64-bit halves
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s)); // swap 32-bit pairs
    _mm_cvtsi128_si32(s)
}

/// Exact i8 dot product; bit-identical to
/// [`crate::quant::act::dot_i8`].
///
/// # Safety
/// Caller must ensure AVX2 is available (the dispatch table in
/// [`super`] guarantees it) and that `x` holds activation codes in
/// ±127 (see module docs).
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i8(w: &[i8], x: &[i8]) -> i32 {
    debug_assert_eq!(w.len(), x.len());
    debug_assert!(
        x.iter().all(|&v| v != i8::MIN),
        "activation codes must be clamped to ±127"
    );
    let n = w.len();
    let chunks = n / 32;
    let mut acc = _mm256_setzero_si256();
    let ones = _mm256_set1_epi16(1);
    for i in 0..chunks {
        let vw = _mm256_loadu_si256(w.as_ptr().add(32 * i) as *const __m256i);
        let vx = _mm256_loadu_si256(x.as_ptr().add(32 * i) as *const __m256i);
        let aw = _mm256_sign_epi8(vw, vw); // |w| as u8 lanes
        let sx = _mm256_sign_epi8(vx, vw); // sign(w)·x, |·| ≤ 127
        let p16 = _mm256_maddubs_epi16(aw, sx); // exact: pairs ≤ 32512
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16, ones));
    }
    let mut s = hsum_epi32(acc);
    for j in 32 * chunks..n {
        s += w[j] as i32 * x[j] as i32;
    }
    s
}

/// Exact fused `(Σ w·x, Σ x)`; bit-identical to
/// [`super::dot_i8_xsum_scalar`].
///
/// # Safety
/// Same preconditions as [`dot_i8`].
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i8_xsum(w: &[i8], x: &[i8]) -> (i32, i32) {
    debug_assert_eq!(w.len(), x.len());
    debug_assert!(
        x.iter().all(|&v| v != i8::MIN),
        "activation codes must be clamped to ±127"
    );
    let n = w.len();
    let chunks = n / 32;
    let mut acc_dot = _mm256_setzero_si256();
    let mut acc_sum = _mm256_setzero_si256();
    let ones16 = _mm256_set1_epi16(1);
    let ones8 = _mm256_set1_epi8(1);
    for i in 0..chunks {
        let vw = _mm256_loadu_si256(w.as_ptr().add(32 * i) as *const __m256i);
        let vx = _mm256_loadu_si256(x.as_ptr().add(32 * i) as *const __m256i);
        let aw = _mm256_sign_epi8(vw, vw);
        let sx = _mm256_sign_epi8(vx, vw);
        let p16 = _mm256_maddubs_epi16(aw, sx);
        acc_dot = _mm256_add_epi32(acc_dot, _mm256_madd_epi16(p16, ones16));
        let s16 = _mm256_maddubs_epi16(ones8, vx); // x[2j]+x[2j+1], ≤ 254
        acc_sum = _mm256_add_epi32(acc_sum, _mm256_madd_epi16(s16, ones16));
    }
    let mut d = hsum_epi32(acc_dot);
    let mut s = hsum_epi32(acc_sum);
    for j in 32 * chunks..n {
        d += w[j] as i32 * x[j] as i32;
        s += x[j] as i32;
    }
    (d, s)
}
