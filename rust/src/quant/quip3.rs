//! QuIP#-3bit simulator (paper §2.4, §7.1; Table 1 row "QuIP#-3bit").
//!
//! QuIP# rotates weights with *random* orthogonal transforms before
//! quantizing. Its operative mechanism at block size ≤ 256 is the
//! incoherence induced by the rotation (the paper's own §7.1 argument),
//! so this simulator implements exactly that mechanism: a per-block
//! random sign diagonal `S` (seeded from the block ordinal — nothing to
//! store) followed by the deterministic FWHT, i.e. the randomized
//! Hadamard transform `H·S`, then the same dual-ternary 3-bit grid. No
//! zero-point is stored (QuIP# grids are symmetric), landing at
//! 3.0625 b/w vs. the paper's "3.0".
//!
//! What it deliberately omits (documented substitution, DESIGN.md §6):
//! QuIP#'s E8 lattice codebook — replaced by the scalar grid shared with
//! ITQ3_S so Table 1 isolates the rotation choice.

use super::packing::*;
use super::ternary;
use super::Format;
use crate::fwht;
use crate::util::XorShift;

pub struct Quip3 {
    n: usize,
    seed: u64,
}

impl Quip3 {
    pub fn new(seed: u64) -> Self {
        Quip3 { n: 256, seed }
    }

    /// The per-block sign diagonal, derived (never stored) from the
    /// global seed and block ordinal.
    fn signs(&self, block_idx: u64) -> Vec<f32> {
        let mut rng = XorShift::new(self.seed ^ block_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        (0..self.n).map(|_| rng.next_sign()).collect()
    }
}

impl Format for Quip3 {
    fn name(&self) -> &'static str {
        "quip3"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // planes (96) + d (2) = 98 @ n=256 -> 3.0625 b/w.
        self.n * 3 / 8 + 2
    }

    fn quantize_block(&self, idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        let s = self.signs(idx);
        let mut rot: Vec<f32> = w.iter().zip(&s).map(|(&x, &sg)| x * sg).collect();
        fwht::fwht_inplace(&mut rot);
        let d = crate::f16::f16_round(ternary::block_scale_dual(&rot)).max(1e-8);
        let mut codes = vec![0u8; self.n];
        let mut sel = vec![false; self.n];
        for (i, &v) in rot.iter().enumerate() {
            let (digit, coarse) = ternary::dual_ternary_digit(v, d);
            codes[i] = (digit + 1) as u8;
            sel[i] = coarse;
        }
        pack_2bit(&codes, out);
        pack_bits(&sel, out);
        push_f16(out, d);
    }

    fn dequantize_block_raw(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let d = read_f16(bytes, self.n * 3 / 8);
        let base = &bytes[..self.n / 4];
        let sel = &bytes[self.n / 4..self.n * 3 / 8];
        for i in 0..self.n {
            let code = (base[i / 4] >> ((i % 4) * 2)) & 0x3;
            let coarse = get_bit(sel, i);
            out[i] = ternary::dual_ternary_value(code as i8 - 1, coarse, d);
        }
    }

    fn dequantize_block(&self, idx: u64, bytes: &[u8], out: &mut [f32]) {
        self.dequantize_block_raw(idx, bytes, out);
        // Inverse of H·S is S·H (both H and S are involutions).
        fwht::fwht_256(out.try_into().unwrap());
        for (x, sg) in out.iter_mut().zip(self.signs(idx)) {
            *x *= sg;
        }
    }

    fn rotate_activation_block(&self, idx: u64, x: &mut [f32]) {
        // dot(HS w, HS x) == dot(w, x): sign-flip then rotate the
        // activation block with the same per-block transform.
        for (v, sg) in x.iter_mut().zip(self.signs(idx)) {
            *v *= sg;
        }
        fwht::fwht_256(x.try_into().unwrap());
    }

    fn is_rotated(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift as Rng};

    #[test]
    fn bits_per_weight() {
        assert!((Quip3::new(1).bits_per_weight() - 3.0625).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_error_comparable_to_itq3s() {
        let mut rng = Rng::new(1);
        let f = Quip3::new(0x51A5);
        let g = crate::quant::itq3s::Itq3S::new(256);
        let mut rel_q = 0.0;
        let mut rel_i = 0.0;
        for bi in 0..20u64 {
            let w: Vec<f32> = (0..256).map(|_| rng.next_student_t(4.0) as f32 * 0.02).collect();
            let mut bytes = Vec::new();
            f.quantize_block(bi, &w, &mut bytes);
            let mut out = vec![0.0f32; 256];
            f.dequantize_block(bi, &bytes, &mut out);
            rel_q += stats::rel_l2_err(&w, &out);
            bytes.clear();
            g.quantize_block(bi, &w, &mut bytes);
            g.dequantize_block(bi, &bytes, &mut out);
            rel_i += stats::rel_l2_err(&w, &out);
        }
        // Same rotation mechanism, so errors must be in the same ballpark;
        // the missing zero-point makes quip3 no better on average.
        assert!(rel_q < rel_i * 1.5, "quip3 {rel_q} vs itq3s {rel_i}");
        assert!(rel_q / 20.0 < 0.75);
    }

    #[test]
    fn per_block_signs_differ_but_are_deterministic() {
        let f = Quip3::new(7);
        assert_ne!(f.signs(0), f.signs(1));
        assert_eq!(f.signs(3), f.signs(3));
    }

    #[test]
    fn different_block_idx_decodes_with_matching_signs() {
        // Using the wrong block index must corrupt reconstruction —
        // i.e. the sign diagonal really participates.
        let mut rng = Rng::new(2);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.05).collect();
        let f = Quip3::new(9);
        let mut bytes = Vec::new();
        f.quantize_block(4, &w, &mut bytes);
        let mut good = vec![0.0f32; 256];
        let mut bad = vec![0.0f32; 256];
        f.dequantize_block(4, &bytes, &mut good);
        f.dequantize_block(5, &bytes, &mut bad);
        assert!(stats::rel_l2_err(&w, &good) < 0.8);
        assert!(stats::rel_l2_err(&w, &bad) > 0.9);
    }

    #[test]
    fn fused_rotation_identity() {
        let mut rng = Rng::new(3);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.03).collect();
        let x: Vec<f32> = (0..256).map(|_| rng.next_f32() - 0.5).collect();
        let f = Quip3::new(11);
        let mut bytes = Vec::new();
        f.quantize_block(2, &w, &mut bytes);
        let mut full = vec![0.0f32; 256];
        f.dequantize_block(2, &bytes, &mut full);
        let slow: f64 = full.iter().zip(&x).map(|(&a, &b)| (a * b) as f64).sum();
        let mut raw = vec![0.0f32; 256];
        f.dequantize_block_raw(2, &bytes, &mut raw);
        let mut xr = x.clone();
        f.rotate_activation_block(2, &mut xr);
        let fast: f64 = raw.iter().zip(&xr).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((slow - fast).abs() < 1e-3 * slow.abs().max(1.0));
    }
}
