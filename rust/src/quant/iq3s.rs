//! IQ3_S — the paper's 3-bit *baseline* (Table 1 row "IQ3_S"): the same
//! interleaved dual-ternary 3-bit grid as ITQ3_S but **no rotation**, plus
//! llama.cpp-style per-32 sub-scales (which is why the real IQ3_S sits at
//! ~3.5 b/w rather than 3.125). Outliers in the raw weight domain inflate
//! each sub-block's scale and waste grid levels — exactly the failure
//! mode §1 describes and the FWHT removes.

use super::packing::*;
use super::ternary;
use super::Format;

pub struct Iq3S {
    n: usize,
    sub: usize,
}

impl Iq3S {
    pub fn new() -> Self {
        Iq3S { n: 256, sub: 32 }
    }

    fn nsub(&self) -> usize {
        self.n / self.sub
    }
}

impl Default for Iq3S {
    fn default() -> Self {
        Self::new()
    }
}

impl Format for Iq3S {
    fn name(&self) -> &'static str {
        "iq3_s"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // planes (96) + z (2) + 8 sub-scale f16s (16) = 114 @ n=256
        // -> 3.5625 b/w, matching the paper's "3.5".
        self.n * 3 / 8 + 2 + 2 * self.nsub()
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        let z = crate::f16::f16_round(crate::util::stats::mean(w) as f32);
        let centered: Vec<f32> = w.iter().map(|&x| x - z).collect();
        let mut codes = vec![0u8; self.n];
        let mut sel = vec![false; self.n];
        let mut subs = Vec::with_capacity(self.nsub());
        for (s, chunk) in centered.chunks_exact(self.sub).enumerate() {
            let ds = crate::f16::f16_round(ternary::block_scale_dual(chunk)).max(1e-8);
            subs.push(ds);
            for (j, &v) in chunk.iter().enumerate() {
                let (digit, coarse) = ternary::dual_ternary_digit(v, ds);
                codes[s * self.sub + j] = (digit + 1) as u8;
                sel[s * self.sub + j] = coarse;
            }
        }
        pack_2bit(&codes, out);
        pack_bits(&sel, out);
        push_f16(out, z);
        for &ds in &subs {
            push_f16(out, ds);
        }
    }

    fn dequantize_block(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let planes = self.n * 3 / 8;
        let z = read_f16(bytes, planes);
        let base = &bytes[..self.n / 4];
        let sel = &bytes[self.n / 4..planes];
        for s in 0..self.nsub() {
            let ds = read_f16(bytes, planes + 2 + 2 * s);
            for j in 0..self.sub {
                let i = s * self.sub + j;
                let code = (base[i / 4] >> ((i % 4) * 2)) & 0x3;
                let coarse = get_bit(sel, i);
                out[i] = ternary::dual_ternary_value(code as i8 - 1, coarse, ds) + z;
            }
        }
    }

    /// Fused LUT dot (per-sub-block scale; zero-point factored out).
    fn dot_block_raw(
        &self,
        _idx: u64,
        bytes: &[u8],
        x: &[f32],
        x_sum: f32,
        _s: &mut Vec<f32>,
    ) -> f32 {
        let n = self.n;
        let planes = n * 3 / 8;
        let z = read_f16(bytes, planes);
        let base = &bytes[..n / 4];
        let sel = &bytes[n / 4..planes];
        let mut acc = [0.0f32; 2];
        for s in 0..self.nsub() {
            let ds = read_f16(bytes, planes + 2 + 2 * s);
            let lut = [-ds, 0.0, ds, 0.0, -3.0 * ds, 0.0, 3.0 * ds, 0.0];
            for g in 0..self.sub / 8 {
                let gi = s * self.sub / 8 + g;
                let codes = u16::from_le_bytes([base[2 * gi], base[2 * gi + 1]]) as usize;
                let sb = sel[gi] as usize;
                let xs = &x[gi * 8..gi * 8 + 8];
                for j in 0..8 {
                    let idx = ((codes >> (2 * j)) & 3) | (((sb >> j) & 1) << 2);
                    acc[j & 1] += lut[idx] * xs[j];
                }
            }
        }
        acc[0] + acc[1] + z * x_sum
    }

    fn has_q8_kernel(&self) -> bool {
        true
    }

    /// W3A8 integer fused dot: same ternary-level unpack as ITQ3_S but
    /// with the per-sub-block scale applied at the i32→f32 boundary of
    /// each 32-element sub-block; the global zero-point term reuses the
    /// precomputed activation code sum. Levels are unpacked once into
    /// an aligned i8 block and each sub-block runs through the
    /// runtime-dispatched [`super::simd::dot_i8`] — the i32 sub-sums
    /// and the f32 combination order match the original inline loop
    /// exactly (integer sums are regrouping-invariant).
    /// |acc| ≤ 32·3·127 ≈ 1.2e4 per sub-block: no overflow.
    fn dot_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        act: super::act::ActBlock<'_>,
        _scratch: &mut Vec<f32>,
    ) -> f32 {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(act.codes.len(), n);
        let planes = n * 3 / 8;
        let z = read_f16(bytes, planes);
        let mut lv = crate::util::align::AlignedBlockI8::zeroed();
        let lv = &mut lv.0[..n];
        ternary::unpack_dual_ternary_levels(&bytes[..n / 4], &bytes[n / 4..planes], lv);
        let mut total = 0.0f32;
        for s in 0..self.nsub() {
            let ds = read_f16(bytes, planes + 2 + 2 * s);
            let acc = super::simd::dot_i8(
                &lv[s * self.sub..(s + 1) * self.sub],
                &act.codes[s * self.sub..(s + 1) * self.sub],
            );
            total += ds * acc as f32;
        }
        (total + z * act.sum as f32) * act.scale
    }

    /// Batched W3A8 fused dot: ternary levels unpacked to i8 once,
    /// sub-scales read once, then one integer inner loop per (column,
    /// sub-block). Per column the float combination replays
    /// [`Format::dot_block_q8`] exactly (same sub-block order, same
    /// expressions), so each `y[t]` increment is bit-identical to the
    /// sequential path.
    fn gemm_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        acts: super::act::BatchBlock<'_>,
        y: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) {
        let n = self.n;
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(acts.block, n);
        debug_assert_eq!(y.len(), acts.cols());
        let planes = n * 3 / 8;
        let z = read_f16(bytes, planes);
        let mut lv = crate::util::align::AlignedBlockI8::zeroed();
        let lv = &mut lv.0[..n];
        ternary::unpack_dual_ternary_levels(&bytes[..n / 4], &bytes[n / 4..planes], lv);
        let mut ds = [0.0f32; 16];
        let nsub = self.nsub();
        for (s, d) in ds[..nsub].iter_mut().enumerate() {
            *d = read_f16(bytes, planes + 2 + 2 * s);
        }
        for (t, yo) in y.iter_mut().enumerate() {
            let ab = acts.col(t);
            let mut total = 0.0f32;
            for s in 0..nsub {
                let acc = super::simd::dot_i8(
                    &lv[s * self.sub..(s + 1) * self.sub],
                    &ab.codes[s * self.sub..(s + 1) * self.sub],
                );
                total += ds[s] * acc as f32;
            }
            *yo += (total + z * ab.sum as f32) * ab.scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift};

    #[test]
    fn bits_per_weight_is_3_5ish() {
        let f = Iq3S::new();
        assert!((f.bits_per_weight() - 3.5625).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_on_gaussian() {
        let mut rng = XorShift::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.04).collect();
        let f = Iq3S::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![0.0f32; 256];
        f.dequantize_block(0, &bytes, &mut out);
        let rel = stats::rel_l2_err(&w, &out);
        assert!(rel < 0.65, "rel={rel}");
    }

    #[test]
    fn outliers_degrade_whole_subblock() {
        // The motivating pathology: one 25σ outlier inflates its
        // sub-block's scale, so the *other* 31 weights there reconstruct
        // much worse than in a clean sub-block.
        let mut rng = XorShift::new(2);
        let mut w: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        w[5] = 0.5;
        let f = Iq3S::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![0.0f32; 256];
        f.dequantize_block(0, &bytes, &mut out);
        let mut mse_hit = 0.0; // sub-block 0, excluding the outlier itself
        for i in 0..32 {
            if i != 5 {
                mse_hit += ((w[i] - out[i]) as f64).powi(2);
            }
        }
        mse_hit /= 31.0;
        let mut mse_clean = 0.0;
        for i in 32..64 {
            mse_clean += ((w[i] - out[i]) as f64).powi(2);
        }
        mse_clean /= 32.0;
        assert!(
            mse_hit > 3.0 * mse_clean,
            "hit={mse_hit} clean={mse_clean}: outlier should poison its sub-block"
        );
    }

    #[test]
    fn not_rotated() {
        let f = Iq3S::new();
        assert!(!f.is_rotated());
        // raw == full dequant for non-rotated formats.
        let mut rng = XorShift::new(3);
        let w: Vec<f32> = (0..256).map(|_| rng.next_f32() - 0.5).collect();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut a = vec![0.0f32; 256];
        let mut b = vec![0.0f32; 256];
        f.dequantize_block(0, &bytes, &mut a);
        f.dequantize_block_raw(0, &bytes, &mut b);
        assert_eq!(a, b);
    }
}
