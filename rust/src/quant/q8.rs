//! Q8_0 baseline (Table 1 row "Q8_0"): llama.cpp's symmetric int8 format —
//! 32-element blocks, one f16 scale, codes in [-127, 127].
//! 34 bytes / 32 weights = 8.5 b/w (the paper rounds to "8.0").

use super::packing::*;
use super::Format;

#[allow(non_camel_case_types)]
pub struct Q8_0 {
    n: usize,
}

impl Q8_0 {
    pub fn new() -> Self {
        Q8_0 { n: 32 }
    }
}

impl Default for Q8_0 {
    fn default() -> Self {
        Self::new()
    }
}

impl Format for Q8_0 {
    fn name(&self) -> &'static str {
        "q8_0"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        2 + self.n
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        let amax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let d = crate::f16::f16_round(amax / 127.0).max(1e-12);
        push_f16(out, d);
        for &x in w {
            let c = (x / d).round().clamp(-127.0, 127.0) as i8;
            out.push(c as u8);
        }
    }

    fn dequantize_block(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let d = read_f16(bytes, 0);
        for (i, o) in out.iter_mut().enumerate() {
            *o = (bytes[2 + i] as i8) as f32 * d;
        }
    }

    /// Fused int8 dot: `d · Σ c_i·x_i` (one pass, scale factored out).
    fn dot_block_raw(
        &self,
        _idx: u64,
        bytes: &[u8],
        x: &[f32],
        _x_sum: f32,
        _s: &mut Vec<f32>,
    ) -> f32 {
        let d = read_f16(bytes, 0);
        let mut acc = [0.0f32; 4];
        for (i, chunk) in x.chunks_exact(4).enumerate() {
            let q = &bytes[2 + 4 * i..2 + 4 * i + 4];
            acc[0] += (q[0] as i8) as f32 * chunk[0];
            acc[1] += (q[1] as i8) as f32 * chunk[1];
            acc[2] += (q[2] as i8) as f32 * chunk[2];
            acc[3] += (q[3] as i8) as f32 * chunk[3];
        }
        d * (acc[0] + acc[1] + acc[2] + acc[3])
    }

    fn has_q8_kernel(&self) -> bool {
        true
    }

    /// W8A8 integer fused dot: the packed bytes *are* the i8 weight
    /// codes (reinterpreted in place, no copy), so this is one
    /// runtime-dispatched i8·i8→i32 dot ([`super::simd::dot_i8`]) with
    /// `d·s_act` folded into one final multiply — the i32 sum is exact,
    /// so every tier is bit-identical to the original 4-accumulator
    /// loop. |acc| ≤ 32·127² ≈ 5.2e5: no overflow.
    fn dot_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        act: super::act::ActBlock<'_>,
        _scratch: &mut Vec<f32>,
    ) -> f32 {
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(act.codes.len(), self.n);
        let d = read_f16(bytes, 0);
        let wq = super::simd::bytes_as_i8(&bytes[2..2 + self.n]);
        let acc = super::simd::dot_i8(wq, act.codes);
        acc as f32 * (d * act.scale)
    }

    /// Batched W8A8 fused dot: the packed weight codes are reinterpreted
    /// as i8 once, then one i8·i8→i32 dot per column with `d·s_t` folded
    /// in at the end. The i32 accumulation is exact, so regrouping it
    /// through [`super::simd::dot_i8`] leaves each `y[t]` increment
    /// bit-identical to [`Format::dot_block_q8`].
    fn gemm_block_q8(
        &self,
        _idx: u64,
        bytes: &[u8],
        acts: super::act::BatchBlock<'_>,
        y: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) {
        debug_assert_eq!(bytes.len(), self.block_bytes());
        debug_assert_eq!(acts.block, self.n);
        debug_assert_eq!(y.len(), acts.cols());
        let d = read_f16(bytes, 0);
        let wq = super::simd::bytes_as_i8(&bytes[2..2 + self.n]);
        for (t, yo) in y.iter_mut().enumerate() {
            let ab = acts.col(t);
            let acc = super::simd::dot_i8(wq, ab.codes);
            *yo += acc as f32 * (d * ab.scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift};

    #[test]
    fn bits_per_weight() {
        assert!((Q8_0::new().bits_per_weight() - 8.5).abs() < 1e-9);
    }

    #[test]
    fn near_lossless() {
        let mut rng = XorShift::new(1);
        let w: Vec<f32> = (0..32).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        let f = Q8_0::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![0.0f32; 32];
        f.dequantize_block(0, &bytes, &mut out);
        assert!(stats::rel_l2_err(&w, &out) < 0.01);
    }

    #[test]
    fn handles_all_zero_block() {
        let w = vec![0.0f32; 32];
        let f = Q8_0::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![1.0f32; 32];
        f.dequantize_block(0, &bytes, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
