//! Quantization formats: ITQ3_S and every baseline the paper evaluates.
//!
//! | Format        | b/w    | Grid                         | Rotation |
//! |---------------|--------|------------------------------|----------|
//! | `itq3_s`      | 3.125  | dual ternary {0,±d,±3d}      | FWHT-256 (Table 3 ablates 32..512) |
//! | `itq3_s_sub`  | 3.625  | dual ternary + 8 sub-scales  | FWHT-256 |
//! | `iq3_s`       | 3.5625 | dual ternary + 8 sub-scales  | none (llama.cpp-style baseline) |
//! | `quip3`       | 3.0625 | dual ternary                 | random-sign ⊙ FWHT (QuIP#-sim) |
//! | `q4_k_m`      | 4.5625 | asymmetric int4, sub-scales  | none |
//! | `iq4_xs`      | 4.3125 | nonlinear int4 codebook      | none |
//! | `q8_0`        | 8.5    | symmetric int8, 32-block     | none |
//! | `fp16`        | 16     | IEEE binary16                | none |
//!
//! All formats quantize independent blocks laid out along matrix rows, so
//! a row of a `(rows, cols)` weight matrix occupies an integral number of
//! blocks — the same constraint the paper inherits from GGUF (`cols` must
//! be a multiple of the block size; §8 "non-power-of-two layers" is
//! handled by [`pad_cols`]).

pub mod act;
pub mod audit;
pub mod error;
pub mod fp16q;
pub mod iq3s;
pub mod iq4xs;
pub mod itq3s;
pub mod matmul;
pub mod packing;
pub mod q4km;
pub mod q8;
pub mod quip3;
pub mod simd;
pub mod ternary;

use crate::tensor::Tensor;
use std::sync::Arc;

/// Default rotation/quantization block size (paper §4.1).
pub const BLOCK: usize = 256;

/// A weight-block quantization format.
///
/// `block_idx` is the global block ordinal within the tensor; formats
/// with per-block randomness (QuIP#-sim) derive their seed from it so
/// encode and decode agree without storing seeds.
pub trait Format: Send + Sync {
    /// Short identifier, e.g. `"itq3_s"`.
    fn name(&self) -> &'static str;

    /// Elements per quantization block.
    fn block_elems(&self) -> usize;

    /// Encoded bytes per block.
    fn block_bytes(&self) -> usize;

    /// Quantize one block of exactly `block_elems()` values, appending
    /// exactly `block_bytes()` bytes to `out`.
    fn quantize_block(&self, block_idx: u64, w: &[f32], out: &mut Vec<u8>);

    /// Reconstruct one block into `out` (original weight domain — rotated
    /// formats apply the inverse rotation here).
    fn dequantize_block(&self, block_idx: u64, bytes: &[u8], out: &mut [f32]);

    /// Reconstruct one block **without** inverse rotation (grid values in
    /// the storage domain). For non-rotated formats this equals
    /// `dequantize_block`. The fast matvec path uses this together with
    /// [`Format::rotate_activation_block`].
    fn dequantize_block_raw(&self, block_idx: u64, bytes: &[u8], out: &mut [f32]) {
        self.dequantize_block(block_idx, bytes, out);
    }

    /// Apply this format's forward rotation to an *activation* block so
    /// that `dot(raw_weights, rotated_activations) == dot(weights, activations)`
    /// (valid because the rotations used are orthogonal & symmetric).
    /// Identity for non-rotated formats.
    fn rotate_activation_block(&self, _block_idx: u64, _x: &mut [f32]) {}

    /// Whether the storage domain differs from the weight domain.
    fn is_rotated(&self) -> bool {
        false
    }

    /// Fused dot product of one packed block against a (rotated-domain)
    /// activation slice — the per-block core of the serving matvec
    /// (paper Alg 2 with the multiply folded into the unpack loop).
    /// `x_sum` is `Σ x_i` over the slice, precomputed once per matvec and
    /// shared across all weight rows so zero-point terms are O(1).
    /// Default: dequantize into `scratch` and dot; hot formats override
    /// with a single-pass LUT+FMA implementation (§Perf).
    fn dot_block_raw(
        &self,
        idx: u64,
        bytes: &[u8],
        x: &[f32],
        x_sum: f32,
        scratch: &mut Vec<f32>,
    ) -> f32 {
        let _ = x_sum;
        scratch.resize(self.block_elems(), 0.0);
        self.dequantize_block_raw(idx, bytes, scratch);
        matmul::dot(scratch, x)
    }

    /// Whether [`Format::dot_block_q8`] is a hand-specialized integer
    /// kernel (true for the hot serving formats). The engine's decode
    /// path only routes through W3A8 when this is set — the generic
    /// fallback below is *slower* than the fused f32 path (it
    /// reconstructs the activation block per weight row) and would add
    /// activation-quantization error for no benefit.
    fn has_q8_kernel(&self) -> bool {
        false
    }

    /// Integer-domain fused dot of one packed weight block against one
    /// Q8-quantized activation block — the CPU analog of the paper's
    /// DP4A MMVQ inner loop (§5.4): weight codes are decoded straight
    /// into i32 multiply-accumulates against the i8 activation codes,
    /// and the weight scale `d` and activation scale `act.scale` fold
    /// into a single f32 multiply at the end. `act.sum` (Σ codes,
    /// precomputed once per matvec) keeps zero-point terms O(1). Hot
    /// formats override with hand-specialized kernels; this default
    /// reconstructs the activations into `scratch` and falls back to the
    /// f32 path, so every format is W3A8-callable.
    fn dot_block_q8(
        &self,
        idx: u64,
        bytes: &[u8],
        act: act::ActBlock<'_>,
        scratch: &mut Vec<f32>,
    ) -> f32 {
        let be = self.block_elems();
        debug_assert_eq!(act.codes.len(), be);
        scratch.resize(2 * be, 0.0);
        let (xf, wf) = scratch.split_at_mut(be);
        for (o, &c) in xf.iter_mut().zip(act.codes) {
            *o = c as f32 * act.scale;
        }
        self.dequantize_block_raw(idx, bytes, wf);
        matmul::dot(wf, xf)
    }

    /// Batched integer-domain fused dot — the per-block core of the
    /// fused multi-sequence GEMM (`QuantizedLinear::gemm_q8`): one
    /// packed weight block against `acts.cols()` Q8 activation columns
    /// at once, accumulating `y[t] += <block, column t>`.
    ///
    /// **Contract (test-enforced in `quant::matmul`):** for every column
    /// `t`, the value added to `y[t]` is bit-identical to what
    /// [`Format::dot_block_q8`] returns for `acts.col(t)` — batching
    /// amortizes the unpack, it never changes the numerics. The batched
    /// decode path's equivalence to the sequential matvec path rests on
    /// this. Hot formats override to unpack the block once and run one
    /// integer inner loop per column; this default replays the generic
    /// fallback's exact f32 math with the weight reconstruction hoisted
    /// out of the column loop.
    fn gemm_block_q8(
        &self,
        idx: u64,
        bytes: &[u8],
        acts: act::BatchBlock<'_>,
        y: &mut [f32],
        scratch: &mut Vec<f32>,
    ) {
        let be = self.block_elems();
        debug_assert_eq!(acts.block, be);
        debug_assert_eq!(y.len(), acts.cols());
        scratch.resize(2 * be, 0.0);
        let (xf, wf) = scratch.split_at_mut(be);
        self.dequantize_block_raw(idx, bytes, wf);
        for (t, yo) in y.iter_mut().enumerate() {
            let ab = acts.col(t);
            for (o, &c) in xf.iter_mut().zip(ab.codes) {
                *o = c as f32 * ab.scale;
            }
            *yo += matmul::dot(wf, xf);
        }
    }

    /// Effective bits per weight, including metadata.
    fn bits_per_weight(&self) -> f64 {
        self.block_bytes() as f64 * 8.0 / self.block_elems() as f64
    }

    /// Grid step `d` stored in one packed block, for formats whose
    /// reconstruction error is governed by the paper's Theorem-2 bound
    /// (the rotated dual-ternary family). `None` for formats without a
    /// single per-block step — the weight audit (`quant::audit`) then
    /// falls back to a generic requantization-consistency check instead
    /// of the analytic bound.
    fn grid_step(&self, _bytes: &[u8]) -> Option<f32> {
        None
    }
}

/// Look up a format by name (CLI / config entry point).
pub fn format_by_name(name: &str) -> Option<Arc<dyn Format>> {
    Some(match name {
        "itq3_s" => Arc::new(itq3s::Itq3S::new(BLOCK)),
        "itq3_s_sub" => Arc::new(itq3s::Itq3SSub::new()),
        "iq3_s" => Arc::new(iq3s::Iq3S::new()),
        "quip3" => Arc::new(quip3::Quip3::new(0x51A5)),
        "q4_k_m" => Arc::new(q4km::Q4KM::new()),
        "iq4_xs" => Arc::new(iq4xs::Iq4Xs::new()),
        "q8_0" => Arc::new(q8::Q8_0::new()),
        "fp16" => Arc::new(fp16q::Fp16::new()),
        _ => {
            // itq3_s@N selects the Table-3 ablation block size.
            if let Some(n) = name.strip_prefix("itq3_s@") {
                let n: usize = n.parse().ok()?;
                if n.is_power_of_two() && (32..=512).contains(&n) {
                    return Some(Arc::new(itq3s::Itq3S::new(n)));
                }
            }
            return None;
        }
    })
}

/// All evaluated format names in Table-1 order.
pub const TABLE1_FORMATS: &[&str] =
    &["fp16", "q8_0", "q4_k_m", "iq4_xs", "iq3_s", "quip3", "itq3_s"];

/// A quantized 2-D weight matrix: `rows` independent rows, each an
/// integral number of format blocks over `cols` columns.
pub struct QuantizedMatrix {
    pub fmt: Arc<dyn Format>,
    pub rows: usize,
    pub cols: usize,
    /// Packed blocks, row-major: row 0's blocks, then row 1's, ...
    pub data: Vec<u8>,
}

impl QuantizedMatrix {
    /// Quantize a dense `(rows, cols)` tensor. `cols` must be a multiple
    /// of the format block size (pad first via [`pad_cols`] if not).
    pub fn quantize(fmt: Arc<dyn Format>, w: &Tensor) -> Self {
        let (rows, cols) = (w.rows(), w.cols());
        let be = fmt.block_elems();
        assert_eq!(
            cols % be,
            0,
            "cols {cols} not a multiple of block {be} for {}",
            fmt.name()
        );
        let blocks_per_row = cols / be;
        let mut data = Vec::with_capacity(rows * blocks_per_row * fmt.block_bytes());
        for r in 0..rows {
            let row = w.row(r);
            for (b, chunk) in row.chunks_exact(be).enumerate() {
                // Rotation index is the COLUMN block ordinal, shared by all
                // rows: this is what lets the fused matvec rotate each
                // activation block once and reuse it for every weight row
                // (QuIP#-sim derives its sign diagonal from this index).
                fmt.quantize_block(b as u64, chunk, &mut data);
            }
        }
        QuantizedMatrix { fmt, rows, cols, data }
    }

    pub fn blocks_per_row(&self) -> usize {
        self.cols / self.fmt.block_elems()
    }

    /// Raw bytes of block `(row, block_in_row)`.
    pub fn block_bytes(&self, row: usize, block: usize) -> &[u8] {
        let bb = self.fmt.block_bytes();
        let idx = row * self.blocks_per_row() + block;
        &self.data[idx * bb..(idx + 1) * bb]
    }

    /// Rotation index of block `(row, block_in_row)` — the column block
    /// ordinal (see [`QuantizedMatrix::quantize`]).
    pub fn block_idx(&self, _row: usize, block: usize) -> u64 {
        block as u64
    }

    /// Full dense reconstruction (original weight domain).
    pub fn dequantize(&self) -> Tensor {
        let be = self.fmt.block_elems();
        let mut out = Tensor::zeros(vec![self.rows, self.cols]);
        for r in 0..self.rows {
            for b in 0..self.blocks_per_row() {
                let idx = b as u64;
                let bytes = self.block_bytes(r, b);
                let dst = &mut out.row_mut(r)[b * be..(b + 1) * be];
                self.fmt.dequantize_block(idx, bytes, dst);
            }
        }
        out
    }

    /// Total packed size in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }
}

/// Pad the column dimension up to a multiple of `block` with zeros
/// (paper §8 "non-power-of-two layers": zero-padding leaves the FWHT
/// energy argument intact because H maps zero-padded blocks to blocks of
/// the same norm).
pub fn pad_cols(w: &Tensor, block: usize) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    let padded = cols.div_ceil(block) * block;
    if padded == cols {
        return w.clone();
    }
    let mut out = Tensor::zeros(vec![rows, padded]);
    for r in 0..rows {
        out.row_mut(r)[..cols].copy_from_slice(w.row(r));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::heavy_tailed_tensor;

    // dof=4 keeps the exact RNG stream the fidelity assertions below
    // were calibrated on (this was a local generator before the shared
    // one in util::prop replaced the hand-rolled copies).
    fn heavy_tensor(rows: usize, cols: usize, seed: u64) -> Tensor {
        heavy_tailed_tensor(rows, cols, seed, 4.0)
    }

    #[test]
    fn registry_has_all_table1_formats() {
        for &name in TABLE1_FORMATS {
            let f = format_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(f.name(), name);
            assert!(f.bits_per_weight() > 2.9 && f.bits_per_weight() <= 16.0);
        }
        assert!(format_by_name("nope").is_none());
        assert!(format_by_name("itq3_s@64").is_some());
        assert!(format_by_name("itq3_s@100").is_none());
    }

    #[test]
    fn q8_kernels_cover_exactly_the_hot_formats() {
        // The engine gates W3A8 decode on this capability; the generic
        // fallback must stay off the serving path.
        for (name, want) in [
            ("itq3_s", true),
            ("iq3_s", true),
            ("q4_k_m", true),
            ("q8_0", true),
            ("fp16", false),
            ("iq4_xs", false),
            ("quip3", false),
            ("itq3_s_sub", false),
        ] {
            let f = format_by_name(name).unwrap();
            assert_eq!(f.has_q8_kernel(), want, "{name}");
        }
    }

    #[test]
    fn bits_per_weight_match_paper_table1() {
        // Paper Table 1 bit-widths (ours differ slightly where the paper's
        // own metadata accounting is rounded; asserted to 0.15 b/w).
        let expect = [
            ("itq3_s", 3.125),
            ("quip3", 3.0625),
            ("iq3_s", 3.5),
            ("q4_k_m", 4.5),
            ("iq4_xs", 4.3),
            ("q8_0", 8.5),
            ("fp16", 16.0),
        ];
        for (name, bw) in expect {
            let f = format_by_name(name).unwrap();
            assert!(
                (f.bits_per_weight() - bw).abs() < 0.15,
                "{name}: {} vs {bw}",
                f.bits_per_weight()
            );
        }
    }

    #[test]
    fn quantize_dequantize_all_formats_reasonable_error() {
        let w = heavy_tensor(8, 512, 42);
        let sd = crate::util::stats::stddev(w.data());
        for &name in TABLE1_FORMATS {
            let fmt = format_by_name(name).unwrap();
            let q = QuantizedMatrix::quantize(fmt.clone(), &w);
            let recon = q.dequantize();
            let rmse = crate::util::stats::mse(w.data(), recon.data()).sqrt();
            // Even the coarsest 3-bit format must reconstruct to within
            // ~0.8 sigma RMSE on heavy-tailed input.
            assert!(rmse < 0.8 * sd, "{name}: rmse={rmse} sd={sd}");
            // And size accounting must be exact.
            assert_eq!(
                q.nbytes(),
                8 * (512 / fmt.block_elems()) * fmt.block_bytes()
            );
        }
    }

    #[test]
    fn format_fidelity_ordering_matches_table1_shape() {
        // The reproduction claim of Table 1: on heavy-tailed weights,
        // reconstruction error ranks fp16 < q8 < q4 < itq3_s < quip3 <= iq3_s.
        //
        // Tolerance triage (by inspection): these are *strict ordering*
        // assertions on one fixed seed, not tolerance bands. The gaps
        // they rely on are structural, not marginal — per-element RMSE
        // on Student-t(4) weights is ≈ 0.0003σ (fp16), ≈ 0.004σ (q8_0),
        // ≈ 0.05σ (q4_k_m), ≈ 0.3-0.5σ (3-bit family): adjacent tiers
        // differ by ~an order of magnitude except within the 3-bit
        // family, where the rotation advantage of itq3_s/quip3 over
        // unrotated iq3_s is the paper's Table-1 claim itself (~10-20%
        // RMSE on 16k samples, >>  the ~1% seed-to-seed spread of an
        // RMSE over 16384 elements). No slack factor is needed; a
        // different seed cannot plausibly flip any of these.
        let w = heavy_tensor(16, 1024, 7);
        let rmse = |name: &str| {
            let fmt = format_by_name(name).unwrap();
            let q = QuantizedMatrix::quantize(fmt, &w);
            crate::util::stats::mse(w.data(), q.dequantize().data()).sqrt()
        };
        let fp16 = rmse("fp16");
        let q8 = rmse("q8_0");
        let q4 = rmse("q4_k_m");
        let itq3 = rmse("itq3_s");
        let quip3 = rmse("quip3");
        let iq3 = rmse("iq3_s");
        assert!(fp16 < q8 && q8 < q4 && q4 < itq3, "{fp16} {q8} {q4} {itq3}");
        assert!(itq3 < iq3, "itq3_s {itq3} must beat iq3_s {iq3}");
        assert!(quip3 < iq3, "quip3 {quip3} must beat iq3_s {iq3}");
    }

    #[test]
    fn pad_cols_zero_fills() {
        let w = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_cols(&w, 4);
        assert_eq!(p.shape(), &[2, 4]);
        assert_eq!(p.row(0), &[1., 2., 3., 0.]);
        assert_eq!(p.row(1), &[4., 5., 6., 0.]);
        // Already aligned: untouched.
        let q = pad_cols(&p, 4);
        assert_eq!(q.data(), p.data());
    }
}
