//! FP16 "format" — the Table 1 baseline row. Stores IEEE binary16
//! directly (16 b/w); quantization error is only the f32→f16 rounding.

use super::Format;
use crate::f16;

pub struct Fp16 {
    n: usize,
}

impl Fp16 {
    pub fn new() -> Self {
        Fp16 { n: 32 }
    }
}

impl Default for Fp16 {
    fn default() -> Self {
        Self::new()
    }
}

impl Format for Fp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        self.n * 2
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        for &x in w {
            out.extend_from_slice(&f16::f32_to_f16_bits(x).to_le_bytes());
        }
    }

    fn dequantize_block(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        for (i, o) in out.iter_mut().enumerate() {
            let bits = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            *o = f16::f16_bits_to_f32(bits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Format as _;

    #[test]
    fn sixteen_bits_per_weight() {
        assert_eq!(Fp16::new().bits_per_weight(), 16.0);
    }

    #[test]
    fn roundtrip_is_f16_rounding() {
        let w: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.013).collect();
        let f = Fp16::new();
        let mut bytes = Vec::new();
        f.quantize_block(0, &w, &mut bytes);
        let mut out = vec![0.0f32; 32];
        f.dequantize_block(0, &bytes, &mut out);
        for (a, b) in w.iter().zip(&out) {
            assert_eq!(crate::f16::f16_round(*a), *b);
        }
    }
}
