//! Activation quantization for the W3A8 integer serving path.
//!
//! The paper's fused MMQ/MMVQ kernels (§5.2/§5.4) run the hot dot
//! products in *integer* arithmetic via DP4A: activations are quantized
//! to int8 once per matvec, and each packed weight block is decoded
//! straight into integer multiply-accumulates, with all scales folded
//! into a single float multiply at the end. This module is the CPU
//! analog's activation side (TWLA-style W3A8 post-training pairing):
//!
//! - [`QuantizedActs::quantize`] turns one (already rotated) activation
//!   vector into per-block `{scale, i8 codes, code sum}` — the scale is
//!   `amax/127` per *weight-format* block so it pairs one-to-one with
//!   each weight block's own scale;
//! - the precomputed per-block code sums make every zero-point term O(1)
//!   per block (the same trick the f32 fused path uses with `x_sum`);
//! - [`dot_i8`] is the shared i8·i8→i32 inner kernel, written with four
//!   independent accumulators so the autovectorizer can emit the
//!   SIMD widening-multiply-add pattern (the scalar analog of one DP4A
//!   per 4 lanes).
//!
//! Quantizing each rotated block with its own scale is what makes W3A8
//! benign here: the FWHT Gaussianizes the block (paper Thm 1), so
//! `amax/rms` is small and int8 resolution loses well under 1% relative
//! accuracy per dot product — see the parity tests in `quant::matmul`
//! and `EXPERIMENTS.md §Perf`.

/// One activation block in Q8 form, borrowed from a [`QuantizedActs`].
#[derive(Clone, Copy)]
pub struct ActBlock<'a> {
    /// i8 codes, `block` of them; value ≈ `code * scale`.
    pub codes: &'a [i8],
    /// Dequantization scale (`amax / 127`; 0.0 for an all-zero block).
    pub scale: f32,
    /// Precomputed `Σ codes` (so zero-point terms cost O(1)).
    pub sum: i32,
}

/// A full activation vector quantized to Q8 in per-block form. The
/// buffers are reusable: [`QuantizedActs::quantize`] overwrites in place
/// without reallocating once warmed up (decode-path scratch reuse).
#[derive(Default)]
pub struct QuantizedActs {
    block: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
    sums: Vec<i32>,
}

impl QuantizedActs {
    pub fn new() -> Self {
        QuantizedActs::default()
    }

    /// Total quantized elements.
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Elements per block (matches the paired weight format).
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn n_blocks(&self) -> usize {
        self.scales.len()
    }

    /// Quantize `x` (rotated domain) into per-`block` Q8 codes. `x.len()`
    /// must be a multiple of `block` (guaranteed by `QuantizedMatrix`'s
    /// column-alignment invariant).
    pub fn quantize(&mut self, x: &[f32], block: usize) {
        assert!(block > 0, "block must be positive");
        assert_eq!(x.len() % block, 0, "len {} not a multiple of block {block}", x.len());
        let nb = x.len() / block;
        self.block = block;
        self.codes.clear();
        self.codes.resize(x.len(), 0);
        self.scales.clear();
        self.scales.resize(nb, 0.0);
        self.sums.clear();
        self.sums.resize(nb, 0);
        for (b, chunk) in x.chunks_exact(block).enumerate() {
            let dst = &mut self.codes[b * block..(b + 1) * block];
            let (scale, sum) = quantize_block_q8(chunk, dst);
            self.scales[b] = scale;
            self.sums[b] = sum;
        }
    }

    /// Borrow block `b`.
    #[inline]
    pub fn block_at(&self, b: usize) -> ActBlock<'_> {
        ActBlock {
            codes: &self.codes[b * self.block..(b + 1) * self.block],
            scale: self.scales[b],
            sum: self.sums[b],
        }
    }
}

/// One activation block of a whole batch, borrowed from a
/// [`QuantizedBatch`]: the same *column* block of all `cols` sequences,
/// stored block-major (each sequence's `block` codes contiguous), so a
/// weight block unpacked once can be dotted against every column without
/// re-walking the packed bytes.
#[derive(Clone, Copy)]
pub struct BatchBlock<'a> {
    /// i8 codes, `cols * block` of them; column `t` occupies
    /// `codes[t*block..(t+1)*block]`.
    pub codes: &'a [i8],
    /// Per-column dequantization scales (`amax / 127`).
    pub scales: &'a [f32],
    /// Per-column precomputed `Σ codes`.
    pub sums: &'a [i32],
    /// Elements per column.
    pub block: usize,
}

impl<'a> BatchBlock<'a> {
    /// Number of activation columns (sequences) in the batch.
    pub fn cols(&self) -> usize {
        self.scales.len()
    }

    /// Column `t` viewed as a single-sequence [`ActBlock`] — byte-for-byte
    /// the input [`super::Format::dot_block_q8`] receives on the
    /// sequential path, which is what makes the batched/sequential
    /// bit-identity contract checkable column by column.
    #[inline]
    pub fn col(&self, t: usize) -> ActBlock<'a> {
        ActBlock {
            codes: &self.codes[t * self.block..(t + 1) * self.block],
            scale: self.scales[t],
            sum: self.sums[t],
        }
    }
}

/// A batch of `cols` activation vectors quantized to per-block Q8, laid
/// out **block-major**: all columns' codes for column block 0, then all
/// columns' codes for block 1, ... Within one block the `cols` code
/// vectors are contiguous ([`BatchBlock`]). This is the activation side
/// of the fused batched GEMM ([`super::Format::gemm_block_q8`]): the
/// GEMM walks weight blocks outermost, so everything it needs for one
/// weight block — every sequence's codes, scales and sums — is one
/// contiguous slab.
///
/// Per-column codes/scales/sums are produced by the same
/// [`quantize_block_q8`] calls the single-sequence [`QuantizedActs`]
/// makes, so column `t` of a batch is bit-identical to quantizing row
/// `t` alone. Buffers are reused across calls (decode-round scratch).
#[derive(Default)]
pub struct QuantizedBatch {
    block: usize,
    cols: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
    sums: Vec<i32>,
}

impl QuantizedBatch {
    pub fn new() -> Self {
        QuantizedBatch::default()
    }

    /// Number of activation columns (sequences).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Elements per block (matches the paired weight format).
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn n_blocks(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.scales.len() / self.cols
        }
    }

    /// Quantized elements per column (the activation vector length).
    pub fn seq_len(&self) -> usize {
        self.n_blocks() * self.block
    }

    /// Quantize `cols` row-major activation vectors (`x` is
    /// `(cols, n)` flattened, already rotated) into per-`block` Q8 codes
    /// in block-major order. `n` must be a multiple of `block`.
    pub fn quantize(&mut self, x: &[f32], cols: usize, block: usize) {
        assert!(cols > 0, "cols must be positive");
        assert!(block > 0, "block must be positive");
        assert_eq!(x.len() % cols, 0, "len {} not a multiple of cols {cols}", x.len());
        let n = x.len() / cols;
        assert_eq!(n % block, 0, "row len {n} not a multiple of block {block}");
        let nb = n / block;
        self.block = block;
        self.cols = cols;
        self.codes.clear();
        self.codes.resize(x.len(), 0);
        self.scales.clear();
        self.scales.resize(nb * cols, 0.0);
        self.sums.clear();
        self.sums.resize(nb * cols, 0);
        for b in 0..nb {
            for t in 0..cols {
                let src = &x[t * n + b * block..t * n + (b + 1) * block];
                let o = (b * cols + t) * block;
                let dst = &mut self.codes[o..o + block];
                let (scale, sum) = quantize_block_q8(src, dst);
                self.scales[b * cols + t] = scale;
                self.sums[b * cols + t] = sum;
            }
        }
    }

    /// Borrow column block `b` of all columns.
    #[inline]
    pub fn block_at(&self, b: usize) -> BatchBlock<'_> {
        let (cols, block) = (self.cols, self.block);
        BatchBlock {
            codes: &self.codes[b * cols * block..(b + 1) * cols * block],
            scales: &self.scales[b * cols..(b + 1) * cols],
            sums: &self.sums[b * cols..(b + 1) * cols],
            block,
        }
    }
}

/// Quantize one activation block to i8 codes with an `amax/127` scale.
/// Returns `(scale, Σ codes)`.
pub fn quantize_block_q8(x: &[f32], codes: &mut [i8]) -> (f32, i32) {
    debug_assert_eq!(x.len(), codes.len());
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax <= 0.0 {
        codes.fill(0);
        return (0.0, 0);
    }
    let scale = amax / 127.0;
    let inv = 127.0 / amax;
    let mut sum = 0i32;
    for (c, &v) in codes.iter_mut().zip(x) {
        let q = (v * inv).round().clamp(-127.0, 127.0) as i32;
        *c = q as i8;
        sum += q;
    }
    (scale, sum)
}

/// i8·i8 → i32 dot product, 4-way split accumulators (autovectorizes to
/// the widening multiply-add SIMD pattern — the DP4A analog).
///
/// This is the **scalar oracle** of the runtime-dispatched SIMD tiers in
/// [`super::simd`]: the explicit AVX2/NEON kernels are required to match
/// it bit-for-bit (i32 sums are regrouping-invariant), and
/// `tests/simd_parity.rs` enforces that differentially. Keep this body
/// as-is — changing it redefines the contract for every tier.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = 4 * i;
        acc[0] += a[j] as i32 * b[j] as i32;
        acc[1] += a[j + 1] as i32 * b[j + 1] as i32;
        acc[2] += a[j + 2] as i32 * b[j + 2] as i32;
        acc[3] += a[j + 3] as i32 * b[j + 3] as i32;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] as i32 * b[j] as i32;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::{stats, XorShift};

    #[test]
    fn roundtrip_error_is_subpercent_on_gaussian() {
        let mut rng = XorShift::new(1);
        let x: Vec<f32> = (0..256).map(|_| rng.next_gaussian() as f32 * 0.3).collect();
        let mut codes = vec![0i8; 256];
        let (scale, sum) = quantize_block_q8(&x, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| c as f32 * scale).collect();
        let rel = stats::rel_l2_err(&x, &recon);
        assert!(rel < 0.01, "rel={rel}");
        assert_eq!(sum, codes.iter().map(|&c| c as i32).sum::<i32>());
    }

    #[test]
    fn zero_block_is_exact() {
        let x = vec![0.0f32; 64];
        let mut codes = vec![7i8; 64];
        let (scale, sum) = quantize_block_q8(&x, &mut codes);
        assert_eq!(scale, 0.0);
        assert_eq!(sum, 0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn codes_saturate_at_127() {
        let x = [1.0f32, -1.0, 0.5, 0.0];
        let mut codes = [0i8; 4];
        quantize_block_q8(&x, &mut codes);
        assert_eq!(codes[0], 127);
        assert_eq!(codes[1], -127);
        assert_eq!(codes[2], 64); // 0.5 * 127 = 63.5 rounds to 64
        assert_eq!(codes[3], 0);
    }

    #[test]
    fn quantized_acts_blocks_are_independent() {
        let mut rng = XorShift::new(2);
        // Two blocks with wildly different magnitudes: per-block scales
        // must keep both accurate.
        let mut x: Vec<f32> = (0..64).map(|_| rng.next_gaussian() as f32 * 10.0).collect();
        x.extend((0..64).map(|_| rng.next_gaussian() as f32 * 0.001));
        let mut acts = QuantizedActs::new();
        acts.quantize(&x, 64);
        assert_eq!(acts.n_blocks(), 2);
        assert_eq!(acts.len(), 128);
        for b in 0..2 {
            let blk = acts.block_at(b);
            let recon: Vec<f32> =
                blk.codes.iter().map(|&c| c as f32 * blk.scale).collect();
            let rel = stats::rel_l2_err(&x[b * 64..(b + 1) * 64], &recon);
            assert!(rel < 0.01, "block {b}: rel={rel}");
        }
        assert!(acts.block_at(0).scale > 100.0 * acts.block_at(1).scale);
    }

    #[test]
    fn quantize_reuses_buffers() {
        let mut acts = QuantizedActs::new();
        acts.quantize(&[1.0f32; 512], 256);
        let cap = (acts.codes.capacity(), acts.scales.capacity());
        acts.quantize(&[-2.0f32; 512], 256);
        assert_eq!((acts.codes.capacity(), acts.scales.capacity()), cap);
        assert_eq!(acts.block_at(1).sum, 256 * -127);
    }

    #[test]
    fn quantized_batch_columns_match_quantized_acts_bitwise() {
        // The batched-layout invariant: column t of a QuantizedBatch is
        // exactly what QuantizedActs produces for row t alone (codes,
        // scale and sum all bit-identical) — the foundation of the
        // batched-GEMM == sequential-matvec equivalence.
        let mut rng = XorShift::new(11);
        let (cols, n, block) = (5usize, 256usize, 64usize);
        let x: Vec<f32> = (0..cols * n).map(|_| rng.next_gaussian() as f32).collect();
        let mut batch = QuantizedBatch::new();
        batch.quantize(&x, cols, block);
        assert_eq!(batch.cols(), cols);
        assert_eq!(batch.n_blocks(), n / block);
        assert_eq!(batch.seq_len(), n);
        let mut acts = QuantizedActs::new();
        for t in 0..cols {
            acts.quantize(&x[t * n..(t + 1) * n], block);
            for b in 0..n / block {
                let want = acts.block_at(b);
                let got = batch.block_at(b).col(t);
                assert_eq!(want.codes, got.codes, "t={t} b={b}");
                assert_eq!(want.scale, got.scale, "t={t} b={b}");
                assert_eq!(want.sum, got.sum, "t={t} b={b}");
            }
        }
    }

    #[test]
    fn quantized_batch_reuses_buffers() {
        let mut batch = QuantizedBatch::new();
        batch.quantize(&[1.0f32; 1024], 4, 128);
        let cap = (batch.codes.capacity(), batch.scales.capacity());
        batch.quantize(&[-1.0f32; 1024], 4, 128);
        assert_eq!((batch.codes.capacity(), batch.scales.capacity()), cap);
        assert_eq!(batch.block_at(1).col(3).sum, 128 * -127);
    }

    #[test]
    fn dot_i8_matches_reference() {
        let mut rng = XorShift::new(3);
        for n in [0usize, 1, 3, 4, 31, 32, 256] {
            let a: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_i8(&a, &b), want, "n={n}");
        }
    }

    #[test]
    fn prop_quantized_dot_tracks_f32_dot() {
        // The W3A8 premise: Q8 activations preserve dot products to well
        // under 1% relative error on Gaussian-ish blocks.
        forall("q8 activation dot fidelity", 80, |g| {
            let n = 8 * g.usize_in(4, 64);
            let x: Vec<f32> = (0..n).map(|_| g.gaussian_f32(0.5)).collect();
            let w: Vec<f32> = (0..n).map(|_| g.gaussian_f32(0.1)).collect();
            let mut codes = vec![0i8; n];
            let (scale, _) = quantize_block_q8(&x, &mut codes);
            let exact: f64 = w.iter().zip(&x).map(|(&a, &b)| (a * b) as f64).sum();
            let approx: f64 = w
                .iter()
                .zip(&codes)
                .map(|(&a, &c)| (a * c as f32 * scale) as f64)
                .sum();
            let wn = stats::l2(&w);
            let xn = stats::l2(&x);
            // |err| <= ||w|| * ||x_err||, with ||x_err|| <= scale/2 * sqrt(n).
            let bound = wn * (scale as f64) * 0.5 * (n as f64).sqrt() + 1e-6;
            assert!(
                (exact - approx).abs() <= bound.max(1e-4 * wn * xn),
                "n={n} exact={exact} approx={approx} bound={bound}"
            );
        });
    }
}
