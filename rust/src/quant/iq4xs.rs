//! IQ4_XS-style 4-bit baseline (Table 1 row "IQ4_XS"): a *nonlinear*
//! 16-level codebook (llama.cpp's IQ4_NL table, denser near zero where
//! Gaussian weights concentrate) with per-32 sub-scales quantized to
//! 6 bits. 138 bytes per 256 weights = 4.3125 b/w (paper: 4.3).

use super::packing::*;
use super::Format;

/// llama.cpp IQ4_NL codebook (values are in units of the sub-scale/127).
pub const IQ4_NL: [i8; 16] = [
    -127, -104, -83, -65, -49, -35, -22, -10, 1, 13, 25, 38, 53, 69, 89, 113,
];

pub struct Iq4Xs {
    n: usize,
    sub: usize,
}

impl Iq4Xs {
    pub fn new() -> Self {
        Iq4Xs { n: 256, sub: 32 }
    }

    fn nsub(&self) -> usize {
        self.n / self.sub
    }
}

impl Default for Iq4Xs {
    fn default() -> Self {
        Self::new()
    }
}

/// Nearest codebook index for `x` in units of `scale/127`.
fn nearest_code(x: f32, scale: f32) -> u8 {
    if scale <= 0.0 {
        return 8; // code for value 1 (≈0)
    }
    let t = x / scale * 127.0;
    let mut best = 0usize;
    let mut bd = f32::INFINITY;
    for (i, &kv) in IQ4_NL.iter().enumerate() {
        let d = (t - kv as f32).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    best as u8
}

impl Format for Iq4Xs {
    fn name(&self) -> &'static str {
        "iq4_xs"
    }

    fn block_elems(&self) -> usize {
        self.n
    }

    fn block_bytes(&self) -> usize {
        // d (2) + 8 x 6-bit sub-scales (6) + hi nibble pad (2) + codes (128)
        // = 138 bytes -> 4.3125 b/w.
        2 + 6 + 2 + self.n / 2
    }

    fn quantize_block(&self, _idx: u64, w: &[f32], out: &mut Vec<u8>) {
        assert_eq!(w.len(), self.n);
        // Per-sub scale: fit max|x| to the codebook extreme (127/127 = 1).
        let mut scales = [0.0f32; 8];
        for (s, chunk) in w.chunks_exact(self.sub).enumerate() {
            scales[s] = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-10);
        }
        let d = crate::f16::f16_round(scales.iter().cloned().fold(0.0f32, f32::max) / 63.0)
            .max(1e-10);
        let mut six = [0u8; 8];
        for s in 0..8 {
            six[s] = ((scales[s] / d).round() as i64).clamp(1, 63) as u8;
        }
        push_f16(out, d);
        // 8 six-bit scales in 6 bytes.
        let mut acc: u64 = 0;
        let mut nbits = 0;
        for &v in &six {
            acc |= (v as u64) << nbits;
            nbits += 6;
            while nbits >= 8 {
                out.push((acc & 0xFF) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        out.extend_from_slice(&[0, 0]); // alignment pad (counted in b/w)
        let mut codes = vec![0u8; self.n];
        for (s, chunk) in w.chunks_exact(self.sub).enumerate() {
            let sc = d * six[s] as f32;
            for (j, &x) in chunk.iter().enumerate() {
                codes[s * self.sub + j] = nearest_code(x, sc);
            }
        }
        pack_4bit(&codes, out);
    }

    fn dequantize_block(&self, _idx: u64, bytes: &[u8], out: &mut [f32]) {
        assert_eq!(bytes.len(), self.block_bytes());
        let d = read_f16(bytes, 0);
        let sixb = &bytes[2..8];
        let codes = &bytes[10..];
        for s in 0..self.nsub() {
            let bit = s * 6;
            let byte = bit / 8;
            let off = bit % 8;
            let lo = sixb[byte] as u16;
            let hi = if byte + 1 < 6 { sixb[byte + 1] as u16 } else { 0 };
            let sc = d * (((lo | (hi << 8)) >> off) & 0x3F) as f32;
            for j in 0..self.sub {
                let i = s * self.sub + j;
                let c = (codes[i / 2] >> ((i % 2) * 4)) & 0xF;
                out[i] = sc * IQ4_NL[c as usize] as f32 / 127.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, XorShift};

    #[test]
    fn bits_per_weight() {
        assert!((Iq4Xs::new().bits_per_weight() - 4.3125).abs() < 1e-9);
    }

    #[test]
    fn codebook_is_monotone() {
        for w in IQ4_NL.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn nearest_code_exact_on_codebook_points() {
        for (i, &kv) in IQ4_NL.iter().enumerate() {
            let x = kv as f32 / 127.0 * 0.05;
            assert_eq!(nearest_code(x, 0.05) as usize, i);
        }
    }

    #[test]
    fn roundtrip_error_between_q4km_and_3bit() {
        let mut rng = XorShift::new(1);
        let mut e_iq4 = 0.0;
        let mut e_q4k = 0.0;
        let mut e_it3 = 0.0;
        for bi in 0..10u64 {
            let w: Vec<f32> =
                (0..256).map(|_| rng.next_student_t(4.0) as f32 * 0.02).collect();
            let mut out = vec![0.0f32; 256];
            let mut bytes = Vec::new();
            let f = Iq4Xs::new();
            f.quantize_block(bi, &w, &mut bytes);
            f.dequantize_block(bi, &bytes, &mut out);
            e_iq4 += stats::mse(&w, &out);
            bytes.clear();
            let g = crate::quant::q4km::Q4KM::new();
            g.quantize_block(bi, &w, &mut bytes);
            g.dequantize_block(bi, &bytes, &mut out);
            e_q4k += stats::mse(&w, &out);
            bytes.clear();
            let h = crate::quant::itq3s::Itq3S::new(256);
            h.quantize_block(bi, &w, &mut bytes);
            h.dequantize_block(bi, &bytes, &mut out);
            e_it3 += stats::mse(&w, &out);
        }
        // Table 1 ordering: Q4_K_M <= IQ4_XS < ITQ3_S in error.
        assert!(e_iq4 < e_it3, "iq4_xs {e_iq4} vs itq3_s {e_it3}");
        assert!(e_q4k < e_it3);
    }
}
