//! Ternary grid theory: optimal scales for Gaussian inputs.
//!
//! Reproduces paper §3.3 and Appendix A: for `x ~ N(0, σ²)` the ternary
//! quantizer `{-α, 0, +α}` (with decision threshold α/2... the paper uses
//! round-to-nearest, i.e. threshold α/2) has an MSE-optimal scale
//! `α* ≈ 0.798 σ` under the paper's stationarity condition. We provide
//! both the closed-form constant and a numeric golden-section minimizer
//! so tests can cross-check the derivation, plus the dual-scale variant
//! used by the full ITQ3_S 3-bit grid (levels `{0, ±1, ±3}·d`).

/// The constant printed in the paper (§3.3, Eq. 8): `√2·erfinv(2/3) ≈ 0.7979`.
///
/// ERRATUM: this is *not* the MSE-optimal scale for the quantizer the
/// paper actually defines. Eq. (5) is round-to-nearest (decision
/// threshold d/2), whose Gaussian optimum is the 3-level Lloyd-Max scale
/// [`ALPHA_STAR`] ≈ 1.2235σ; Appendix A's integral assumes a dead-zone
/// threshold at α, whose optimum is ≈ 0.8767σ — neither equals 0.798.
/// We keep the paper's constant for reference and use the correct
/// Lloyd-Max values in the quantizers (verified numerically in tests).
pub const ALPHA_STAR_PAPER: f64 = 0.797_884_560_802_865_4;

/// MSE-optimal scale for round-to-nearest ternary `{-α,0,+α}` on N(0,1):
/// the 3-level Lloyd-Max solution (numeric minimum 1.2235, MSE 0.1903σ²).
pub const ALPHA_STAR: f64 = 1.2235;

/// Optimal dual-scale grid step for `{0, ±d, ±3d}` on N(0,1), found by
/// numeric MSE minimization (minimum 0.5682, MSE 0.0898σ²); hard-coded so
/// the hot quantization path does no solving.
pub const DUAL_SCALE_STAR: f64 = 0.5682;

/// Round-to-nearest ternary quantization of `x` on grid `{-d, 0, +d}`:
/// returns the digit in {-1, 0, +1}.
#[inline]
pub fn ternary_digit(x: f32, d: f32) -> i8 {
    // Nearest of {-d, 0, d}: thresholds at ±d/2.
    let t = 0.5 * d;
    if x > t {
        1
    } else if x < -t {
        -1
    } else {
        0
    }
}

/// Nearest level of the ITQ3_S dual-scale grid `{0, ±d, ±3d}` (the
/// "interleaved ternary" 3-bit grid: a fine ternary sub-grid `{0,±d}`
/// and a coarse one `{0,±3d}` selected by the interleave bit).
/// Returns (digit ∈ {-1,0,1}, coarse_selector).
#[inline]
pub fn dual_ternary_digit(x: f32, d: f32) -> (i8, bool) {
    // Levels: -3d, -d, 0, d, 3d. Midpoints: ±d/2, ±2d.
    let a = x.abs();
    if a <= 0.5 * d {
        (0, false)
    } else {
        let digit = if x > 0.0 { 1 } else { -1 };
        (digit, a > 2.0 * d)
    }
}

/// Reconstruct a value from a dual-scale code.
#[inline]
pub fn dual_ternary_value(digit: i8, coarse: bool, d: f32) -> f32 {
    let mag = if coarse { 3.0 * d } else { d };
    digit as f32 * mag
}

/// Decode one interleaved-ternary plane pair — 2-bit digit words plus
/// coarse-selector bits, 8 elements per `(u16 codes, u8 sel)` group —
/// into i8 grid levels `{0, ±1, ±3}`. The single unpack shared by the
/// W3A8 integer kernels (`itq3s`/`iq3s` `dot_block_q8`/`gemm_block_q8`),
/// so the plane layout cannot drift between them. `lv.len()` must be a
/// multiple of 8 with `base`/`sel` sized to match.
#[inline]
pub fn unpack_dual_ternary_levels(base: &[u8], sel: &[u8], lv: &mut [i8]) {
    const LUT: [i8; 8] = [-1, 0, 1, 0, -3, 0, 3, 0];
    debug_assert_eq!(base.len(), lv.len() / 4);
    debug_assert_eq!(sel.len(), lv.len() / 8);
    for g in 0..lv.len() / 8 {
        let codes = u16::from_le_bytes([base[2 * g], base[2 * g + 1]]) as usize;
        let s = sel[g] as usize;
        let o = &mut lv[g * 8..g * 8 + 8];
        for (j, oj) in o.iter_mut().enumerate() {
            *oj = LUT[((codes >> (2 * j)) & 3) | (((s >> j) & 1) << 2)];
        }
    }
}

/// Monte-Carlo MSE of plain ternary quantization at scale `alpha` on
/// N(0,1) samples (used by tests and the solver below).
pub fn ternary_mse_gaussian(alpha: f64, samples: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in samples {
        let d = ternary_digit(x as f32, alpha as f32) as f64;
        let e = x - d * alpha;
        acc += e * e;
    }
    acc / samples.len() as f64
}

/// Monte-Carlo MSE of the dual-scale grid at step `d`.
pub fn dual_mse_gaussian(d: f64, samples: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in samples {
        let (dg, coarse) = dual_ternary_digit(x as f32, d as f32);
        let e = x - dual_ternary_value(dg, coarse, d as f32) as f64;
        acc += e * e;
    }
    acc / samples.len() as f64
}

/// Golden-section minimizer over [lo, hi] for a unimodal f.
pub fn golden_min(lo: f64, hi: f64, iters: usize, f: impl Fn(f64) -> f64) -> f64 {
    const PHI: f64 = 0.618_033_988_749_894_8;
    let (mut a, mut b) = (lo, hi);
    let mut c = b - PHI * (b - a);
    let mut d = a + PHI * (b - a);
    let (mut fc, mut fd) = (f(c), f(d));
    for _ in 0..iters {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - PHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + PHI * (b - a);
            fd = f(d);
        }
    }
    0.5 * (a + b)
}

/// Numerically find the MSE-optimal plain-ternary scale on N(0,1),
/// cross-checking `ALPHA_STAR` (Appendix A reproduction).
pub fn optimal_scale_numeric(samples: &[f64]) -> f64 {
    golden_min(0.3, 2.0, 60, |a| ternary_mse_gaussian(a, samples))
}

/// Numerically find the optimal dual-scale step on N(0,1).
pub fn optimal_dual_scale_numeric(samples: &[f64]) -> f64 {
    golden_min(0.2, 1.5, 60, |d| dual_mse_gaussian(d, samples))
}

/// Per-block scale for plain ternary: `d_k = α*·σ(block)`.
///
/// NOTE (erratum): the paper's Algorithm 1 line 3 prints `d_k ← α*/σ(w')`,
/// which is dimensionally inconsistent with its own §3.3 (`α* = 0.798 σ`);
/// we implement the §3.3 form.
pub fn block_scale_ternary(block: &[f32]) -> f32 {
    (ALPHA_STAR * crate::util::stats::stddev(block)) as f32
}

/// Per-block step for the dual-scale ITQ3_S grid: `d_k = 0.5505·σ(block)`.
pub fn block_scale_dual(block: &[f32]) -> f32 {
    (DUAL_SCALE_STAR * crate::util::stats::stddev(block)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn gaussian_samples(n: usize, seed: u64) -> Vec<f64> {
        let mut r = XorShift::new(seed);
        (0..n).map(|_| r.next_gaussian()).collect()
    }

    #[test]
    fn alpha_star_matches_numeric_minimum() {
        // Appendix A: the closed form α* ≈ 0.798 must agree with direct
        // numeric minimization of the Monte-Carlo MSE.
        let samples = gaussian_samples(400_000, 1);
        let a = optimal_scale_numeric(&samples);
        assert!((a - ALPHA_STAR).abs() < 0.02, "numeric α* = {a}");
        // ...and the paper's printed constant is demonstrably not optimal
        // under its own Eq. (5) round-to-nearest rule (the erratum).
        let mse_paper = ternary_mse_gaussian(ALPHA_STAR_PAPER, &samples);
        let mse_ours = ternary_mse_gaussian(ALPHA_STAR, &samples);
        assert!(mse_ours < mse_paper * 0.75, "{mse_ours} vs {mse_paper}");
    }

    #[test]
    fn dual_scale_constant_matches_numeric() {
        let samples = gaussian_samples(400_000, 2);
        let d = optimal_dual_scale_numeric(&samples);
        assert!((d - DUAL_SCALE_STAR).abs() < 0.02, "numeric d* = {d}");
    }

    #[test]
    fn dual_grid_strictly_beats_plain_ternary_on_gaussian() {
        // The 3-bit interleaved grid must dominate the 2-bit ternary grid —
        // this is what pays for the extra bit.
        let samples = gaussian_samples(200_000, 3);
        let t = ternary_mse_gaussian(ALPHA_STAR, &samples);
        let d = dual_mse_gaussian(DUAL_SCALE_STAR, &samples);
        assert!(d < t * 0.65, "dual {d} vs ternary {t}");
    }

    #[test]
    fn digit_thresholds() {
        assert_eq!(ternary_digit(0.0, 1.0), 0);
        assert_eq!(ternary_digit(0.49, 1.0), 0);
        assert_eq!(ternary_digit(0.51, 1.0), 1);
        assert_eq!(ternary_digit(-0.51, 1.0), -1);
    }

    #[test]
    fn dual_digit_nearest_level() {
        let d = 1.0f32;
        // Levels -3,-1,0,1,3. Check representative points.
        for (x, want) in [
            (0.0, 0.0),
            (0.4, 0.0),
            (0.6, 1.0),
            (1.9, 1.0),
            (2.1, 3.0),
            (10.0, 3.0),
            (-0.7, -1.0),
            (-2.5, -3.0),
        ] {
            let (dg, c) = dual_ternary_digit(x, d);
            assert_eq!(dual_ternary_value(dg, c, d), want, "x={x}");
        }
    }

    #[test]
    fn dual_digit_is_nearest_everywhere() {
        crate::util::prop::forall("dual grid picks the nearest level", 300, |g| {
            let d = g.f32_in(0.05, 2.0);
            let x = g.f32_in(-8.0, 8.0);
            let (dg, c) = dual_ternary_digit(x, d);
            let picked = dual_ternary_value(dg, c, d);
            let levels = [-3.0 * d, -d, 0.0, d, 3.0 * d];
            let best = levels
                .iter()
                .copied()
                .min_by(|a, b| (x - a).abs().partial_cmp(&(x - b).abs()).unwrap())
                .unwrap();
            assert!(
                (x - picked).abs() <= (x - best).abs() + 1e-6,
                "x={x} d={d} picked={picked} best={best}"
            );
        });
    }

    #[test]
    fn block_scales_track_sigma() {
        let mut r = XorShift::new(4);
        let block: Vec<f32> = (0..256).map(|_| r.next_gaussian() as f32 * 0.05).collect();
        let sd = crate::util::stats::stddev(&block);
        assert!((block_scale_ternary(&block) as f64 - ALPHA_STAR * sd).abs() < 1e-6);
        assert!((block_scale_dual(&block) as f64 - DUAL_SCALE_STAR * sd).abs() < 1e-6);
    }
}
