//! Static weight audit — runtime evidence for the paper's Theorem-2
//! reconstruction claim on the *deployed* artifact, not just on unit-test
//! blocks (`itq3s audit`, the server's `audit` op, and the load-time
//! check before a replicated server starts serving).
//!
//! At serve time the original f32 weights are gone; the packed blocks
//! *are* the ground truth. What the audit can and does verify per block:
//!
//! 1. **Finiteness** — `dequantize_block` must reconstruct finite
//!    values. The detectable corruption class for the f16-scaled formats
//!    is precisely a scale word with an all-ones exponent (`d` or `z`
//!    becoming ±Inf/NaN), which poisons the whole block and, untrapped,
//!    every logit downstream.
//! 2. **Theorem-2 self-consistency** (formats exposing
//!    [`Format::grid_step`], i.e. the rotated dual-ternary family):
//!    requantizing the reconstruction ŵ and decoding again must land
//!    within `thm2_bound_l2sq(ŵ, d₂, n)` — the bound holds for *any*
//!    finite input block, so a violation means the encode/decode pair
//!    itself is broken (format mismatch, layout drift, scale corruption
//!    that survived finiteness).
//! 3. **Requantization smoke ceiling** (all other formats): the
//!    round-trip error must not exceed the reconstruction's own norm —
//!    a generous ceiling that still catches NaN propagation (NaN fails
//!    every comparison) and runaway scales.
//!
//! A flipped *code* bit is undetectable by construction — every bit
//! pattern in the ternary planes decodes to a legal grid point — which
//! is exactly why the serve path pairs this static audit with sampled
//! logit-drift shadow scoring (`--audit-sample-rate`).

use super::{Format, QuantizedMatrix};
use crate::util::json::Json;

/// Multiplicative slack on the Theorem-2 comparison, absorbing the FWHT
/// rounding term ε_FWHT — the same idiom the offline bound test uses
/// (`quant::itq3s::tests::thm2_bound_holds`).
const THM2_SLACK: f64 = 1.01;

/// Audit verdict for one quantized tensor.
pub struct TensorAudit {
    /// GGUF-style tensor name, e.g. `layers.0.wq`.
    pub name: String,
    pub blocks: usize,
    /// Requantization round-trip error over the whole tensor, relative
    /// to the reconstruction norm: ‖ŵ₂−ŵ‖₂ / ‖ŵ‖₂.
    pub rel_l2: f64,
    /// The audit ceiling in the same normalization (Theorem-2 bound for
    /// `grid_step` formats, the smoke ceiling of 1.0 otherwise).
    pub bound_rel_l2: f64,
    /// `bound_rel_l2 − rel_l2`: how much headroom the artifact has.
    pub margin: f64,
    /// Block ordinal (row-major) with the worst err²/bound ratio.
    pub worst_block: usize,
    /// That block's err²/bound ratio (≤ 1 on a clean artifact).
    pub worst_ratio: f64,
    pub ok: bool,
    /// Human-readable reason when `!ok` (empty otherwise).
    pub detail: String,
}

impl TensorAudit {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("blocks", Json::num(self.blocks as f64)),
            ("rel_l2", Json::num(self.rel_l2)),
            ("bound_rel_l2", Json::num(self.bound_rel_l2)),
            ("margin", Json::num(self.margin)),
            ("worst_block", Json::num(self.worst_block as f64)),
            ("worst_ratio", Json::num(self.worst_ratio)),
            ("ok", Json::Bool(self.ok)),
            ("detail", Json::str(self.detail.clone())),
        ])
    }
}

/// Whole-model audit report (built by `QuantizedModel::audit` /
/// `Engine::audit_weights`; rendered by the CLI and the `audit` op).
pub struct AuditReport {
    /// Format name, or a marker like `"dense"` for engines with no
    /// quantized tensors (trivially ok).
    pub fmt: String,
    pub tensors: Vec<TensorAudit>,
}

impl AuditReport {
    /// Report for an engine with nothing to audit.
    pub fn empty(fmt: &str) -> Self {
        AuditReport { fmt: fmt.to_string(), tensors: Vec::new() }
    }

    pub fn ok(&self) -> bool {
        self.tensors.iter().all(|t| t.ok)
    }

    /// Names of the violated tensors (empty on a clean artifact).
    pub fn violations(&self) -> Vec<&str> {
        self.tensors.iter().filter(|t| !t.ok).map(|t| t.name.as_str()).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fmt", Json::str(self.fmt.clone())),
            ("ok", Json::Bool(self.ok())),
            ("tensors", Json::Arr(self.tensors.iter().map(|t| t.to_json()).collect())),
        ])
    }

    /// Fixed-width per-tensor table for the `itq3s audit` CLI.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:>7} {:>10} {:>10} {:>10} {:>11} {:>6}\n",
            "tensor", "blocks", "rel_l2", "bound", "margin", "worst", "ok"
        ));
        for t in &self.tensors {
            out.push_str(&format!(
                "{:<24} {:>7} {:>10.3e} {:>10.3e} {:>10.3e} {:>5}:{:<5.2} {:>6}\n",
                t.name,
                t.blocks,
                t.rel_l2,
                t.bound_rel_l2,
                t.margin,
                t.worst_block,
                t.worst_ratio,
                if t.ok { "ok" } else { "FAIL" },
            ));
            if !t.ok {
                out.push_str(&format!("  ^ {}\n", t.detail));
            }
        }
        out.push_str(&format!(
            "[{}] {} tensors, {}\n",
            self.fmt,
            self.tensors.len(),
            if self.ok() { "all within bound".to_string() } else { format!("{} VIOLATED", self.violations().len()) },
        ));
        out
    }
}

/// Result of one logit-drift shadow probe: the same token history scored
/// through the quantized decode path and the f32 reference path
/// (`act_quant = false`), with the per-layer residual stream captured at
/// the probed position. Built by `Engine::audit_probe`; the drift
/// summaries below are what the coordinator feeds into the
/// `audit_logit_kl` / `audit_top1_agree` / `audit_max_logit_delta`
/// rings.
pub struct AuditProbe {
    /// Per-layer rel-L2 between the quantized and reference residual
    /// streams after each transformer layer (length = `n_layers`) — the
    /// error-accumulation profile of the probed position.
    pub layer_rel_l2: Vec<f64>,
    pub logits_quant: Vec<f32>,
    pub logits_ref: Vec<f32>,
}

/// Numerically stable log-softmax in f64 (drift metrics must not add
/// their own rounding noise to the drift they measure).
fn log_softmax(xs: &[f32]) -> Vec<f64> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = xs.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>().ln() + m;
    xs.iter().map(|&x| x as f64 - lse).collect()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

impl AuditProbe {
    /// KL(quantized ‖ reference) over the softmaxed logits, in nats.
    /// Clamped at 0 so f64 rounding can never report a negative
    /// divergence.
    pub fn kl_divergence(&self) -> f64 {
        if self.logits_quant.is_empty() {
            return 0.0;
        }
        let lq = log_softmax(&self.logits_quant);
        let lr = log_softmax(&self.logits_ref);
        lq.iter().zip(&lr).map(|(&a, &b)| a.exp() * (a - b)).sum::<f64>().max(0.0)
    }

    /// Whether greedy decoding would pick the same token on both paths
    /// (ties break to the lowest index on both sides, so the comparison
    /// is well defined).
    pub fn top1_agree(&self) -> bool {
        self.logits_quant.is_empty() || argmax(&self.logits_quant) == argmax(&self.logits_ref)
    }

    /// Largest absolute per-logit deviation between the two paths.
    pub fn max_logit_delta(&self) -> f64 {
        self.logits_quant
            .iter()
            .zip(&self.logits_ref)
            .map(|(&a, &b)| ((a - b) as f64).abs())
            .fold(0.0, f64::max)
    }
}

/// Audit one packed matrix block by block (see the module docs for what
/// each check proves). `name` is the tensor name carried into the
/// report.
pub fn audit_matrix(name: &str, m: &QuantizedMatrix) -> TensorAudit {
    let fmt: &dyn Format = m.fmt.as_ref();
    let n = fmt.block_elems();
    let mut recon = vec![0.0f32; n];
    let mut recon2 = vec![0.0f32; n];
    let mut repacked: Vec<u8> = Vec::with_capacity(fmt.block_bytes());
    let (mut err_sq, mut bound_sq, mut ref_sq) = (0.0f64, 0.0f64, 0.0f64);
    let (mut worst_block, mut worst_ratio) = (0usize, 0.0f64);
    let mut detail = String::new();
    let mut ok = true;
    for r in 0..m.rows {
        for b in 0..m.blocks_per_row() {
            let idx = m.block_idx(r, b);
            let bytes = m.block_bytes(r, b);
            fmt.dequantize_block(idx, bytes, &mut recon);
            let ordinal = r * m.blocks_per_row() + b;
            if let Some(bad) = recon.iter().find(|v| !v.is_finite()) {
                if ok {
                    detail = format!("block {ordinal}: non-finite reconstruction ({bad})");
                }
                ok = false;
                worst_block = ordinal;
                worst_ratio = f64::INFINITY;
                continue;
            }
            ref_sq += recon.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
            repacked.clear();
            fmt.quantize_block(idx, &recon, &mut repacked);
            fmt.dequantize_block(idx, &repacked, &mut recon2);
            let block_err: f64 = recon
                .iter()
                .zip(&recon2)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            let block_bound = match fmt.grid_step(&repacked) {
                Some(d2) => {
                    super::error::thm2_bound_l2sq(&recon, d2 as f64, n) * THM2_SLACK + 1e-9
                }
                // Smoke ceiling: round-trip error may not exceed the
                // signal itself (catches NaN propagation and runaway
                // scales, nothing subtler).
                None => recon.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() + 1e-9,
            };
            err_sq += block_err;
            bound_sq += block_bound;
            let ratio = block_err / block_bound;
            // A NaN ratio (NaN scale that stayed "finite" through decode
            // cannot happen, but belt and braces) fails the comparison.
            if !(block_err <= block_bound) {
                if ok {
                    detail = format!(
                        "block {ordinal}: err²={block_err:.3e} exceeds bound {block_bound:.3e}"
                    );
                }
                ok = false;
            }
            if ratio > worst_ratio {
                worst_ratio = ratio;
                worst_block = ordinal;
            }
        }
    }
    let ref_norm = ref_sq.sqrt();
    let (rel_l2, bound_rel_l2) = if ref_norm > 0.0 {
        (err_sq.sqrt() / ref_norm, bound_sq.sqrt() / ref_norm)
    } else {
        (err_sq.sqrt(), bound_sq.sqrt())
    };
    TensorAudit {
        name: name.to_string(),
        blocks: m.rows * m.blocks_per_row(),
        rel_l2,
        bound_rel_l2,
        margin: bound_rel_l2 - rel_l2,
        worst_block,
        worst_ratio,
        ok,
        detail,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name;
    use crate::tensor::Tensor;
    use crate::util::XorShift;

    fn heavy_matrix(fmt_name: &str, rows: usize, cols: usize, seed: u64) -> QuantizedMatrix {
        let mut rng = XorShift::new(seed);
        let mut t = Tensor::zeros(vec![rows, cols]);
        for x in t.data_mut() {
            *x = (rng.next_student_t(4.0) as f32) * 0.02;
        }
        QuantizedMatrix::quantize(format_by_name(fmt_name).unwrap(), &t)
    }

    #[test]
    fn clean_itq3s_matrix_passes_with_margin() {
        let m = heavy_matrix("itq3_s", 4, 512, 11);
        let a = audit_matrix("t", &m);
        assert!(a.ok, "{}", a.detail);
        assert_eq!(a.blocks, 8);
        assert!(a.margin > 0.0, "margin {}", a.margin);
        assert!(a.worst_ratio <= 1.0, "worst {}", a.worst_ratio);
        assert!(a.rel_l2.is_finite() && a.rel_l2 >= 0.0);
    }

    #[test]
    fn clean_fallback_formats_pass_the_smoke_ceiling() {
        // Formats without a grid_step go through the generic ceiling.
        for name in ["q8_0", "q4_k_m", "itq3_s_sub", "fp16"] {
            let m = heavy_matrix(name, 2, 512, 13);
            let a = audit_matrix("t", &m);
            assert!(a.ok, "{name}: {}", a.detail);
        }
    }

    #[test]
    fn corrupted_scale_word_is_flagged() {
        // Force an itq3_s block's stored d to +Inf (f16 0x7C00): the
        // reconstruction goes non-finite and the audit must name the
        // block. d sits at byte offset n*3/8 = 96, little-endian.
        let mut m = heavy_matrix("itq3_s", 2, 512, 17);
        let bb = m.fmt.block_bytes();
        let victim = 3; // row 1, block 1 at 512 cols -> ordinal 3
        m.data[victim * bb + 96] = 0x00;
        m.data[victim * bb + 97] = 0x7C;
        let a = audit_matrix("t", &m);
        assert!(!a.ok);
        assert_eq!(a.worst_block, victim);
        assert!(a.worst_ratio.is_infinite());
        assert!(a.detail.contains("block 3"), "{}", a.detail);
        // The report machinery agrees.
        let rep = AuditReport { fmt: "itq3_s".into(), tensors: vec![a] };
        assert!(!rep.ok());
        assert_eq!(rep.violations(), vec!["t"]);
        assert!(rep.render_table().contains("FAIL"));
        assert_eq!(rep.to_json().get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn probe_drift_metrics() {
        // Identical logits: zero drift on every metric.
        let same = AuditProbe {
            layer_rel_l2: vec![0.0],
            logits_quant: vec![1.0, 2.0, 3.0],
            logits_ref: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(same.kl_divergence(), 0.0);
        assert!(same.top1_agree());
        assert_eq!(same.max_logit_delta(), 0.0);

        // Shifted argmax: KL positive, top-1 disagrees, delta exact.
        let drift = AuditProbe {
            layer_rel_l2: vec![0.1],
            logits_quant: vec![3.0, 2.0, 1.0],
            logits_ref: vec![1.0, 2.0, 3.0],
        };
        assert!(drift.kl_divergence() > 0.1, "kl {}", drift.kl_divergence());
        assert!(!drift.top1_agree());
        assert!((drift.max_logit_delta() - 2.0).abs() < 1e-12);

        // A uniform logit shift is softmax-invariant: KL stays ~0 even
        // though the raw delta is large — the metrics really do measure
        // the distribution, not the raw vectors.
        let shifted = AuditProbe {
            layer_rel_l2: vec![],
            logits_quant: vec![11.0, 12.0, 13.0],
            logits_ref: vec![1.0, 2.0, 3.0],
        };
        assert!(shifted.kl_divergence() < 1e-9);
        assert!(shifted.top1_agree());
        assert!((shifted.max_logit_delta() - 10.0).abs() < 1e-12);

        // Empty probe (engine without shadow support) is all-quiet.
        let empty = AuditProbe {
            layer_rel_l2: vec![],
            logits_quant: vec![],
            logits_ref: vec![],
        };
        assert_eq!(empty.kl_divergence(), 0.0);
        assert!(empty.top1_agree());
        assert_eq!(empty.max_logit_delta(), 0.0);
    }

    #[test]
    fn empty_report_is_trivially_ok() {
        let rep = AuditReport::empty("dense");
        assert!(rep.ok());
        assert!(rep.violations().is_empty());
        assert!(rep.render_table().contains("0 tensors"));
    }
}
