//! Minimal JSON reader/writer for the serving protocol and config files.
//!
//! `serde`/`serde_json` are not in the offline vendor set, so the server's
//! JSON-lines protocol is handled by this small, strict-enough parser:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept ordered (BTreeMap) so serialized
/// output is deterministic — handy for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document. Trailing garbage is an error.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.i = start + len;
                    match std::str::from_utf8(&self.b[start..start + len]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = Json::obj(vec![
            ("tokens", Json::Arr(vec![Json::num(1), Json::num(2)])),
            ("text", Json::str("a \"quoted\" line\nwith\ttabs")),
            ("t", Json::Bool(true)),
            ("x", Json::num(0.5)),
        ]);
        let s = src.to_string();
        assert_eq!(Json::parse(&s).unwrap(), src);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\u12\"").is_err());
    }
}
