//! Small self-contained utilities shared across the crate.
//!
//! The offline vendor set for this build contains only `xla` and `anyhow`,
//! so everything that would normally come from `rand`, `serde_json`,
//! `half`, `criterion`, or `proptest` is implemented here from scratch
//! (see DESIGN.md §6 "Substitutions").

pub mod align;
pub mod failpoint;
pub mod flight;
pub mod json;
pub mod log;
pub mod prng;
pub mod profile;
pub mod prop;
pub mod stats;
pub mod threadpool;
pub mod trace;

pub use prng::XorShift;

/// Round `x` to `digits` decimal digits (for stable table output).
pub fn round_to(x: f64, digits: u32) -> f64 {
    let p = 10f64.powi(digits as i32);
    (x * p).round() / p
}

/// Human-readable byte size (GiB/MiB/KiB).
pub fn human_bytes(bytes: u64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.2} MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_to_works() {
        assert_eq!(round_to(3.14159, 2), 3.14);
        assert_eq!(round_to(6.515, 2), 6.52);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert!(human_bytes(29_305_000_000).starts_with("27.2"));
    }
}
