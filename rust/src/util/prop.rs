//! In-repo property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides the
//! subset we need: seeded case generation with automatic shrinking of
//! counterexample *seeds* (we re-run with the failing seed printed so a
//! failure is reproducible), plus a few common generators. Property tests
//! throughout the crate (`quant`, `fwht`, `coordinator`) are built on it.
//!
//! Usage:
//! ```no_run
//! use itq3s::util::prop::{forall, Gen};
//! forall("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::XorShift;
use crate::tensor::Tensor;

/// A test-case generator handed to each property invocation.
pub struct Gen {
    rng: XorShift,
    /// Size hint (grows over cases like proptest's size parameter).
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: XorShift::new(seed), size }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn gaussian_f32(&mut self, sigma: f32) -> f32 {
        (self.rng.next_gaussian() as f32) * sigma
    }

    /// Student-t draw (heavy tails; `dof` degrees of freedom) — the
    /// transformer-weight-like marginal the kernel fuzz loop uses.
    pub fn student_t_f32(&mut self, dof: f64) -> f32 {
        self.rng.next_student_t(dof) as f32
    }

    /// A weight-like vector: mostly Gaussian with occasional heavy
    /// outliers, mimicking transformer weight blocks (the paper's §1
    /// "heavy-tailed weight distributions").
    pub fn weight_block(&mut self, n: usize) -> Vec<f32> {
        let sigma = self.f32_in(0.005, 0.2);
        (0..n)
            .map(|_| {
                if self.rng.next_f64() < 0.01 {
                    // outlier: 5-30 sigma
                    self.gaussian_f32(sigma) + self.f32_in(5.0, 30.0) * sigma * self.sign()
                } else {
                    self.gaussian_f32(sigma)
                }
            })
            .collect()
    }

    pub fn sign(&mut self) -> f32 {
        self.rng.next_sign()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` random test cases of the property `f`. On panic, the
/// failing seed is printed and the panic is re-raised, so the case can be
/// replayed with `ITQ3S_PROP_SEED=<seed>`.
pub fn forall(name: &str, cases: u64, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    forall_indexed(name, cases, move |_i, g| f(g));
}

/// Like [`forall`] but hands `f` the case ordinal alongside the
/// generator. Fixed-pattern fuzz loops (e.g. [`kernel_weight_block`])
/// use the ordinal to cycle adversarial shapes deterministically before
/// seeded randoms; under `ITQ3S_PROP_SEED` replay the ordinal is
/// re-derived from the seed so the replayed case builds the same inputs.
pub fn forall_indexed(
    name: &str,
    cases: u64,
    f: impl Fn(u64, &mut Gen) + std::panic::RefUnwindSafe,
) {
    // Base seed: env override for replay, otherwise a fixed default so CI
    // is deterministic.
    const BASE: u64 = 0xC0FFEE;
    let base = std::env::var("ITQ3S_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let (start, count) = match base {
        Some(s) => (s, 1), // replay exactly one case
        None => (BASE, cases),
    };
    for i in 0..count {
        let seed = start.wrapping_add(i);
        // Ordinal: `i` normally; under replay, recovered from the seed
        // (seed = BASE + ordinal when the default base was in effect).
        let ordinal = seed.wrapping_sub(BASE);
        let size = 1 + (i as usize * 64) / cases.max(1) as usize;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            f(ordinal, &mut g);
        });
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {i} (replay with ITQ3S_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// One weight block for the cross-format kernel fuzz loop. The ordinal
/// cycles through the fixed shapes that historically break packed
/// integer kernels — so every bounded run covers each at least once —
/// before seeded randoms:
/// `0` all-zero, `1` ±1e3 alternation (max magnitude, max cancellation),
/// `2` ±0.05 alternation (sign-alternating at ordinary scale), `3`
/// constant 1e3 (monotone accumulator: quantizes to max-magnitude codes
/// of one sign, driving the i32 partial sums toward the per-kernel
/// bounds each kernel documents as unreachable), `4` heavy-tailed
/// Student-t, `5` uniform.
pub fn kernel_weight_block(n: usize, case: u64, g: &mut Gen) -> Vec<f32> {
    match case % 6 {
        0 => vec![0.0; n],
        1 => (0..n)
            .map(|i| if i % 2 == 0 { 1.0e3 } else { -1.0e3 })
            .collect(),
        2 => (0..n).map(|i| if i % 2 == 0 { 0.05 } else { -0.05 }).collect(),
        3 => vec![1.0e3; n],
        4 => (0..n).map(|_| g.student_t_f32(4.0) * 0.02).collect(),
        _ => (0..n).map(|_| g.f32_in(-0.5, 0.5)).collect(),
    }
}

/// The activation batch paired with [`kernel_weight_block`]: the same
/// adversarial shapes on the activation side. The ±8 alternation
/// quantizes to sign-alternating ±127 codes; the constant row to all
/// +127 codes (pairing with weight case 3 to maximize every partial
/// sum); then Gaussian, uniform, and near-denormal-scale rows.
pub fn kernel_act_rows(n: usize, g: &mut Gen) -> Vec<Vec<f32>> {
    vec![
        vec![0.0; n],
        (0..n).map(|i| if i % 2 == 0 { 8.0 } else { -8.0 }).collect(),
        vec![8.0; n],
        (0..n).map(|_| g.gaussian_f32(1.0)).collect(),
        (0..n).map(|_| g.f32_in(-0.5, 0.5)).collect(),
        (0..n).map(|_| g.gaussian_f32(1e-3)).collect(),
    ]
}

/// Seeded cross-format kernel fuzz loop — the shared driver of the
/// scalar differential tests in `quant::matmul` and the SIMD parity
/// harness in `tests/simd_parity.rs`. Runs `cases` deterministic
/// iterations; each builds one weight block of `n` elements (fixed
/// adversarial shapes first, then seeded randoms — see
/// [`kernel_weight_block`]) plus the full adversarial activation batch,
/// and hands `f` `(ordinal, weight_block, act_rows)`. Failing seeds
/// replay via `ITQ3S_PROP_SEED` exactly like [`forall`].
pub fn forall_kernel_cases(
    name: &str,
    n: usize,
    cases: u64,
    f: impl Fn(u64, &[f32], &[Vec<f32>]) + std::panic::RefUnwindSafe,
) {
    forall_indexed(name, cases, move |ordinal, g| {
        let w = kernel_weight_block(n, ordinal, g);
        let rows = kernel_act_rows(n, g);
        f(ordinal, &w, &rows);
    });
}

/// Deterministic heavy-tailed `(rows, cols)` weight tensor — Student-t
/// marginals scaled like transformer weights (the paper's §1
/// "heavy-tailed weight distributions"). The single generator behind
/// every tensor-level differential test and bench; `dof` = 4 for the
/// fidelity-ordering fixtures, 5 for the linear-level ones (the streams
/// the tests' tolerances were calibrated on).
pub fn heavy_tailed_tensor(rows: usize, cols: usize, seed: u64, dof: f64) -> Tensor {
    let mut rng = XorShift::new(seed);
    let mut t = Tensor::zeros(vec![rows, cols]);
    for x in t.data_mut() {
        *x = (rng.next_student_t(dof) as f32) * 0.02;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs is nonnegative", 50, |g| {
            let x = g.f32_in(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn forall_reports_failure() {
        forall("always fails", 5, |_g| {
            panic!("intentional");
        });
    }

    #[test]
    fn weight_block_has_outliers_sometimes() {
        let mut g = Gen::new(9, 8);
        let mut saw_outlier = false;
        for _ in 0..50 {
            let w = g.weight_block(256);
            let sd = crate::util::stats::stddev(&w).max(1e-9);
            if crate::util::stats::linf(&w) > 4.0 * sd {
                saw_outlier = true;
            }
        }
        assert!(saw_outlier);
    }

    #[test]
    fn kernel_fuzz_cases_have_fixed_shapes_and_batch_layout() {
        forall_kernel_cases("kernel case layout", 64, 8, |case, w, rows| {
            assert_eq!(w.len(), 64);
            assert_eq!(rows.len(), 6, "adversarial batch is 6 activation rows");
            assert!(rows.iter().all(|r| r.len() == 64));
            match case % 6 {
                0 => assert!(w.iter().all(|&v| v == 0.0)),
                1 => assert!(w.iter().enumerate().all(|(i, &v)| v.abs() == 1.0e3
                    && (v > 0.0) == (i % 2 == 0))),
                3 => assert!(w.iter().all(|&v| v == 1.0e3)),
                _ => {}
            }
            assert!(rows[0].iter().all(|&v| v == 0.0));
            assert!(rows[2].iter().all(|&v| v == 8.0));
        });
    }

    #[test]
    fn heavy_tailed_tensor_is_deterministic_per_seed() {
        let a = heavy_tailed_tensor(5, 7, 13, 4.0);
        let b = heavy_tailed_tensor(5, 7, 13, 4.0);
        let c = heavy_tailed_tensor(5, 7, 14, 4.0);
        assert_eq!(a.data(), b.data());
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(4, 1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let x = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
        }
    }
}
