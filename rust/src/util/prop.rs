//! In-repo property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides the
//! subset we need: seeded case generation with automatic shrinking of
//! counterexample *seeds* (we re-run with the failing seed printed so a
//! failure is reproducible), plus a few common generators. Property tests
//! throughout the crate (`quant`, `fwht`, `coordinator`) are built on it.
//!
//! Usage:
//! ```no_run
//! use itq3s::util::prop::{forall, Gen};
//! forall("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::XorShift;

/// A test-case generator handed to each property invocation.
pub struct Gen {
    rng: XorShift,
    /// Size hint (grows over cases like proptest's size parameter).
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: XorShift::new(seed), size }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn gaussian_f32(&mut self, sigma: f32) -> f32 {
        (self.rng.next_gaussian() as f32) * sigma
    }

    /// A weight-like vector: mostly Gaussian with occasional heavy
    /// outliers, mimicking transformer weight blocks (the paper's §1
    /// "heavy-tailed weight distributions").
    pub fn weight_block(&mut self, n: usize) -> Vec<f32> {
        let sigma = self.f32_in(0.005, 0.2);
        (0..n)
            .map(|_| {
                if self.rng.next_f64() < 0.01 {
                    // outlier: 5-30 sigma
                    self.gaussian_f32(sigma) + self.f32_in(5.0, 30.0) * sigma * self.sign()
                } else {
                    self.gaussian_f32(sigma)
                }
            })
            .collect()
    }

    pub fn sign(&mut self) -> f32 {
        self.rng.next_sign()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` random test cases of the property `f`. On panic, the
/// failing seed is printed and the panic is re-raised, so the case can be
/// replayed with `ITQ3S_PROP_SEED=<seed>`.
pub fn forall(name: &str, cases: u64, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Base seed: env override for replay, otherwise a fixed default so CI
    // is deterministic.
    let base = std::env::var("ITQ3S_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let (start, count) = match base {
        Some(s) => (s, 1),       // replay exactly one case
        None => (0xC0FFEE, cases),
    };
    for i in 0..count {
        let seed = start.wrapping_add(i);
        let size = 1 + (i as usize * 64) / cases.max(1) as usize;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            f(&mut g);
        });
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {i} (replay with ITQ3S_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs is nonnegative", 50, |g| {
            let x = g.f32_in(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn forall_reports_failure() {
        forall("always fails", 5, |_g| {
            panic!("intentional");
        });
    }

    #[test]
    fn weight_block_has_outliers_sometimes() {
        let mut g = Gen::new(9, 8);
        let mut saw_outlier = false;
        for _ in 0..50 {
            let w = g.weight_block(256);
            let sd = crate::util::stats::stddev(&w).max(1e-9);
            if crate::util::stats::linf(&w) > 4.0 * sd {
                saw_outlier = true;
            }
        }
        assert!(saw_outlier);
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(4, 1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let x = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
        }
    }
}
