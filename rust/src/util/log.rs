//! Minimal leveled structured logger: `key=value` lines to stderr.
//!
//! The serving stack used to scatter ad-hoc `eprintln!` calls; this
//! module replaces them with one consistent line shape so operators
//! can grep restarts, connection errors, and flight-recorder dumps
//! mechanically:
//!
//! ```text
//! ts_ms=1523.4 level=warn target=server msg="connection error" err="broken pipe"
//! ```
//!
//! The global level is an atomic (default [`Level::Info`]); `itq3s
//! serve --log-level debug|info|warn|error|off` sets it at startup and
//! tests may flip it at will. Values containing whitespace, `"`, or
//! `=` are quoted with `{:?}`; bare tokens stay unquoted so the lines
//! stay terse. There is no timestamp formatting or output routing —
//! stderr only, milliseconds since the first log call — deliberately
//! small enough to never be the thing being debugged.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered so `Error < Warn < Info < Debug`: a message
/// is emitted when its level is *at or above* the global threshold in
/// severity (i.e. numerically `<=`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Nothing is emitted (threshold only; messages cannot be `Off`).
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static T0: OnceLock<Instant> = OnceLock::new();

/// Set the global log threshold.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log threshold.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        _ => Level::Debug,
    }
}

/// Would a message at `l` be emitted right now?
pub fn enabled(l: Level) -> bool {
    l != Level::Off && (l as u8) <= (level() as u8)
}

/// Milliseconds since the logger first ticked (monotonic).
fn ts_ms() -> f64 {
    T0.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}

/// Quote a value only when it would break `key=value` tokenization.
fn fmt_value(v: &str) -> String {
    let bare = !v.is_empty()
        && v.chars().all(|c| !c.is_whitespace() && c != '"' && c != '=' && c != '\n');
    if bare {
        v.to_string()
    } else {
        format!("{v:?}")
    }
}

/// Emit one structured line (already level-checked by the callers).
fn emit(l: Level, target: &str, msg: &str, kv: &[(&str, String)]) {
    let mut line = format!("ts_ms={:.1} level={} target={} msg={:?}", ts_ms(), l.as_str(), target, msg);
    for (k, v) in kv {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(&fmt_value(v));
    }
    eprintln!("{line}");
}

/// Log at `l` from component `target` with structured `kv` pairs.
pub fn log(l: Level, target: &str, msg: &str, kv: &[(&str, String)]) {
    if enabled(l) {
        emit(l, target, msg, kv);
    }
}

pub fn error(target: &str, msg: &str, kv: &[(&str, String)]) {
    log(Level::Error, target, msg, kv);
}

pub fn warn(target: &str, msg: &str, kv: &[(&str, String)]) {
    log(Level::Warn, target, msg, kv);
}

pub fn info(target: &str, msg: &str, kv: &[(&str, String)]) {
    log(Level::Info, target, msg, kv);
}

pub fn debug(target: &str, msg: &str, kv: &[(&str, String)]) {
    log(Level::Debug, target, msg, kv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_spellings() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("INFO"), Some(Level::Info));
        assert_eq!(Level::parse("Warn"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("none"), Some(Level::Off));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn threshold_ordering_gates_messages() {
        // Pure predicate check against explicit thresholds — does not
        // depend on (or race with) the process-global level.
        let gate = |msg: Level, thr: Level| msg != Level::Off && (msg as u8) <= (thr as u8);
        assert!(gate(Level::Error, Level::Info));
        assert!(gate(Level::Info, Level::Info));
        assert!(!gate(Level::Debug, Level::Info));
        assert!(!gate(Level::Error, Level::Off));
        assert!(gate(Level::Debug, Level::Debug));
    }

    #[test]
    fn values_quote_only_when_needed() {
        assert_eq!(fmt_value("plain-token_7"), "plain-token_7");
        assert_eq!(fmt_value("has space"), "\"has space\"");
        assert_eq!(fmt_value("k=v"), "\"k=v\"");
        assert_eq!(fmt_value(""), "\"\"");
    }

    #[test]
    fn round_trips_through_the_global_level() {
        let before = level();
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Error);
        assert!(!enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(before);
    }
}
