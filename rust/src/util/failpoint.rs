//! Deterministic fault injection for the robustness test suite.
//!
//! A *failpoint* is a named site in production code that asks, each time
//! execution passes it, "should I fail right now?". With the default
//! feature set the answer is a compile-time constant `false` — the call
//! inlines to nothing and the serving paths carry zero overhead. With
//! `--features failpoints` a process-global registry scripts the
//! answer: tests arm a site with a 1-based *hit window* and a
//! [`FailAction`], then drive a real workload through the coordinator
//! or server and assert on the typed wreckage.
//!
//! Sites are plain strings (`"engine.decode"`, `"kvpaged.alloc"`, …)
//! checked via [`should_fail`]; the full list lives in
//! `docs/ARCHITECTURE.md` § "Failure domains & recovery". Triggers are
//! counted per-site, so a schedule like "fail the 3rd decode round"
//! is `arm_at("engine.decode", 3, FailAction::Panic)` — deterministic
//! because the coordinator is a single worker thread.
//!
//! The registry is process-global, so tests that arm *real* sites must
//! serialize against each other **and** against every other test that
//! might trip those sites. [`exclusive`] provides that: chaos tests
//! live in their own integration binary (`rust/tests/chaos.rs`, cargo
//! runs test binaries one at a time) and each takes the exclusive
//! guard, which resets the registry on acquire and on drop.

/// What an armed failpoint does when its hit window matches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailAction {
    /// Report failure: [`should_fail`] returns `true` and the call site
    /// takes its error path (typed `Err`, `None` from an allocator, …).
    Error,
    /// Panic at the site with a recognizable message — exercises the
    /// coordinator's `catch_unwind` restart path.
    Panic,
    /// Sleep for the given milliseconds, then proceed normally. Used to
    /// pace fast paths (e.g. decode rounds on a tiny test model) so
    /// mid-flight client behavior lands deterministically.
    Sleep(u64),
}

#[cfg(feature = "failpoints")]
mod imp {
    use super::FailAction;
    use std::collections::BTreeMap;
    use std::sync::{Mutex, MutexGuard};

    #[derive(Clone, Copy, Debug)]
    struct Trigger {
        /// 1-based first hit the trigger fires on.
        from: u64,
        /// Last hit (inclusive); `u64::MAX` means "forever".
        to: u64,
        action: FailAction,
    }

    #[derive(Default)]
    struct Site {
        hits: u64,
        triggers: Vec<Trigger>,
    }

    static REGISTRY: Mutex<BTreeMap<String, Site>> = Mutex::new(BTreeMap::new());
    /// Serializes chaos tests; independent of the registry lock.
    static EXCLUSIVE: Mutex<()> = Mutex::new(());

    /// A failpoint panic unwinds through `registry()`'s guard *after*
    /// it is dropped, but an injected panic elsewhere may still poison
    /// either mutex — both locks hold plain data, so poison is noise.
    fn registry() -> MutexGuard<'static, BTreeMap<String, Site>> {
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Arm `site` to perform `action` on hits `from..=to` (1-based).
    pub fn arm(site: &str, from: u64, to: u64, action: FailAction) {
        assert!(from >= 1 && to >= from, "hit window must be 1-based and non-empty");
        registry()
            .entry(site.to_string())
            .or_default()
            .triggers
            .push(Trigger { from, to, action });
    }

    /// Arm `site` for exactly the `n`-th hit.
    pub fn arm_at(site: &str, n: u64, action: FailAction) {
        arm(site, n, n, action);
    }

    /// Arm `site` from the `n`-th hit onward, forever.
    pub fn arm_from(site: &str, n: u64, action: FailAction) {
        arm(site, n, u64::MAX, action);
    }

    /// Total times `site` has been evaluated since the last [`reset`].
    pub fn hits(site: &str) -> u64 {
        registry().get(site).map_or(0, |s| s.hits)
    }

    /// Clear every trigger and hit counter.
    pub fn reset() {
        registry().clear();
    }

    /// Evaluate `site`: count the hit, fire a matching trigger if any.
    ///
    /// Returns `true` when the call site should take its error path.
    /// `FailAction::Panic` panics here (with the registry lock already
    /// released); `FailAction::Sleep` delays and then reports `false`.
    pub fn should_fail(site: &str) -> bool {
        let mut reg = registry();
        let s = reg.entry(site.to_string()).or_default();
        s.hits += 1;
        let hit = s.hits;
        let act = s
            .triggers
            .iter()
            .find(|t| hit >= t.from && hit <= t.to)
            .map(|t| t.action);
        drop(reg);
        match act {
            None => false,
            Some(FailAction::Error) => true,
            Some(FailAction::Sleep(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                false
            }
            Some(FailAction::Panic) => panic!("failpoint '{site}': injected panic"),
        }
    }

    /// Held by a chaos test for its whole body: serializes armed-site
    /// tests and guarantees a clean registry on entry and exit.
    pub struct FailpointsGuard {
        _lock: MutexGuard<'static, ()>,
    }

    impl Drop for FailpointsGuard {
        fn drop(&mut self) {
            reset();
        }
    }

    /// Acquire the chaos-test lock and reset the registry.
    pub fn exclusive() -> FailpointsGuard {
        let lock = EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        FailpointsGuard { _lock: lock }
    }
}

#[cfg(feature = "failpoints")]
pub use imp::*;

/// With failpoints compiled out every site check is a constant `false`
/// — the optimizer deletes the branch entirely.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn should_fail(_site: &str) -> bool {
    false
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    // These self-tests use fictitious "test.*" sites that no production
    // code evaluates, so holding `exclusive()` only serializes them
    // against other chaos tests without perturbing ordinary lib tests.

    #[test]
    fn unarmed_sites_never_fail_but_count_hits() {
        let _g = exclusive();
        assert!(!should_fail("test.unarmed"));
        assert!(!should_fail("test.unarmed"));
        assert_eq!(hits("test.unarmed"), 2);
        assert_eq!(hits("test.never-evaluated"), 0);
    }

    #[test]
    fn hit_windows_are_one_based_and_inclusive() {
        let _g = exclusive();
        arm("test.window", 2, 3, FailAction::Error);
        assert!(!should_fail("test.window")); // hit 1
        assert!(should_fail("test.window")); // hit 2
        assert!(should_fail("test.window")); // hit 3
        assert!(!should_fail("test.window")); // hit 4
        assert_eq!(hits("test.window"), 4);
    }

    #[test]
    fn arm_from_fires_forever_and_reset_clears() {
        let _g = exclusive();
        arm_from("test.forever", 1, FailAction::Error);
        for _ in 0..5 {
            assert!(should_fail("test.forever"));
        }
        reset();
        assert!(!should_fail("test.forever"));
        assert_eq!(hits("test.forever"), 1);
    }

    #[test]
    fn panic_action_panics_with_site_name() {
        let _g = exclusive();
        arm_at("test.panics", 1, FailAction::Panic);
        let err = std::panic::catch_unwind(|| should_fail("test.panics"))
            .expect_err("armed panic must unwind");
        let msg = err.downcast_ref::<String>().expect("panic payload is a String");
        assert!(msg.contains("test.panics"), "panic message names the site: {msg}");
        // The registry mutex was released before panicking: still usable.
        assert_eq!(hits("test.panics"), 1);
        assert!(!should_fail("test.panics"));
    }

    #[test]
    fn sleep_action_delays_then_proceeds() {
        let _g = exclusive();
        arm_at("test.sleepy", 1, FailAction::Sleep(20));
        let t0 = std::time::Instant::now();
        assert!(!should_fail("test.sleepy"));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        assert!(!should_fail("test.sleepy"));
    }

    #[test]
    fn exclusive_guard_resets_on_drop() {
        let g = exclusive();
        arm_from("test.guarded", 1, FailAction::Error);
        assert!(should_fail("test.guarded"));
        drop(g);
        let _g = exclusive();
        assert!(!should_fail("test.guarded"));
    }
}
