//! Engine phase profiler: where inside a scheduling round does the
//! compute time go?
//!
//! Scoped timers wrap the engine's internal phases — FWHT rotation +
//! activation quantization ([`Phase::RotQuant`]), the integer GEMM
//! kernels ([`Phase::Gemm`]), the attention loops
//! ([`Phase::Attention`]), and sampling ([`Phase::Sampler`]) — and
//! accumulate nanoseconds into process-global atomics. Once per
//! scheduling round the coordinator drains them ([`take`]) into the
//! `phase_*_ms` distributions in `coordinator/metrics.rs`.
//!
//! Like `util/failpoint.rs`, the whole mechanism sits behind a cargo
//! feature (`--features profiling`). With the feature off, [`scope`]
//! returns a zero-sized guard and every call compiles to nothing — a
//! test asserts `size_of::<PhaseGuard>() == 0` so the zero-cost claim
//! cannot rot. With it on, the cost per scope is two `Instant` reads
//! and one relaxed atomic add, cheap enough to leave on in production
//! builds that want the breakdown.
//!
//! Scopes are timed from the calling thread (wall time of the whole
//! sharded call, not CPU time summed across the pool), and the
//! instrumented sites are chosen so scopes never nest — the four
//! buckets partition engine wall time instead of double counting it.

/// The profiled engine phases, in drain order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// FWHT rotation of activations + Q8 quantization (the
    /// rotation-domain smoothing front end of every quantized GEMM).
    RotQuant = 0,
    /// The fused integer (or dense fallback) matvec/GEMM kernels.
    Gemm = 1,
    /// Attention: score, softmax, and weighted-sum loops over KV.
    Attention = 2,
    /// Sampling: logits → filtered distribution → drawn token, plus
    /// the speculative accept loop's sampler replay.
    Sampler = 3,
}

/// Number of phases (the length of [`take`]'s result).
pub const NUM_PHASES: usize = 4;

/// Stable metric names, indexed by `Phase as usize`.
pub const PHASE_NAMES: [&str; NUM_PHASES] = ["rot_quant", "gemm", "attention", "sampler"];

#[cfg(feature = "profiling")]
mod imp {
    use super::{Phase, NUM_PHASES};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Instant;

    static ACC_NS: [AtomicU64; NUM_PHASES] =
        [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

    /// Compile-time switch callers can branch on without `cfg`.
    pub const ENABLED: bool = true;

    /// RAII guard: accumulates the scope's elapsed time on drop.
    pub struct PhaseGuard {
        phase: Phase,
        t0: Instant,
    }

    impl Drop for PhaseGuard {
        fn drop(&mut self) {
            let ns = self.t0.elapsed().as_nanos() as u64;
            ACC_NS[self.phase as usize].fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Time everything until the returned guard drops under `phase`.
    #[must_use = "the guard must live for the scope being timed"]
    pub fn scope(phase: Phase) -> PhaseGuard {
        PhaseGuard { phase, t0: Instant::now() }
    }

    /// Drain the accumulators: milliseconds per phase since the last
    /// call, indexed by `Phase as usize`.
    pub fn take() -> [f64; NUM_PHASES] {
        core::array::from_fn(|i| ACC_NS[i].swap(0, Ordering::Relaxed) as f64 / 1e6)
    }
}

#[cfg(not(feature = "profiling"))]
mod imp {
    use super::{Phase, NUM_PHASES};

    /// Compile-time switch callers can branch on without `cfg`.
    pub const ENABLED: bool = false;

    /// Zero-sized stand-in: constructing and dropping it is a no-op
    /// the optimizer deletes (`profiler_guard_is_zero_sized_when_off`
    /// pins the size).
    pub struct PhaseGuard;

    #[inline(always)]
    pub fn scope(_phase: Phase) -> PhaseGuard {
        PhaseGuard
    }

    #[inline(always)]
    pub fn take() -> [f64; NUM_PHASES] {
        [0.0; NUM_PHASES]
    }
}

pub use imp::*;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(not(feature = "profiling"))]
    fn profiler_guard_is_zero_sized_when_off() {
        assert_eq!(std::mem::size_of::<PhaseGuard>(), 0, "feature-off guard must cost nothing");
        let _g = scope(Phase::Gemm);
        assert_eq!(take(), [0.0; NUM_PHASES]);
    }

    #[test]
    #[cfg(feature = "profiling")]
    fn scopes_accumulate_and_take_drains() {
        // Other tests may profile concurrently; drain first and assert
        // only lower bounds on our own contribution.
        let _ = take();
        {
            let _g = scope(Phase::Attention);
            std::thread::sleep(std::time::Duration::from_millis(3));
        }
        let ms = take();
        assert!(
            ms[Phase::Attention as usize] >= 2.0,
            "attention scope must record its sleep: {ms:?}"
        );
        // A second drain without new scopes from this thread reports
        // (at least) nothing from us — exact zero only when no other
        // test is running engines, so just check it does not explode.
        let again = take();
        for v in again {
            assert!(v >= 0.0);
        }
    }

    #[test]
    fn phase_names_line_up_with_discriminants() {
        assert_eq!(PHASE_NAMES[Phase::RotQuant as usize], "rot_quant");
        assert_eq!(PHASE_NAMES[Phase::Gemm as usize], "gemm");
        assert_eq!(PHASE_NAMES[Phase::Attention as usize], "attention");
        assert_eq!(PHASE_NAMES[Phase::Sampler as usize], "sampler");
    }
}
