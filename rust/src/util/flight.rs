//! Flight recorder: a process-global bounded ring of recent
//! coordinator events (admissions, round summaries, preemptions,
//! sheds, deadline expiries, restarts).
//!
//! The point is post-mortems: when the scheduling round panics and the
//! PR 6 `catch_unwind` fires, the coordinator dumps this ring through
//! the structured logger ([`dump_to_log`]) so the rounds *leading up
//! to* the crash are visible, not just the restart counter. The same
//! ring is queryable live over the wire via the `dump` op
//! ([`dump_json`], see `docs/PROTOCOL.md`).
//!
//! Recording is a short mutex-guarded push — microseconds against
//! millisecond-scale scheduling rounds — and the ring is capacity
//! bounded ([`CAP`]), so memory stays flat forever. The ring is
//! process-global on purpose (one serving process, one black box);
//! tests that assert on contents take `failpoint::exclusive()` and
//! [`clear`] first so concurrent coordinators cannot interleave.

use crate::util::json::Json;
use crate::util::log;
use std::collections::VecDeque;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Maximum retained events; older entries are evicted FIFO.
pub const CAP: usize = 256;

#[derive(Clone, Debug)]
struct FlightEvent {
    /// Milliseconds since the recorder first ticked (monotonic).
    at_ms: f64,
    /// Coarse event class: `admit`, `round`, `preempt`, `shed`,
    /// `deadline`, `restart`, `panic`, ...
    kind: &'static str,
    /// Free-form `key=value` detail, including request ids.
    detail: String,
}

static RING: Mutex<VecDeque<FlightEvent>> = Mutex::new(VecDeque::new());
static T0: OnceLock<Instant> = OnceLock::new();

/// Injected panics can poison the mutex mid-unwind; the ring is plain
/// data, so poison is noise.
fn ring() -> MutexGuard<'static, VecDeque<FlightEvent>> {
    RING.lock().unwrap_or_else(|e| e.into_inner())
}

fn now_ms() -> f64 {
    T0.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}

/// Append one event, evicting the oldest when full.
pub fn record(kind: &'static str, detail: String) {
    let ev = FlightEvent { at_ms: now_ms(), kind, detail };
    let mut r = ring();
    if r.len() == CAP {
        r.pop_front();
    }
    r.push_back(ev);
}

/// Number of retained events.
pub fn len() -> usize {
    ring().len()
}

/// Drop every retained event (tests).
pub fn clear() {
    ring().clear();
}

/// Snapshot the ring, oldest first, as an array of
/// `{"at_ms", "kind", "detail"}` objects (the `dump` op payload).
pub fn dump_json() -> Json {
    let r = ring();
    Json::Arr(
        r.iter()
            .map(|ev| {
                Json::obj(vec![
                    ("at_ms", Json::num((ev.at_ms * 10.0).round() / 10.0)),
                    ("kind", Json::str(ev.kind)),
                    ("detail", Json::str(&ev.detail)),
                ])
            })
            .collect(),
    )
}

/// Dump the ring through the structured logger at error level — called
/// by the coordinator when `catch_unwind` traps a scheduling-round
/// panic, so the black box lands in stderr next to the panic message.
pub fn dump_to_log() {
    let events: Vec<FlightEvent> = ring().iter().cloned().collect();
    log::error(
        "flight",
        "flight recorder dump (oldest first)",
        &[("events", events.len().to_string())],
    );
    for ev in &events {
        log::error(
            "flight",
            ev.kind,
            &[("at_ms", format!("{:.1}", ev.at_ms)), ("detail", ev.detail.clone())],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The ring is process-global and other tests' coordinators record
    // into it concurrently, so these tests only assert properties that
    // survive interleaving: capacity bounds and the presence of their
    // own uniquely-tagged events immediately after recording.

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        for i in 0..(CAP + 50) {
            record("test.flood", format!("i={i}"));
        }
        assert!(len() <= CAP);
        let Json::Arr(evs) = dump_json() else { panic!("dump is an array") };
        assert!(evs.len() <= CAP);
        // The newest flood entry survived eviction.
        let last_detail = format!("i={}", CAP + 49);
        assert!(
            evs.iter().any(|e| {
                e.get("kind").and_then(|k| k.as_str()) == Some("test.flood")
                    && e.get("detail").and_then(|d| d.as_str()) == Some(last_detail.as_str())
            }),
            "newest event must be retained"
        );
    }

    #[test]
    fn dump_carries_timestamps_and_details() {
        record("test.shape", "req=42 note=shape-check".to_string());
        let Json::Arr(evs) = dump_json() else { panic!("dump is an array") };
        let mine = evs
            .iter()
            .rev()
            .find(|e| e.get("kind").and_then(|k| k.as_str()) == Some("test.shape"))
            .expect("just-recorded event present");
        assert!(mine.get("at_ms").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert!(mine
            .get("detail")
            .and_then(|d| d.as_str())
            .unwrap()
            .contains("req=42"));
    }

    #[test]
    fn clear_empties_only_until_someone_records_again() {
        record("test.clear", "x".into());
        clear();
        // Concurrent tests may push immediately after; assert only that
        // our own pre-clear event is gone.
        let Json::Arr(evs) = dump_json() else { panic!("dump is an array") };
        assert!(
            !evs.iter().any(|e| e.get("kind").and_then(|k| k.as_str()) == Some("test.clear")),
            "cleared events must not reappear"
        );
    }
}
