//! Cache-line-aligned fixed scratch for the unpack-once kernel path.
//!
//! The SIMD kernels (`quant::simd`) use unaligned loads, so alignment
//! is a throughput concern (no split-line loads, clean prefetch), not a
//! correctness one — but the hot GEMM decodes one weight block into
//! this scratch and then streams every batch column over it, so keeping
//! it on one set of cache lines is worth the fixed footprint.

/// Largest block any [`crate::quant::Format`] decodes (the itq3_s@512
/// ablation block; every other format is ≤ 256).
pub const MAX_BLOCK: usize = 512;

/// 64-byte-aligned i8 scratch for one decoded weight block.
#[repr(C, align(64))]
pub struct AlignedBlockI8(pub [i8; MAX_BLOCK]);

impl AlignedBlockI8 {
    #[inline]
    pub fn zeroed() -> Self {
        AlignedBlockI8([0; MAX_BLOCK])
    }
}

impl Default for AlignedBlockI8 {
    fn default() -> Self {
        Self::zeroed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_scratch_is_cache_line_aligned() {
        assert_eq!(std::mem::align_of::<AlignedBlockI8>(), 64);
        let b = AlignedBlockI8::zeroed();
        assert_eq!(b.0.as_ptr() as usize % 64, 0);
        assert!(b.0.iter().all(|&v| v == 0));
    }
}
