//! Row-sharded parallel execution for the quantized matvec/matmul hot
//! paths (the CPU stand-in for the paper's SM-level row parallelism).
//!
//! Deliberately **work-stealing-free**: the output rows of a matvec are
//! split into `shards` contiguous ranges, one per thread, decided up
//! front. Because every row is computed by exactly the same code in the
//! same order regardless of which shard owns it, the parallel result is
//! bit-identical to the single-threaded one — the property the
//! `parallel_matvec_bit_identical` test pins down, and what keeps greedy
//! decoding reproducible across thread counts.
//!
//! Execution uses `std::thread::scope` (no persistent pool, no unsafe):
//! shards 1..N are spawned, shard 0 runs on the calling thread. The
//! ~tens-of-microseconds spawn cost is why callers gate parallelism on
//! [`suggested_shards`] — a shard must carry at least
//! [`MIN_MACS_PER_SHARD`] multiply-accumulates before forking pays, so
//! small layers (e.g. the 256-wide unit-test model) stay on the fast
//! single-threaded path automatically.

use std::ops::Range;
use std::sync::OnceLock;

/// Upper bound on worker threads: `ITQ3S_THREADS` env override, else the
/// machine's available parallelism, capped at 16 (beyond that the
/// decode-path matvecs are memory-bound and extra threads only contend).
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("ITQ3S_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                if n >= 1 {
                    return n.min(64);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(16)
    })
}

/// Minimum multiply-accumulates per shard before forking is a net win
/// (thread spawn ≈ tens of µs; a shard this size runs for hundreds).
pub const MIN_MACS_PER_SHARD: usize = 1 << 19;

/// Shard count for a `(rows x cols)` matvec: enough shards to keep every
/// shard above [`MIN_MACS_PER_SHARD`], never more than [`default_threads`]
/// or `rows`. Returns 1 for small layers — the caller then runs inline.
pub fn suggested_shards(rows: usize, total_macs: usize) -> usize {
    if rows == 0 {
        return 1;
    }
    let by_work = total_macs / MIN_MACS_PER_SHARD;
    by_work.clamp(1, default_threads()).min(rows)
}

/// The contiguous sub-range of `0..n` owned by shard `s` of `shards`
/// (near-equal split; the first `n % shards` shards get one extra).
pub fn shard_range(n: usize, s: usize, shards: usize) -> Range<usize> {
    debug_assert!(s < shards);
    let base = n / shards;
    let rem = n % shards;
    let start = s * base + s.min(rem);
    let len = base + usize::from(s < rem);
    start..start + len
}

/// Run `f(first_chunk_index, chunk_slice)` over `data` split into
/// contiguous shards aligned to `chunk_len` elements. `data.len()` must
/// be a multiple of `chunk_len`. With `shards <= 1` (or a single chunk)
/// this degenerates to one inline call — zero threading overhead.
pub fn parallel_chunks<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    shards: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len() / chunk_len;
    assert_eq!(data.len(), n_chunks * chunk_len, "data not chunk-aligned");
    let shards = shards.max(1).min(n_chunks.max(1));
    if shards <= 1 {
        f(0, data);
        return;
    }
    let first_chunks = shard_range(n_chunks, 0, shards).len();
    let (first, tail) = data.split_at_mut(first_chunks * chunk_len);
    let mut rest = tail;
    std::thread::scope(|scope| {
        let fref = &f;
        let mut start_chunk = first_chunks;
        for s in 1..shards {
            let len_chunks = shard_range(n_chunks, s, shards).len();
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut(len_chunks * chunk_len);
            rest = tail;
            let c0 = start_chunk;
            scope.spawn(move || fref(c0, head));
            start_chunk += len_chunks;
        }
        debug_assert!(rest.is_empty());
        // Shard 0 runs on the calling thread, concurrently with the rest.
        fref(0, first);
    });
}

/// [`parallel_chunks`] with one element per chunk: `f(first_row, rows)`.
pub fn parallel_rows<T: Send>(
    data: &mut [T],
    shards: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    parallel_chunks(data, 1, shards, f);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_exactly() {
        crate::util::prop::forall("shard ranges partition 0..n", 200, |g| {
            let n = g.usize_in(0, 500);
            let shards = g.usize_in(1, 16);
            let mut next = 0usize;
            for s in 0..shards {
                let r = shard_range(n, s, shards);
                assert_eq!(r.start, next, "gap at shard {s}");
                next = r.end;
            }
            assert_eq!(next, n, "ranges must cover 0..{n}");
        });
    }

    #[test]
    fn parallel_matches_serial() {
        let mut serial = vec![0u64; 1000];
        for (i, v) in serial.iter_mut().enumerate() {
            *v = (i as u64).wrapping_mul(0x9E37_79B9);
        }
        for shards in [1, 2, 3, 7, 16] {
            let mut par = vec![0u64; 1000];
            parallel_rows(&mut par, shards, |row0, out| {
                for (d, v) in out.iter_mut().enumerate() {
                    *v = ((row0 + d) as u64).wrapping_mul(0x9E37_79B9);
                }
            });
            assert_eq!(par, serial, "shards={shards}");
        }
    }

    #[test]
    fn chunked_sharding_keeps_chunks_whole() {
        // 30 chunks of 4; every shard must receive whole chunks.
        let mut data = vec![0usize; 120];
        parallel_chunks(&mut data, 4, 4, |c0, slab| {
            assert_eq!(slab.len() % 4, 0);
            for (i, chunk) in slab.chunks_exact_mut(4).enumerate() {
                for v in chunk.iter_mut() {
                    *v = c0 + i;
                }
            }
        });
        for (i, chunk) in data.chunks_exact(4).enumerate() {
            assert!(chunk.iter().all(|&v| v == i), "chunk {i}: {chunk:?}");
        }
    }

    #[test]
    fn oversubscription_is_clamped() {
        // More shards than rows: must not panic, must still be correct.
        let mut data = vec![0u8; 3];
        parallel_rows(&mut data, 64, |row0, out| {
            for (d, v) in out.iter_mut().enumerate() {
                *v = (row0 + d) as u8 + 1;
            }
        });
        assert_eq!(data, vec![1, 2, 3]);
    }

    #[test]
    fn empty_input_is_a_noop() {
        let mut data: Vec<u32> = Vec::new();
        parallel_rows(&mut data, 4, |_, out| {
            assert!(out.is_empty());
        });
    }

    #[test]
    fn suggested_shards_gates_small_work() {
        // Tiny decode layers must stay single-threaded...
        assert_eq!(suggested_shards(256, 256 * 256), 1);
        // ...while serving-size layers fan out (bounded by threads/rows).
        let s = suggested_shards(4096, 4096 * 4096);
        assert!(s >= 1 && s <= default_threads().min(4096));
        if default_threads() > 1 {
            assert!(s > 1, "16.7M MACs should shard on a multicore host");
        }
        assert_eq!(suggested_shards(0, 0), 1);
    }

    #[test]
    fn default_threads_is_stable_and_positive() {
        let a = default_threads();
        assert!(a >= 1);
        assert_eq!(a, default_threads());
    }
}
