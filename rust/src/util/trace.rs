//! Per-request trace timelines: where did this request's latency go?
//!
//! A request that opts in (`"trace": true` on the wire, or
//! `GenRequest::trace`) carries a [`RequestTrace`] through the
//! scheduler. The coordinator records one [`TraceEventKind`] per
//! lifecycle step — queued → admitted (with prefix-reuse count) → each
//! prefill chunk (token count) → each decode round (batch size) → each
//! spec verify round (drafted/accepted) → preemption/requeue →
//! restart-implicated → terminal — and accumulates wall time into the
//! phase buckets that make up the `timing` object on the terminal
//! `done` line (`queue_ms` + `prefill_ms` + `decode_ms` ≈ `total_ms`;
//! the remainder is scheduler bookkeeping between rounds).
//!
//! Completed timelines land in a bounded [`TraceStore`] ring owned by
//! the coordinator worker and are served newest-first by the `trace`
//! op (`docs/PROTOCOL.md`). Event lists are bounded ([`MAX_EVENTS`])
//! so a 100k-token generation cannot grow a trace without limit —
//! overflow is counted, not silently dropped.
//!
//! Everything here is monotonic-clock based ([`Span`]); tracing an
//! individual request never perturbs its tokens (asserted by
//! `tracing_does_not_change_tokens` in the coordinator tests).

use crate::util::json::Json;
use std::collections::VecDeque;
use std::time::Instant;

/// Maximum retained events per request; later events bump
/// `dropped_events` instead of growing the list.
pub const MAX_EVENTS: usize = 256;

/// A monotonic scoped timer: `Span::begin()` … `span.ms()`.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    t0: Instant,
}

impl Span {
    pub fn begin() -> Span {
        Span { t0: Instant::now() }
    }

    /// Milliseconds elapsed since [`Span::begin`].
    pub fn ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Span {
    fn default() -> Self {
        Span::begin()
    }
}

/// One step in a request's lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEventKind {
    /// Entered (or re-entered, after preemption/restart) the admission
    /// queue.
    Queued,
    /// Admitted into the running batch of engine replica `replica`;
    /// `prefix_reused` prompt tokens came from the paged prefix cache.
    /// Replica ids are 0-based; a single-engine coordinator stamps 0.
    Admitted { prefix_reused: usize, replica: usize },
    /// One prefill chunk of `tokens` prompt tokens ran.
    PrefillChunk { tokens: usize },
    /// One fused decode round ran with `batch` sequences.
    DecodeRound { batch: usize },
    /// One speculative verify pass: `drafted` proposed, `accepted` kept.
    SpecVerify { drafted: usize, accepted: usize },
    /// Preempted (KV pressure) and sent back to the queue.
    Preempted,
    /// Implicated in a scheduling-round panic; requeued (or failed).
    RestartImplicated,
    /// Terminal reached (`done` reason or error code).
    Terminal,
}

impl TraceEventKind {
    fn what(&self) -> &'static str {
        match self {
            TraceEventKind::Queued => "queued",
            TraceEventKind::Admitted { .. } => "admitted",
            TraceEventKind::PrefillChunk { .. } => "prefill_chunk",
            TraceEventKind::DecodeRound { .. } => "decode_round",
            TraceEventKind::SpecVerify { .. } => "spec_verify",
            TraceEventKind::Preempted => "preempted",
            TraceEventKind::RestartImplicated => "restart_implicated",
            TraceEventKind::Terminal => "terminal",
        }
    }
}

fn round3(x: f64) -> f64 {
    (x * 1e3).round() / 1e3
}

/// The per-request timeline + phase accumulators. Created at intake,
/// carried inside the sequence state, finished into a [`TraceStore`].
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Coordinator-assigned request id (1-based, per process).
    pub id: u64,
    t0: Instant,
    events: Vec<(f64, TraceEventKind)>,
    dropped: u64,
    /// Set while the request sits in the admission queue.
    queued_at: Option<Instant>,
    queue_ms: f64,
    prefill_ms: f64,
    decode_ms: f64,
    spec_saved_tokens: u64,
    preemptions: u64,
    prefill_rounds: u64,
    decode_rounds: u64,
    spec_rounds: u64,
    audit_rounds: u64,
    audit_kl_max: f64,
    audit_max_logit_delta: f64,
    audit_top1_disagreements: u64,
}

impl RequestTrace {
    /// Start a trace at intake: the request is queued from birth.
    pub fn new(id: u64) -> RequestTrace {
        let mut t = RequestTrace {
            id,
            t0: Instant::now(),
            events: Vec::new(),
            dropped: 0,
            queued_at: None,
            queue_ms: 0.0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            spec_saved_tokens: 0,
            preemptions: 0,
            prefill_rounds: 0,
            decode_rounds: 0,
            spec_rounds: 0,
            audit_rounds: 0,
            audit_kl_max: 0.0,
            audit_max_logit_delta: 0.0,
            audit_top1_disagreements: 0,
        };
        t.record(TraceEventKind::Queued);
        t
    }

    /// Record one lifecycle event (bounded) and fold it into the
    /// phase accumulators.
    pub fn record(&mut self, kind: TraceEventKind) {
        match kind {
            TraceEventKind::Queued => self.queued_at = Some(Instant::now()),
            TraceEventKind::Admitted { .. } => {
                if let Some(q) = self.queued_at.take() {
                    self.queue_ms += q.elapsed().as_secs_f64() * 1e3;
                }
            }
            TraceEventKind::PrefillChunk { .. } => self.prefill_rounds += 1,
            TraceEventKind::DecodeRound { .. } => self.decode_rounds += 1,
            TraceEventKind::SpecVerify { accepted, .. } => {
                self.spec_rounds += 1;
                self.spec_saved_tokens += accepted as u64;
            }
            TraceEventKind::Preempted => self.preemptions += 1,
            TraceEventKind::RestartImplicated | TraceEventKind::Terminal => {}
        }
        if self.events.len() < MAX_EVENTS {
            let at_ms = self.t0.elapsed().as_secs_f64() * 1e3;
            self.events.push((at_ms, kind));
        } else {
            self.dropped += 1;
        }
    }

    /// Add measured engine wall time to the prefill bucket.
    pub fn add_prefill_ms(&mut self, ms: f64) {
        self.prefill_ms += ms;
    }

    /// Add measured engine wall time to the decode bucket (fused
    /// rounds and spec verify passes both land here — they are the
    /// generation phase).
    pub fn add_decode_ms(&mut self, ms: f64) {
        self.decode_ms += ms;
    }

    /// Fold one numerics-audit shadow probe that sampled this request
    /// into the trace (PR 9). The `timing` object grows an `audit`
    /// section once at least one probe landed; un-audited requests are
    /// byte-identical to their pre-PR-9 shape.
    pub fn note_audit(&mut self, kl: f64, top1_agree: bool, max_logit_delta: f64) {
        self.audit_rounds += 1;
        self.audit_kl_max = self.audit_kl_max.max(kl);
        self.audit_max_logit_delta = self.audit_max_logit_delta.max(max_logit_delta);
        if !top1_agree {
            self.audit_top1_disagreements += 1;
        }
    }

    /// The `timing` object carried by the terminal line. Queue time
    /// still accruing (terminal reached while queued) is included.
    pub fn timing_json(&self) -> Json {
        let queue_ms =
            self.queue_ms + self.queued_at.map_or(0.0, |q| q.elapsed().as_secs_f64() * 1e3);
        let mut fields = vec![
            ("queue_ms", Json::num(round3(queue_ms))),
            ("prefill_ms", Json::num(round3(self.prefill_ms))),
            ("decode_ms", Json::num(round3(self.decode_ms))),
            ("spec_saved_tokens", Json::num(self.spec_saved_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("prefill_rounds", Json::num(self.prefill_rounds as f64)),
            ("decode_rounds", Json::num(self.decode_rounds as f64)),
            ("spec_rounds", Json::num(self.spec_rounds as f64)),
        ];
        if self.audit_rounds > 0 {
            fields.push((
                "audit",
                Json::obj(vec![
                    ("rounds", Json::num(self.audit_rounds as f64)),
                    ("kl_max", Json::num(self.audit_kl_max)),
                    ("max_logit_delta", Json::num(self.audit_max_logit_delta)),
                    (
                        "top1_disagreements",
                        Json::num(self.audit_top1_disagreements as f64),
                    ),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Render the full timeline (for the `trace` op); `reason` is the
    /// terminal `done` reason or error code.
    pub fn timeline_json(&self, reason: &str) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|(at_ms, kind)| {
                let mut fields = vec![
                    ("at_ms", Json::num(round3(*at_ms))),
                    ("what", Json::str(kind.what())),
                ];
                match *kind {
                    TraceEventKind::Admitted { prefix_reused, replica } => {
                        fields.push(("prefix_reused", Json::num(prefix_reused as f64)));
                        fields.push(("replica", Json::num(replica as f64)));
                    }
                    TraceEventKind::PrefillChunk { tokens } => {
                        fields.push(("tokens", Json::num(tokens as f64)));
                    }
                    TraceEventKind::DecodeRound { batch } => {
                        fields.push(("batch", Json::num(batch as f64)));
                    }
                    TraceEventKind::SpecVerify { drafted, accepted } => {
                        fields.push(("drafted", Json::num(drafted as f64)));
                        fields.push(("accepted", Json::num(accepted as f64)));
                    }
                    _ => {}
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("reason", Json::str(reason)),
            ("total_ms", Json::num(round3(self.t0.elapsed().as_secs_f64() * 1e3))),
            ("timing", self.timing_json()),
            ("events", Json::Arr(events)),
            ("dropped_events", Json::num(self.dropped as f64)),
        ])
    }
}

/// Bounded ring of completed timelines, owned by the coordinator
/// worker and served newest-first by the `trace` op.
#[derive(Debug, Default)]
pub struct TraceStore {
    ring: VecDeque<Json>,
    cap: usize,
}

impl TraceStore {
    pub fn new(cap: usize) -> TraceStore {
        TraceStore { ring: VecDeque::new(), cap: cap.max(1) }
    }

    /// Retire a finished trace into the ring.
    pub fn push(&mut self, timeline: Json) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(timeline);
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The `n` most recent completed timelines, newest first.
    pub fn recent(&self, n: usize) -> Json {
        Json::Arr(self.ring.iter().rev().take(n).cloned().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_is_monotonic() {
        let s = Span::begin();
        let a = s.ms();
        let b = s.ms();
        assert!(a >= 0.0 && b >= a);
    }

    #[test]
    fn lifecycle_events_feed_the_accumulators() {
        let mut t = RequestTrace::new(7);
        t.record(TraceEventKind::Admitted { prefix_reused: 3, replica: 0 });
        t.record(TraceEventKind::PrefillChunk { tokens: 8 });
        t.add_prefill_ms(1.5);
        t.record(TraceEventKind::DecodeRound { batch: 2 });
        t.add_decode_ms(0.75);
        t.record(TraceEventKind::SpecVerify { drafted: 4, accepted: 3 });
        t.add_decode_ms(0.25);
        t.record(TraceEventKind::Preempted);
        t.record(TraceEventKind::RestartImplicated);
        t.record(TraceEventKind::Queued);
        t.record(TraceEventKind::Admitted { prefix_reused: 11, replica: 1 });
        t.record(TraceEventKind::Terminal);

        let timing = t.timing_json();
        assert_eq!(timing.get("prefill_ms").unwrap().as_f64(), Some(1.5));
        assert_eq!(timing.get("decode_ms").unwrap().as_f64(), Some(1.0));
        assert_eq!(timing.get("spec_saved_tokens").unwrap().as_u64(), Some(3));
        assert_eq!(timing.get("preemptions").unwrap().as_u64(), Some(1));
        assert_eq!(timing.get("prefill_rounds").unwrap().as_u64(), Some(1));
        assert_eq!(timing.get("decode_rounds").unwrap().as_u64(), Some(1));
        assert_eq!(timing.get("spec_rounds").unwrap().as_u64(), Some(1));
        // Two queued→admitted stints, both captured.
        assert!(timing.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);

        let tl = t.timeline_json("max_tokens");
        assert_eq!(tl.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(tl.get("reason").unwrap().as_str(), Some("max_tokens"));
        let evs = tl.get("events").unwrap().as_arr().unwrap();
        // Birth Queued + the 9 recorded above.
        assert_eq!(evs.len(), 10);
        assert_eq!(evs[0].get("what").unwrap().as_str(), Some("queued"));
        assert_eq!(evs[1].get("prefix_reused").unwrap().as_u64(), Some(3));
        assert_eq!(evs[1].get("replica").unwrap().as_u64(), Some(0));
        // The re-admission after preemption landed on replica 1.
        assert_eq!(evs[8].get("replica").unwrap().as_u64(), Some(1));
        let last = evs.last().unwrap();
        assert_eq!(last.get("what").unwrap().as_str(), Some("terminal"));
        // Timestamps are monotone non-decreasing.
        let mut prev = -1.0;
        for e in evs {
            let at = e.get("at_ms").unwrap().as_f64().unwrap();
            assert!(at >= prev);
            prev = at;
        }
    }

    #[test]
    fn audit_section_appears_only_after_a_probe() {
        let mut t = RequestTrace::new(9);
        assert!(
            t.timing_json().get("audit").is_none(),
            "un-audited requests keep the pre-audit timing shape"
        );
        t.note_audit(0.01, true, 0.5);
        t.note_audit(0.25, false, 0.125);
        let timing = t.timing_json();
        let audit = timing.get("audit").expect("audit section after probes");
        assert_eq!(audit.get("rounds").unwrap().as_u64(), Some(2));
        assert_eq!(audit.get("kl_max").unwrap().as_f64(), Some(0.25));
        assert_eq!(audit.get("max_logit_delta").unwrap().as_f64(), Some(0.5));
        assert_eq!(audit.get("top1_disagreements").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn event_list_is_bounded_and_overflow_is_counted() {
        let mut t = RequestTrace::new(1);
        for _ in 0..(MAX_EVENTS + 100) {
            t.record(TraceEventKind::DecodeRound { batch: 1 });
        }
        let tl = t.timeline_json("max_tokens");
        assert_eq!(tl.get("events").unwrap().as_arr().unwrap().len(), MAX_EVENTS);
        // +1: the birth Queued event occupied one slot.
        assert_eq!(tl.get("dropped_events").unwrap().as_u64(), Some(101));
        // Overflowed events still count toward the phase accumulators.
        assert_eq!(
            tl.get("timing").unwrap().get("decode_rounds").unwrap().as_u64(),
            Some((MAX_EVENTS + 100) as u64)
        );
    }

    #[test]
    fn unadmitted_terminal_folds_outstanding_queue_time() {
        let t = RequestTrace::new(2);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let timing = t.timing_json();
        assert!(
            timing.get("queue_ms").unwrap().as_f64().unwrap() >= 4.0,
            "queue time must accrue until the terminal for never-admitted requests"
        );
    }

    #[test]
    fn store_is_a_bounded_newest_first_ring() {
        let mut s = TraceStore::new(3);
        for i in 0..5u64 {
            let t = RequestTrace::new(i);
            s.push(t.timeline_json("max_tokens"));
        }
        assert_eq!(s.len(), 3);
        let recent = s.recent(2);
        let arr = recent.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("id").unwrap().as_u64(), Some(4), "newest first");
        assert_eq!(arr[1].get("id").unwrap().as_u64(), Some(3));
        // Asking for more than retained returns what exists.
        assert_eq!(s.recent(10).as_arr().unwrap().len(), 3);
    }
}
