//! Streaming and batch statistics used by the quantizers (block scale
//! estimation), the distribution-smoothing analysis (Theorem 1 / Cor 1
//! reproduction), and the benchmark harness (latency percentiles).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Mean absolute value — used for the closed-form ternary scale
/// `d* = 2/3 E|x|` mentioned in Remark 1 of the paper.
pub fn mean_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| (x as f64).abs()).sum::<f64>() / xs.len() as f64
}

/// ℓ∞ norm.
pub fn linf(xs: &[f32]) -> f64 {
    xs.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs()))
}

/// ℓ2 norm.
pub fn l2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Kurtosis (4th standardized moment; Gaussian = 3). The paper's Theorem 1
/// claim is that FWHT drives block kurtosis toward 3.
pub fn kurtosis(xs: &[f32]) -> f64 {
    let m = mean(xs);
    let v = variance(xs);
    if v == 0.0 || xs.is_empty() {
        return 0.0;
    }
    let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / xs.len() as f64;
    m4 / (v * v)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Relative ℓ2 reconstruction error ‖a−b‖₂ / ‖a‖₂.
///
/// A zero-norm `reference` has no meaningful relative error: dividing
/// by the old `1e-30` clamp turned any nonzero `approx` into a ~1e30
/// garbage value that would poison an audit ring the same way the
/// pre-`total_cmp` percentile NaN did. Instead the absolute difference
/// norm is returned in that case (0 when both sides are zero), so the
/// result is always finite and never NaN.
pub fn rel_l2_err(reference: &[f32], approx: &[f32]) -> f64 {
    let denom = l2(reference);
    let num = reference
        .iter()
        .zip(approx)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    if denom == 0.0 {
        num
    } else {
        num / denom
    }
}

/// Percentile over a pre-sorted-or-not sample (nearest-rank, p in [0,100]).
/// Total order via `f64::total_cmp`: a NaN that slips into a metrics
/// ring (e.g. a 0/0 rate) sorts after +Inf instead of panicking the
/// whole metrics path mid-`sort_by`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).floor() as usize;
    v[rank.min(v.len() - 1)]
}

/// Online Welford accumulator, used by the serving metrics and the
/// benchmark harness so per-request latencies need not all be retained.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Fold another accumulator into this one (Chan et al. parallel
    /// combine). Exact for count/mean/min/max and the usual numerically
    /// stable merge for m2 — used to aggregate per-replica serving
    /// metrics into one snapshot.
    pub fn merge_from(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n += other.n;
    }
}

/// Bounded latency-distribution accumulator: a [`Welford`] for exact
/// streaming mean/min/max/count plus a fixed-capacity ring of recent
/// samples for p50/p99. Memory is O(capacity) regardless of how many
/// samples flow through — serving metrics stay flat under sustained
/// load (the percentiles are over the most recent window, which is the
/// operationally useful view anyway).
#[derive(Clone, Debug)]
pub struct RingStats {
    w: Welford,
    ring: Vec<f64>,
    cap: usize,
    next: usize,
}

impl RingStats {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        RingStats { w: Welford::new(), ring: Vec::new(), cap: capacity, next: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.w.push(x);
        if self.ring.len() < self.cap {
            self.ring.push(x);
        } else {
            self.ring[self.next] = x;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Total samples ever pushed (not just the retained window).
    pub fn count(&self) -> u64 {
        self.w.count()
    }

    /// Exact mean over all samples ever pushed.
    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// Exact max over all samples ever pushed.
    pub fn max(&self) -> f64 {
        self.w.max()
    }

    /// Percentile over the retained window (nearest-rank).
    pub fn window_percentile(&self, p: f64) -> f64 {
        percentile(&self.ring, p)
    }

    pub fn p50(&self) -> f64 {
        self.window_percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.window_percentile(99.0)
    }

    /// The retained window in chronological (push) order.
    fn window(&self) -> impl Iterator<Item = f64> + '_ {
        let split = if self.ring.len() < self.cap { 0 } else { self.next };
        self.ring[split..].iter().chain(self.ring[..split].iter()).copied()
    }

    /// Fold another ring into this one: the Welford halves combine
    /// exactly; the window absorbs the other's retained samples in
    /// chronological order (oldest evicted first, as if pushed here).
    /// An empty receiver becomes a verbatim clone, so merging N=1
    /// replica metrics into a fresh accumulator is byte-identical to
    /// the unmerged original.
    pub fn merge_from(&mut self, other: &RingStats) {
        if other.w.count() == 0 {
            return;
        }
        if self.w.count() == 0 && self.cap == other.cap {
            *self = other.clone();
            return;
        }
        self.w.merge_from(&other.w);
        for x in other.window() {
            if self.ring.len() < self.cap {
                self.ring.push(x);
            } else {
                self.ring[self.next] = x;
            }
            self.next = (self.next + 1) % self.cap;
        }
    }
}

/// Fixed log-bucketed histogram: exact bounded-memory counts with
/// geometrically growing bucket bounds.
///
/// Complements [`RingStats`]: the ring gives exact percentiles over a
/// *recent window*, the histogram gives process-lifetime quantile
/// *estimates* (within one bucket-growth factor) plus the cumulative
/// bucket counts Prometheus histograms want. Bucket `0` holds
/// `x <= base`; bucket `i` holds `base·growth^(i-1) < x <=
/// base·growth^i`; the last bucket is the `+Inf` overflow. Memory is
/// `O(buckets)` forever.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    base: f64,
    growth: f64,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl LogHistogram {
    pub fn new(base: f64, growth: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && growth > 1.0 && buckets >= 2);
        LogHistogram { base, growth, counts: vec![0; buckets], sum: 0.0, count: 0 }
    }

    /// The serving-latency default: bounds at `2^-10 ms ≈ 1 µs` up
    /// through `2^24 ms ≈ 4.7 h`, doubling — all bounds are exact
    /// binary floats, so their decimal rendering is stable.
    pub fn latency_ms() -> Self {
        LogHistogram::new(1.0 / 1024.0, 2.0, 36)
    }

    /// Upper bound of bucket `i` (`+Inf` for the overflow bucket).
    pub fn upper_bound(&self, i: usize) -> f64 {
        if i + 1 >= self.counts.len() {
            f64::INFINITY
        } else {
            self.base * self.growth.powi(i as i32)
        }
    }

    fn bucket_for(&self, x: f64) -> usize {
        let mut b = 0;
        let mut ub = self.base;
        while x > ub && b + 1 < self.counts.len() {
            b += 1;
            ub *= self.growth;
        }
        b
    }

    pub fn push(&mut self, x: f64) {
        let b = self.bucket_for(x);
        self.counts[b] += 1;
        self.sum += x;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative `(le, count)` pairs in Prometheus order; the final
    /// entry's bound is `+Inf` and its count equals [`Self::count`].
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (self.upper_bound(i), acc)
            })
            .collect()
    }

    /// Nearest-rank quantile estimate (`p` in `[0, 100]`): the upper
    /// bound of the bucket holding the ranked sample, so the estimate
    /// is always `>=` the exact value and overshoots by at most one
    /// `growth` factor. The overflow bucket reports its (finite)
    /// lower bound instead.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.count as f64 - 1.0)).floor() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc > rank {
                return if i + 1 >= self.counts.len() {
                    // Overflow bucket: no finite upper bound; report
                    // the largest finite bound as a floor.
                    self.base * self.growth.powi((i as i32) - 1)
                } else {
                    self.upper_bound(i)
                };
            }
        }
        self.base * self.growth.powi(self.counts.len() as i32 - 2)
    }

    /// Fold another histogram (same base/growth/bucket layout) into
    /// this one: bucket counts, sum, and count add elementwise — exact,
    /// since the bucket bounds are identical.
    pub fn merge_from(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "histogram layout mismatch");
        debug_assert!(self.base == other.base && self.growth == other.growth);
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
        assert!((mean_abs(&[-1.0f32, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let xs = [3.0f32, -4.0];
        assert_eq!(linf(&xs), 4.0);
        assert!((l2(&xs) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn kurtosis_gaussian_near_3() {
        let mut r = crate::util::XorShift::new(5);
        let xs: Vec<f32> = (0..100_000).map(|_| r.next_gaussian() as f32).collect();
        let k = kurtosis(&xs);
        assert!((k - 3.0).abs() < 0.15, "k={k}");
    }

    #[test]
    fn mse_and_rel_err() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 3.0];
        assert_eq!(mse(&a, &b), 0.0);
        assert_eq!(rel_l2_err(&a, &b), 0.0);
        let c = [1.0f32, 2.0, 4.0];
        assert!((mse(&a, &c) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rel_l2_err_zero_norm_reference_is_finite() {
        // Regression: an all-zero reference divided by the 1e-30 clamp
        // used to yield ~1e30 garbage (and NaN once squared into a
        // Welford accumulator). Zero-norm now means absolute error.
        let z = [0.0f32; 4];
        let y = [3.0f32, 0.0, -4.0, 0.0];
        assert_eq!(rel_l2_err(&z, &z), 0.0);
        let e = rel_l2_err(&z, &y);
        assert!((e - 5.0).abs() < 1e-12, "absolute diff norm, got {e}");
        assert!(e.is_finite() && !e.is_nan());
        // Normal path unchanged.
        assert!((rel_l2_err(&y, &z) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
    }

    #[test]
    fn percentile_survives_nan_and_inf_samples() {
        // Regression: the old `partial_cmp(..).unwrap()` sort panicked
        // on the first NaN (e.g. a 0/0 accept rate) — through the
        // public ring path, one poisoned sample killed every later
        // stats/metrics call. total_cmp sorts NaN after +Inf instead.
        let mut r = RingStats::new(8);
        for x in [1.0, f64::NAN, 2.0, f64::INFINITY, 3.0, f64::NEG_INFINITY] {
            r.push(x);
        }
        let p0 = r.window_percentile(0.0);
        assert_eq!(p0, f64::NEG_INFINITY);
        // Finite ranks stay meaningful: the median of the window sits
        // among the finite samples.
        let p50 = r.p50();
        assert!(p50 >= 1.0 && p50 <= 3.0, "p50={p50}");
        // The top rank is NaN (sorted last) — returned, not panicked.
        assert!(r.window_percentile(100.0).is_nan());
        // Direct slice path too.
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
        assert_eq!(percentile(&[f64::NAN, 7.0], 0.0), 7.0);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64) * 1.7 - 9.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 3 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge_from(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // Merging into an empty accumulator is a verbatim copy.
        let mut empty = Welford::new();
        empty.merge_from(&whole);
        assert_eq!(empty.count(), whole.count());
        assert_eq!(empty.mean(), whole.mean());
        // Merging an empty one is a no-op.
        let before = whole.mean();
        whole.merge_from(&Welford::new());
        assert_eq!(whole.mean(), before);
    }

    #[test]
    fn ring_merge_into_empty_is_identity_and_windows_concatenate() {
        let mut src = RingStats::new(8);
        for i in 0..5 {
            src.push(i as f64);
        }
        let mut dst = RingStats::new(8);
        dst.merge_from(&src);
        assert_eq!(dst.count(), src.count());
        assert_eq!(dst.p50(), src.p50());
        assert_eq!(dst.max(), src.max());
        // Non-empty receiver: windows concatenate chronologically.
        let mut more = RingStats::new(8);
        more.push(100.0);
        more.push(200.0);
        dst.merge_from(&more);
        assert_eq!(dst.count(), 7);
        assert_eq!(dst.window_percentile(100.0), 200.0);
        assert_eq!(dst.max(), 200.0);
    }

    #[test]
    fn log_histogram_merge_adds_counts_exactly() {
        let mut a = LogHistogram::new(1.0, 2.0, 5);
        let mut b = LogHistogram::new(1.0, 2.0, 5);
        for x in [0.5, 3.0, 9.0] {
            a.push(x);
        }
        for x in [1.5, 3.5] {
            b.push(x);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 5);
        assert!((a.sum() - (0.5 + 3.0 + 9.0 + 1.5 + 3.5)).abs() < 1e-12);
        let cum = a.cumulative();
        assert_eq!(cum.last().unwrap().1, 5);
    }

    #[test]
    fn ring_stats_stay_bounded_and_percentiles_track_window() {
        let mut r = RingStats::new(64);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert_eq!(r.ring.len(), 64, "ring must not grow past capacity");
        // Window holds the last 64 samples: 9936..9999.
        assert!(r.p50() >= 9936.0 && r.p50() <= 9999.0);
        assert!(r.p99() >= r.p50());
        assert_eq!(r.max(), 9999.0);
        assert!((r.mean() - 4999.5).abs() < 1e-6);
    }

    #[test]
    fn welford_matches_batch() {
        let mut w = Welford::new();
        let xs = [2.0f64, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        // sample variance of xs is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_bucket_edges_are_inclusive_upper() {
        let mut h = LogHistogram::new(1.0, 2.0, 5); // bounds 1, 2, 4, 8, +Inf
        assert_eq!(h.upper_bound(0), 1.0);
        assert_eq!(h.upper_bound(3), 8.0);
        assert_eq!(h.upper_bound(4), f64::INFINITY);
        for x in [0.5, 1.0, 1.5, 2.0, 7.9, 8.0, 9.0, 1e9] {
            h.push(x);
        }
        // Boundary values land in the bucket they bound (inclusive
        // upper): 1.0 → bucket 0, 2.0 → bucket 1, 8.0 → bucket 3.
        let cum = h.cumulative();
        assert_eq!(cum[0], (1.0, 2)); // 0.5, 1.0
        assert_eq!(cum[1], (2.0, 4)); // + 1.5, 2.0
        assert_eq!(cum[2], (4.0, 4));
        assert_eq!(cum[3], (8.0, 6)); // + 7.9, 8.0
        assert_eq!(cum[4].1, 8); // overflow holds 9.0 and 1e9
        assert_eq!(cum[4].0, f64::INFINITY);
        assert_eq!(h.count(), 8);
        assert!((h.sum() - (0.5 + 1.0 + 1.5 + 2.0 + 7.9 + 8.0 + 9.0 + 1e9)).abs() < 1e-6);
    }

    #[test]
    fn prop_log_histogram_quantiles_within_one_growth_factor() {
        // Seeded random latency-looking samples, kept inside the
        // finite bucket range so the +Inf overflow bucket stays empty.
        let mut r = crate::util::XorShift::new(0xA11CE);
        for case in 0..8usize {
            let mut h = LogHistogram::latency_ms();
            let mut xs = Vec::new();
            let n = 50 + case * 137;
            for _ in 0..n {
                // Log-uniform over ~[0.002, 2000] ms: exercises many
                // buckets, avoids bucket 0's unbounded-below edge.
                let x = 10f64.powf(r.range_f64(-2.7, 3.3));
                h.push(x);
                xs.push(x);
            }
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
                let exact = percentile(&xs, p);
                let est = h.quantile(p);
                assert!(
                    est >= exact * (1.0 - 1e-12),
                    "case {case} p{p}: estimate {est} below exact {exact}"
                );
                assert!(
                    est <= exact * 2.0 * (1.0 + 1e-12),
                    "case {case} p{p}: estimate {est} beyond one growth factor of {exact}"
                );
            }
            assert_eq!(h.count(), n as u64);
        }
    }

    #[test]
    fn prop_ring_window_percentiles_match_exact_sort() {
        let mut r = crate::util::XorShift::new(7_654_321);
        for case in 0..8usize {
            let cap = 32 + (case % 3) * 61;
            let n = 10 + case * 73; // below and above capacity
            let mut ring = RingStats::new(cap);
            let mut all = Vec::new();
            for _ in 0..n {
                let x = r.range_f64(-50.0, 1500.0);
                ring.push(x);
                all.push(x);
            }
            // The ring's window is exactly the last `cap` samples.
            let window = if all.len() > cap { &all[all.len() - cap..] } else { &all[..] };
            for p in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
                assert_eq!(
                    ring.window_percentile(p),
                    percentile(window, p),
                    "case {case} cap {cap} n {n} p{p}"
                );
            }
            assert_eq!(ring.count(), n as u64);
            let exact_max = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(ring.max(), exact_max);
        }
    }
}
