//! Deterministic xorshift128+ PRNG.
//!
//! Replaces the `rand` crate (unavailable in the offline vendor set) for
//! every randomized component: synthetic weight generation, corpus
//! sampling, property-test case generation, and the QuIP#-sim random sign
//! diagonal. Deterministic seeding keeps all experiments reproducible.

/// xorshift128+ generator (Vigna, 2017). Not cryptographic; plenty for
/// simulation and test-case generation.
#[derive(Clone, Debug)]
pub struct XorShift {
    s0: u64,
    s1: u64,
}

impl XorShift {
    /// Create a generator from a seed. Seeds are mixed through
    /// splitmix64 so that small consecutive seeds give uncorrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        XorShift { s0, s1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Lemire-style bounded rejection to avoid modulo bias.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        // Draw until u1 is nonzero (probability ~2^-53 of retry).
        let mut u1 = self.next_f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Student-t with `dof` degrees of freedom — the heavy-tailed
    /// distribution used to synthesize outlier-rich weight blocks
    /// (transformer weights are empirically t-distributed with dof 3..6).
    pub fn next_student_t(&mut self, dof: f64) -> f64 {
        // t = Z / sqrt(ChiSq(k)/k); ChiSq(k) as sum of k squared normals
        // is slow for fractional dof, so use the Bailey polar method.
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let w = u * u + v * v;
            if w > 0.0 && w < 1.0 {
                let c = u / w.sqrt().max(f64::MIN_POSITIVE);
                let r = (dof * (w.powf(-2.0 / dof) - 1.0)).sqrt();
                return c * r;
            }
        }
    }

    /// Fill a slice with i.i.d. N(0, sigma^2) values.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = (self.next_gaussian() as f32) * sigma;
        }
    }

    /// Random sign in {-1.0, +1.0}.
    pub fn next_sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range() {
        let mut r = XorShift::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_no_bias_smoke() {
        let mut r = XorShift::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000 each; allow 6% deviation
            assert!((9_400..10_600).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShift::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn student_t_is_heavier_tailed_than_gaussian() {
        let mut r = XorShift::new(13);
        let n = 100_000;
        let mut kurt_num = 0.0f64;
        let mut var = 0.0f64;
        for _ in 0..n {
            let x = r.next_student_t(5.0);
            var += x * x;
            kurt_num += x * x * x * x;
        }
        var /= n as f64;
        let kurtosis = kurt_num / n as f64 / (var * var);
        // t(5) has excess kurtosis 6 (kurtosis 9); Gaussian has 3.
        assert!(kurtosis > 4.0, "kurtosis={kurtosis}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
