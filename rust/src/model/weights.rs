//! Model weights: dense (f32) and quantized representations.
//!
//! Quantization policy mirrors the paper / llama.cpp: the seven large
//! linears per layer (`wq wk wv wo w1 w2 w3`) are quantized; embeddings
//! (tied with the LM head) and RMSNorm gains stay in high precision.

use crate::quant::{
    matmul::{MatvecScratch, QuantizedLinear},
    pad_cols, Format,
};
use crate::tensor::Tensor;
use crate::util::{threadpool, XorShift};
use std::sync::Arc;

use super::ModelConfig;

/// One decoder layer, dense.
pub struct DenseLayer {
    pub attn_norm: Vec<f32>,
    /// All weight matrices are row-major `(out_dim, in_dim)`.
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ffn_norm: Vec<f32>,
    pub w1: Tensor, // gate: (ffn, dim)
    pub w3: Tensor, // up:   (ffn, dim)
    pub w2: Tensor, // down: (dim, ffn)
}

/// Dense f32 model (training-checkpoint precision).
pub struct DenseModel {
    pub cfg: ModelConfig,
    /// `(vocab, dim)`; tied LM head.
    pub embed: Tensor,
    pub layers: Vec<DenseLayer>,
    pub final_norm: Vec<f32>,
}

impl DenseModel {
    /// Random initialization (for tests and synthetic experiments).
    /// `tail_dof`: `None` for Gaussian init, `Some(dof)` for heavy-tailed
    /// weights that exhibit the paper's outlier phenomenon.
    pub fn random(cfg: &ModelConfig, seed: u64, tail_dof: Option<f64>) -> Self {
        let mut rng = XorShift::new(seed);
        let mut mat = |rows: usize, cols: usize| {
            let scale = 1.0 / (cols as f64).sqrt();
            let mut t = Tensor::zeros(vec![rows, cols]);
            for x in t.data_mut() {
                let v = match tail_dof {
                    Some(dof) => rng.next_student_t(dof) / (dof / (dof - 2.0)).sqrt(),
                    None => rng.next_gaussian(),
                };
                *x = (v * scale) as f32;
            }
            t
        };
        let layers = (0..cfg.n_layers)
            .map(|_| DenseLayer {
                attn_norm: vec![1.0; cfg.dim],
                wq: mat(cfg.dim, cfg.dim),
                wk: mat(cfg.dim, cfg.dim),
                wv: mat(cfg.dim, cfg.dim),
                wo: mat(cfg.dim, cfg.dim),
                ffn_norm: vec![1.0; cfg.dim],
                w1: mat(cfg.ffn, cfg.dim),
                w3: mat(cfg.ffn, cfg.dim),
                w2: mat(cfg.dim, cfg.ffn),
            })
            .collect();
        DenseModel {
            cfg: cfg.clone(),
            embed: mat(cfg.vocab, cfg.dim),
            layers,
            final_norm: vec![1.0; cfg.dim],
        }
    }

    /// All linear weights flattened (for distribution analysis).
    pub fn all_linear_weights(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.layers {
            for t in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w3, &l.w2] {
                out.extend_from_slice(t.data());
            }
        }
        out
    }
}

/// A quantized linear that transparently handles an input dimension that
/// is not a multiple of the format block (paper §8): columns are zero-
/// padded at quantization time and activations at apply time.
pub struct PaddedLinear {
    pub lin: QuantizedLinear,
    pub logical_in: usize,
}

impl PaddedLinear {
    pub fn new(fmt: Arc<dyn Format>, dense: &Tensor) -> Self {
        let logical_in = dense.cols();
        let padded = pad_cols(dense, fmt.block_elems());
        PaddedLinear { lin: QuantizedLinear::new(fmt, &padded), logical_in }
    }

    pub fn out_dim(&self) -> usize {
        self.lin.out_dim()
    }

    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.logical_in);
        if self.lin.in_dim() == self.logical_in {
            self.lin.matvec(x, y);
        } else {
            let mut xp = vec![0.0f32; self.lin.in_dim()];
            xp[..self.logical_in].copy_from_slice(x);
            self.lin.matvec(&xp, y);
        }
    }

    /// Whether this linear's format has a hand-specialized W3A8 kernel
    /// (the engine only routes decode through the integer path if so).
    pub fn has_q8_kernel(&self) -> bool {
        self.lin.w.fmt.has_q8_kernel()
    }

    fn shards(&self) -> usize {
        threadpool::suggested_shards(
            self.lin.out_dim(),
            self.lin.out_dim() * self.lin.in_dim(),
        )
    }

    /// W3A8 integer matvec (the serving decode path): pads through the
    /// caller's scratch, picks a row-shard count from the layer size, and
    /// runs the fused integer kernels. Allocation-free once `scratch` is
    /// warm.
    pub fn matvec_q8(&self, x: &[f32], y: &mut [f32], scratch: &mut MatvecScratch) {
        assert_eq!(x.len(), self.logical_in);
        let shards = self.shards();
        if self.lin.in_dim() == self.logical_in {
            self.lin.matvec_q8(x, y, scratch, shards);
        } else {
            let mut xp = std::mem::take(&mut scratch.x_pad);
            xp.clear();
            xp.resize(self.lin.in_dim(), 0.0);
            xp[..self.logical_in].copy_from_slice(x);
            self.lin.matvec_q8(&xp, y, scratch, shards);
            scratch.x_pad = xp;
        }
    }

    /// Row-sharded fused f32 matvec — the decode path for formats
    /// without a specialized integer kernel, and the `act_quant = false`
    /// comparison baseline. Bit-identical to [`Self::matvec`].
    pub fn matvec_par(&self, x: &[f32], y: &mut [f32], scratch: &mut MatvecScratch) {
        assert_eq!(x.len(), self.logical_in);
        let shards = self.shards();
        if self.lin.in_dim() == self.logical_in {
            self.lin.matvec_par(x, y, shards);
        } else {
            let mut xp = std::mem::take(&mut scratch.x_pad);
            xp.clear();
            xp.resize(self.lin.in_dim(), 0.0);
            xp[..self.logical_in].copy_from_slice(x);
            self.lin.matvec_par(&xp, y, shards);
            scratch.x_pad = xp;
        }
    }

    /// Fused batched W3A8 GEMM over `batch` activation rows (the
    /// multi-sequence decode path): `x` is `(batch, logical_in)`
    /// row-major, `y` is `(batch, out)` row-major. Rows are zero-padded
    /// exactly as [`Self::matvec_q8`] pads a single vector, so every
    /// output row is bit-identical to the sequential matvec on that row.
    /// Allocation-free once `scratch` is warm.
    pub fn matmul_q8(&self, x: &[f32], batch: usize, y: &mut [f32], scratch: &mut MatvecScratch) {
        assert_eq!(x.len(), batch * self.logical_in);
        let shards = threadpool::suggested_shards(
            self.lin.out_dim(),
            self.lin.out_dim() * self.lin.in_dim() * batch,
        );
        if self.lin.in_dim() == self.logical_in {
            self.lin.gemm_q8(x, batch, y, scratch, shards);
        } else {
            let mut xp = std::mem::take(&mut scratch.x_pad);
            xp.clear();
            xp.resize(batch * self.lin.in_dim(), 0.0);
            for (src, dst) in x
                .chunks_exact(self.logical_in)
                .zip(xp.chunks_exact_mut(self.lin.in_dim()))
            {
                dst[..self.logical_in].copy_from_slice(src);
            }
            self.lin.gemm_q8(&xp, batch, y, scratch, shards);
            scratch.x_pad = xp;
        }
    }

    /// Batched apply: `X (batch, logical_in)` -> `(batch, out)`.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols(), self.logical_in);
        if self.lin.in_dim() == self.logical_in {
            self.lin.matmul(x)
        } else {
            let mut xp = Tensor::zeros(vec![x.rows(), self.lin.in_dim()]);
            for r in 0..x.rows() {
                xp.row_mut(r)[..self.logical_in].copy_from_slice(x.row(r));
            }
            self.lin.matmul(&xp)
        }
    }

    pub fn nbytes(&self) -> usize {
        self.lin.w.nbytes()
    }
}

/// One decoder layer, quantized.
pub struct QuantLayer {
    pub attn_norm: Vec<f32>,
    pub wq: PaddedLinear,
    pub wk: PaddedLinear,
    pub wv: PaddedLinear,
    pub wo: PaddedLinear,
    pub ffn_norm: Vec<f32>,
    pub w1: PaddedLinear,
    pub w3: PaddedLinear,
    pub w2: PaddedLinear,
}

/// Quantized model: linears packed in a [`Format`], embeddings dense.
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    pub fmt_name: String,
    pub embed: Tensor,
    pub layers: Vec<QuantLayer>,
    pub final_norm: Vec<f32>,
}

impl QuantizedModel {
    pub fn quantize(dense: &DenseModel, fmt: Arc<dyn Format>) -> Self {
        let layers = dense
            .layers
            .iter()
            .map(|l| QuantLayer {
                attn_norm: l.attn_norm.clone(),
                wq: PaddedLinear::new(fmt.clone(), &l.wq),
                wk: PaddedLinear::new(fmt.clone(), &l.wk),
                wv: PaddedLinear::new(fmt.clone(), &l.wv),
                wo: PaddedLinear::new(fmt.clone(), &l.wo),
                ffn_norm: l.ffn_norm.clone(),
                w1: PaddedLinear::new(fmt.clone(), &l.w1),
                w3: PaddedLinear::new(fmt.clone(), &l.w3),
                w2: PaddedLinear::new(fmt.clone(), &l.w2),
            })
            .collect();
        QuantizedModel {
            cfg: dense.cfg.clone(),
            fmt_name: fmt.name().to_string(),
            embed: dense.embed.clone(),
            layers,
            final_norm: dense.final_norm.clone(),
        }
    }

    /// Static weight audit: every packed linear through
    /// [`crate::quant::audit::audit_matrix`], in GGUF tensor-name order
    /// (`layers.{i}.{wq,wk,wv,wo,w1,w3,w2}` — norms and embeddings stay
    /// dense and have nothing to audit).
    pub fn audit(&self) -> crate::quant::audit::AuditReport {
        let mut tensors = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            for (suffix, pl) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wo", &l.wo),
                ("w1", &l.w1),
                ("w3", &l.w3),
                ("w2", &l.w2),
            ] {
                tensors.push(crate::quant::audit::audit_matrix(
                    &format!("layers.{i}.{suffix}"),
                    &pl.lin.w,
                ));
            }
        }
        crate::quant::audit::AuditReport { fmt: self.fmt_name.clone(), tensors }
    }

    /// Packed bytes of all quantized linears (the Table 1 "Mem" column,
    /// measured rather than modeled).
    pub fn linear_nbytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.nbytes()
                    + l.wk.nbytes()
                    + l.wv.nbytes()
                    + l.wo.nbytes()
                    + l.w1.nbytes()
                    + l.w3.nbytes()
                    + l.w2.nbytes()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format_by_name;

    #[test]
    fn random_model_shapes() {
        let cfg = ModelConfig::test();
        let m = DenseModel::random(&cfg, 1, None);
        assert_eq!(m.embed.shape(), &[cfg.vocab, cfg.dim]);
        assert_eq!(m.layers.len(), cfg.n_layers);
        assert_eq!(m.layers[0].w1.shape(), &[cfg.ffn, cfg.dim]);
        assert_eq!(m.layers[0].w2.shape(), &[cfg.dim, cfg.ffn]);
    }

    #[test]
    fn heavy_tail_init_has_outliers() {
        let cfg = ModelConfig::test();
        let g = DenseModel::random(&cfg, 2, None).all_linear_weights();
        let h = DenseModel::random(&cfg, 2, Some(4.0)).all_linear_weights();
        let kg = crate::util::stats::kurtosis(&g);
        let kh = crate::util::stats::kurtosis(&h);
        assert!(kg < 3.5, "gaussian kurtosis {kg}");
        assert!(kh > 4.0, "heavy kurtosis {kh}");
    }

    #[test]
    fn quantize_model_size_matches_bpw() {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 3, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let qm = QuantizedModel::quantize(&dense, fmt.clone());
        let params = cfg.n_layers as u64 * cfg.linear_params_per_layer();
        let expect = params as f64 * fmt.bits_per_weight() / 8.0;
        let got = qm.linear_nbytes() as f64;
        assert!((got - expect).abs() / expect < 1e-9, "{got} vs {expect}");
    }

    #[test]
    fn padded_linear_handles_odd_cols() {
        let mut rng = XorShift::new(4);
        let w = Tensor::randn(vec![8, 300], 0.05, &mut rng); // 300 % 256 != 0
        let pl = PaddedLinear::new(format_by_name("itq3_s").unwrap(), &w);
        assert_eq!(pl.logical_in, 300);
        assert_eq!(pl.lin.in_dim(), 512);
        let x: Vec<f32> = (0..300).map(|_| rng.next_f32() - 0.5).collect();
        let mut y = vec![0.0f32; 8];
        pl.matvec(&x, &mut y);
        // vs dense reference
        let mut y_ref = vec![0.0f32; 8];
        crate::tensor::matvec_accum(&w, &x, &mut y_ref);
        let rel = crate::util::stats::rel_l2_err(&y_ref, &y);
        assert!(rel < 0.9, "rel={rel}");
        // batched agrees with matvec
        let xt = Tensor::new(vec![1, 300], x.clone());
        let ym = pl.matmul(&xt);
        for (a, b) in ym.row(0).iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
        // W3A8 path handles the same padding and tracks the f32 path.
        let mut yq = vec![0.0f32; 8];
        let mut scratch = MatvecScratch::new();
        pl.matvec_q8(&x, &mut yq, &mut scratch);
        let relq = crate::util::stats::rel_l2_err(&y, &yq);
        assert!(relq < 0.05, "padded q8 rel={relq}");
        // Scratch is reusable across differently-shaped linears.
        let w2 = Tensor::randn(vec![4, 260], 0.05, &mut rng);
        let pl2 = PaddedLinear::new(format_by_name("q8_0").unwrap(), &w2);
        let x2: Vec<f32> = (0..260).map(|_| rng.next_f32() - 0.5).collect();
        let mut y2 = vec![0.0f32; 4];
        let mut y2q = vec![0.0f32; 4];
        pl2.matvec(&x2, &mut y2);
        pl2.matvec_q8(&x2, &mut y2q, &mut scratch);
        assert!(crate::util::stats::rel_l2_err(&y2, &y2q) < 0.03);
    }

    #[test]
    fn padded_matmul_q8_matches_matvec_q8_bitwise() {
        // The batched GEMM must pad each activation row exactly as the
        // sequential path pads one vector — every output row identical,
        // bit for bit, including the padded-columns case.
        let mut rng = XorShift::new(14);
        for cols in [300usize, 512] {
            let w = Tensor::randn(vec![9, cols], 0.05, &mut rng);
            let pl = PaddedLinear::new(format_by_name("itq3_s").unwrap(), &w);
            let mut scratch = MatvecScratch::new();
            for batch in [1usize, 2, 5, 8] {
                let x: Vec<f32> =
                    (0..batch * cols).map(|_| rng.next_f32() - 0.5).collect();
                let mut y = vec![0.0f32; batch * 9];
                // NaN-poison the staging buffers (including the padding
                // region x_pad re-stages) before every call: a kernel
                // lane reading past the logical row end would drag NaN
                // into the output and fail the bitwise compare below.
                scratch.poison();
                pl.matmul_q8(&x, batch, &mut y, &mut scratch);
                for t in 0..batch {
                    let mut yt = vec![0.0f32; 9];
                    scratch.poison();
                    pl.matvec_q8(&x[t * cols..(t + 1) * cols], &mut yt, &mut scratch);
                    assert!(yt.iter().all(|v| v.is_finite()), "poison leaked");
                    assert_eq!(
                        &y[t * 9..(t + 1) * 9],
                        &yt[..],
                        "cols={cols} batch={batch} row {t}"
                    );
                }
            }
        }
    }
}
