//! Per-sequence KV cache.
//!
//! The coordinator allocates one of these per active sequence (block-
//! granular accounting lives in `coordinator::kvpool`; this is the dense
//! storage the native engine reads/writes). It also retains the raw token
//! history so the PJRT recompute engine can score growing sequences.

use super::ModelConfig;

/// Storage abstraction the engines read/write KV state through.
///
/// Two implementations exist: the dense per-sequence [`KvCache`] below
/// (contiguous `f32`, worst-case capacity up front) and the paged,
/// refcounted, prefix-shared store in [`crate::kvpaged`]. The engine is
/// written against this trait so the two can be swapped per sequence and
/// cross-checked bit-for-bit (`rust/tests/kv_paged.rs`).
///
/// Read methods take `&mut self` so a quantized (Q8-block) store can
/// dequantize into an internal scratch buffer and hand out a borrow; the
/// dense store ignores the mutability and returns its slice directly.
pub trait KvStore {
    /// Tokens currently stored; also the next write position.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Maximum tokens this store can hold for the sequence.
    fn capacity(&self) -> usize;
    /// Raw token history (the PJRT recompute engine re-scores from it).
    fn tokens(&self) -> &[u32];
    /// Record `t` as consumed (`len()` grows by one).
    fn push_token(&mut self, t: u32);
    /// Key vector written at (`layer`, `pos`).
    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32];
    /// Value vector written at (`layer`, `pos`).
    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32];
    /// Store the K/V vectors for (`layer`, `pos`).
    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);
    /// Roll the sequence back to its first `len` tokens, discarding the
    /// tail tokens and any KV written for them (speculative-decode
    /// rollback). `len` must not exceed the current length. After the
    /// call, positions `len..` are free to be rewritten; a store may
    /// leave stale payload there (the engine always writes a position
    /// before reading it).
    fn truncate(&mut self, len: usize);
}

/// Batched KV access for the fused multi-sequence decode pass.
///
/// The engine steps every sequence of a decode round through each layer
/// at once, but KV traffic stays per-sequence: the batched pass reads
/// and writes one sequence's state at a time through this trait. Every
/// method takes the sequence's batch index `i` (`0..n_seqs()`) and has
/// [`KvStore`] semantics per index.
///
/// Why not `&mut [&mut dyn KvStore]`? The paged pool
/// ([`crate::kvpaged::PagedKvPool`]) owns all sequences behind one
/// `&mut` and cannot hand out several live views at once; routing each
/// call through a batch adapter ([`crate::kvpaged::PagedBatch`]) keeps
/// the borrow single. Independent stores (dense caches in tests and
/// benches) batch through [`StoreBatch`], and [`BatchSlot`] adapts one
/// slot back into a plain [`KvStore`] so per-sequence code (including
/// the default sequential `decode_batch`) runs unchanged.
pub trait KvBatchStore {
    /// Number of sequences in the batch.
    fn n_seqs(&self) -> usize;
    /// Tokens stored for sequence `i` (its next write position).
    fn seq_len(&self, i: usize) -> usize;
    /// Maximum tokens sequence `i` can hold.
    fn capacity(&self, i: usize) -> usize;
    /// Raw token history of sequence `i`.
    fn tokens(&self, i: usize) -> &[u32];
    /// Record `t` as consumed by sequence `i`.
    fn push_token(&mut self, i: usize, t: u32);
    /// Key vector of sequence `i` at (`layer`, `pos`).
    fn k_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32];
    /// Value vector of sequence `i` at (`layer`, `pos`).
    fn v_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32];
    /// Store sequence `i`'s K/V vectors for (`layer`, `pos`).
    fn write_kv(&mut self, i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]);
    /// Roll sequence `i` back to its first `len` tokens (the
    /// [`KvStore::truncate`] analog).
    fn truncate(&mut self, i: usize, len: usize);
}

/// A decode batch over independent per-sequence stores.
pub struct StoreBatch<'a> {
    pub stores: Vec<&'a mut dyn KvStore>,
}

impl KvBatchStore for StoreBatch<'_> {
    fn n_seqs(&self) -> usize {
        self.stores.len()
    }

    fn seq_len(&self, i: usize) -> usize {
        self.stores[i].len()
    }

    fn capacity(&self, i: usize) -> usize {
        self.stores[i].capacity()
    }

    fn tokens(&self, i: usize) -> &[u32] {
        self.stores[i].tokens()
    }

    fn push_token(&mut self, i: usize, t: u32) {
        self.stores[i].push_token(t)
    }

    fn k_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.stores[i].k_at(layer, pos)
    }

    fn v_at(&mut self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.stores[i].v_at(layer, pos)
    }

    fn write_kv(&mut self, i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.stores[i].write_kv(layer, pos, k, v)
    }

    fn truncate(&mut self, i: usize, len: usize) {
        self.stores[i].truncate(len)
    }
}

/// One slot of a [`KvBatchStore`] viewed as a plain [`KvStore`].
pub struct BatchSlot<'a> {
    pub batch: &'a mut dyn KvBatchStore,
    pub i: usize,
}

impl KvStore for BatchSlot<'_> {
    fn len(&self) -> usize {
        self.batch.seq_len(self.i)
    }

    fn capacity(&self) -> usize {
        self.batch.capacity(self.i)
    }

    fn tokens(&self) -> &[u32] {
        self.batch.tokens(self.i)
    }

    fn push_token(&mut self, t: u32) {
        self.batch.push_token(self.i, t)
    }

    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.batch.k_at(self.i, layer, pos)
    }

    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.batch.v_at(self.i, layer, pos)
    }

    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.batch.write_kv(self.i, layer, pos, k, v)
    }

    fn truncate(&mut self, len: usize) {
        self.batch.truncate(self.i, len)
    }
}

/// `n` consecutive positions of **one** sequence presented as a decode
/// batch: slot `i` stands for position `base + i`, where `base` is the
/// store's length at construction.
///
/// This is how the speculative verify pass (greedy and sampled alike —
/// the acceptance rule lives above the engine, in
/// [`crate::spec::spec_step_sampled`]) reuses the fused batched
/// decode unchanged: [`NativeEngine::score_tokens`] hands
/// `decode_batch` a `SpecSlots` view over `[pending, draft...]`, and
/// the batched pass's write-KV-then-attend-per-layer order makes slot
/// `i`'s attention read exactly the KV state a sequential
/// `decode_step` at position `base + i` would see — slots `< i` have
/// written their rows for the layer before any slot attends, and slot
/// `i` only reads positions `0..=base + i`. The fused pass pushes
/// tokens only after all layers complete, so the fixed per-slot
/// `seq_len` stays valid for the whole call.
///
/// [`NativeEngine::score_tokens`]: crate::model::native::Engine::score_tokens
pub struct SpecSlots<'a> {
    store: &'a mut dyn KvStore,
    base: usize,
    n: usize,
}

impl<'a> SpecSlots<'a> {
    pub fn new(store: &'a mut dyn KvStore, n: usize) -> Self {
        let base = store.len();
        SpecSlots { store, base, n }
    }
}

impl KvBatchStore for SpecSlots<'_> {
    fn n_seqs(&self) -> usize {
        self.n
    }

    fn seq_len(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        self.base + i
    }

    fn capacity(&self, _i: usize) -> usize {
        self.store.capacity()
    }

    fn tokens(&self, _i: usize) -> &[u32] {
        self.store.tokens()
    }

    fn push_token(&mut self, _i: usize, t: u32) {
        self.store.push_token(t)
    }

    fn k_at(&mut self, _i: usize, layer: usize, pos: usize) -> &[f32] {
        self.store.k_at(layer, pos)
    }

    fn v_at(&mut self, _i: usize, layer: usize, pos: usize) -> &[f32] {
        self.store.v_at(layer, pos)
    }

    fn write_kv(&mut self, _i: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.store.write_kv(layer, pos, k, v)
    }

    fn truncate(&mut self, _i: usize, len: usize) {
        self.store.truncate(len)
    }
}

/// Dense KV storage for a single sequence: `k[layer][pos][dim]`.
pub struct KvCache {
    pub cfg_layers: usize,
    pub dim: usize,
    pub max_seq: usize,
    /// Token history (BOS included); `len()` is the current position.
    pub tokens: Vec<u32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            cfg_layers: cfg.n_layers,
            dim: cfg.dim,
            max_seq: cfg.max_seq,
            tokens: Vec::new(),
            k: vec![0.0; cfg.n_layers * cfg.max_seq * cfg.dim],
            v: vec![0.0; cfg.n_layers * cfg.max_seq * cfg.dim],
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.tokens.len() >= self.max_seq
    }

    #[inline]
    fn off(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.dim
    }

    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.k[o..o + self.dim]
    }

    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.v[o..o + self.dim]
    }

    pub fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "kv overflow at pos {pos}");
        let o = self.off(layer, pos);
        self.k[o..o + self.dim].copy_from_slice(k);
        self.v[o..o + self.dim].copy_from_slice(v);
    }

    /// Bytes of live KV state (both planes, f32 here; fp16 on the paper's
    /// target — the coordinator's accounting uses this for admission).
    pub fn live_bytes(&self) -> usize {
        2 * self.cfg_layers * self.len() * self.dim * 4
    }

    /// Drop all state (sequence finished); capacity is retained for reuse.
    pub fn reset(&mut self) {
        self.tokens.clear();
    }
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        KvCache::len(self)
    }

    fn capacity(&self) -> usize {
        self.max_seq
    }

    fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    fn push_token(&mut self, t: u32) {
        self.tokens.push(t);
    }

    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        KvCache::k_at(self, layer, pos)
    }

    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        KvCache::v_at(self, layer, pos)
    }

    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        KvCache::write_kv(self, layer, pos, k, v)
    }

    fn truncate(&mut self, len: usize) {
        assert!(len <= self.tokens.len(), "truncate({len}) beyond length");
        // KV rows past `len` are left in place: reads never go past the
        // token count, and every position is rewritten before the first
        // read that could see it.
        self.tokens.truncate(len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let k: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..cfg.dim).map(|i| -(i as f32)).collect();
        c.write_kv(1, 3, &k, &v);
        assert_eq!(c.k_at(1, 3), &k[..]);
        assert_eq!(c.v_at(1, 3), &v[..]);
        // Other slots untouched.
        assert!(c.k_at(0, 3).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accounting() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        assert!(c.is_empty());
        c.tokens.push(0);
        c.tokens.push(65);
        assert_eq!(c.len(), 2);
        assert_eq!(c.live_bytes(), 2 * cfg.n_layers * 2 * cfg.dim * 4);
        c.reset();
        assert!(c.is_empty());
    }

    #[test]
    fn store_batch_and_slot_delegate_per_index() {
        let cfg = ModelConfig::test();
        let mut a = KvCache::new(&cfg);
        let mut b = KvCache::new(&cfg);
        let k: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..cfg.dim).map(|i| 2.0 * i as f32).collect();
        let mut batch = StoreBatch { stores: vec![&mut a, &mut b] };
        assert_eq!(batch.n_seqs(), 2);
        batch.write_kv(1, 0, 0, &k, &v);
        batch.push_token(1, 42);
        assert_eq!(batch.seq_len(0), 0, "slot 0 untouched");
        assert_eq!(batch.seq_len(1), 1);
        assert_eq!(batch.k_at(1, 0, 0), &k[..]);
        // A slot view behaves exactly like the underlying store.
        let mut slot = BatchSlot { batch: &mut batch, i: 1 };
        assert_eq!(slot.len(), 1);
        assert_eq!(slot.tokens(), &[42]);
        slot.write_kv(1, 1, &v, &k);
        slot.push_token(7);
        assert_eq!(slot.v_at(1, 1), &k[..]);
        drop(slot);
        drop(batch);
        assert_eq!(b.tokens, vec![42, 7]);
        assert_eq!(b.k_at(0, 0), &k[..]);
        assert!(a.is_empty());
    }

    #[test]
    fn truncate_drops_tail_tokens() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let row = vec![1.0f32; cfg.dim];
        for pos in 0..5 {
            c.write_kv(0, pos, &row, &row);
            KvStore::push_token(&mut c, pos as u32);
        }
        KvStore::truncate(&mut c, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(KvStore::tokens(&c), &[0, 1, 2]);
        // Truncate to the current length is a no-op; to zero empties.
        KvStore::truncate(&mut c, 3);
        assert_eq!(c.len(), 3);
        KvStore::truncate(&mut c, 0);
        assert!(c.is_empty());
    }

    #[test]
    fn spec_slots_present_consecutive_positions_of_one_store() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let k: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        // Two tokens already consumed.
        for pos in 0..2 {
            c.write_kv(0, pos, &k, &k);
            KvStore::push_token(&mut c, 100 + pos as u32);
        }
        let mut slots = SpecSlots::new(&mut c, 3);
        assert_eq!(slots.n_seqs(), 3);
        // Slot i is position base + i, with a fixed base.
        assert_eq!(slots.seq_len(0), 2);
        assert_eq!(slots.seq_len(2), 4);
        slots.write_kv(1, 1, 3, &k, &k);
        assert_eq!(slots.k_at(1, 1, 3), &k[..]);
        // Pushes land on the single underlying store without moving the
        // per-slot positions (decode_batch pushes only at the end).
        slots.push_token(0, 7);
        slots.push_token(1, 8);
        assert_eq!(slots.seq_len(0), 2);
        drop(slots);
        assert_eq!(c.tokens, vec![100, 101, 7, 8]);
        assert_eq!(KvCache::k_at(&c, 1, 3), &k[..]);
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let z = vec![0.0; cfg.dim];
        c.write_kv(0, cfg.max_seq, &z, &z);
    }
}
