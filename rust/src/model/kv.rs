//! Per-sequence KV cache.
//!
//! The coordinator allocates one of these per active sequence (block-
//! granular accounting lives in `coordinator::kvpool`; this is the dense
//! storage the native engine reads/writes). It also retains the raw token
//! history so the PJRT recompute engine can score growing sequences.

use super::ModelConfig;

/// Storage abstraction the engines read/write KV state through.
///
/// Two implementations exist: the dense per-sequence [`KvCache`] below
/// (contiguous `f32`, worst-case capacity up front) and the paged,
/// refcounted, prefix-shared store in [`crate::kvpaged`]. The engine is
/// written against this trait so the two can be swapped per sequence and
/// cross-checked bit-for-bit (`rust/tests/kv_paged.rs`).
///
/// Read methods take `&mut self` so a quantized (Q8-block) store can
/// dequantize into an internal scratch buffer and hand out a borrow; the
/// dense store ignores the mutability and returns its slice directly.
pub trait KvStore {
    /// Tokens currently stored; also the next write position.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Maximum tokens this store can hold for the sequence.
    fn capacity(&self) -> usize;
    /// Raw token history (the PJRT recompute engine re-scores from it).
    fn tokens(&self) -> &[u32];
    /// Record `t` as consumed (`len()` grows by one).
    fn push_token(&mut self, t: u32);
    /// Key vector written at (`layer`, `pos`).
    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32];
    /// Value vector written at (`layer`, `pos`).
    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32];
    /// Store the K/V vectors for (`layer`, `pos`).
    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);
}

/// Dense KV storage for a single sequence: `k[layer][pos][dim]`.
pub struct KvCache {
    pub cfg_layers: usize,
    pub dim: usize,
    pub max_seq: usize,
    /// Token history (BOS included); `len()` is the current position.
    pub tokens: Vec<u32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            cfg_layers: cfg.n_layers,
            dim: cfg.dim,
            max_seq: cfg.max_seq,
            tokens: Vec::new(),
            k: vec![0.0; cfg.n_layers * cfg.max_seq * cfg.dim],
            v: vec![0.0; cfg.n_layers * cfg.max_seq * cfg.dim],
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.tokens.len() >= self.max_seq
    }

    #[inline]
    fn off(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.dim
    }

    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.k[o..o + self.dim]
    }

    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.v[o..o + self.dim]
    }

    pub fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "kv overflow at pos {pos}");
        let o = self.off(layer, pos);
        self.k[o..o + self.dim].copy_from_slice(k);
        self.v[o..o + self.dim].copy_from_slice(v);
    }

    /// Bytes of live KV state (both planes, f32 here; fp16 on the paper's
    /// target — the coordinator's accounting uses this for admission).
    pub fn live_bytes(&self) -> usize {
        2 * self.cfg_layers * self.len() * self.dim * 4
    }

    /// Drop all state (sequence finished); capacity is retained for reuse.
    pub fn reset(&mut self) {
        self.tokens.clear();
    }
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        KvCache::len(self)
    }

    fn capacity(&self) -> usize {
        self.max_seq
    }

    fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    fn push_token(&mut self, t: u32) {
        self.tokens.push(t);
    }

    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        KvCache::k_at(self, layer, pos)
    }

    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        KvCache::v_at(self, layer, pos)
    }

    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        KvCache::write_kv(self, layer, pos, k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let k: Vec<f32> = (0..cfg.dim).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..cfg.dim).map(|i| -(i as f32)).collect();
        c.write_kv(1, 3, &k, &v);
        assert_eq!(c.k_at(1, 3), &k[..]);
        assert_eq!(c.v_at(1, 3), &v[..]);
        // Other slots untouched.
        assert!(c.k_at(0, 3).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accounting() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        assert!(c.is_empty());
        c.tokens.push(0);
        c.tokens.push(65);
        assert_eq!(c.len(), 2);
        assert_eq!(c.live_bytes(), 2 * cfg.n_layers * 2 * cfg.dim * 4);
        c.reset();
        assert!(c.is_empty());
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let cfg = ModelConfig::test();
        let mut c = KvCache::new(&cfg);
        let z = vec![0.0; cfg.dim];
        c.write_kv(0, cfg.max_seq, &z, &z);
    }
}
