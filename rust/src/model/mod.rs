//! LLaMA-style transformer: configuration, weights (dense and
//! quantized), and the native CPU inference engine.
//!
//! The paper evaluates on LLaMA-3 8B/70B; those checkpoints (and the
//! RTX 5090) are unavailable here, so the reproduction trains a tiny
//! same-architecture model (RMSNorm + RoPE + causal MHA + SwiGLU, tied
//! embeddings) at build time (`python/compile/train.py`) and serves it
//! through this module (native engine) or through the AOT-lowered JAX
//! graph (`runtime::PjrtEngine`). Both engines implement the same math;
//! `rust/tests/` cross-checks them numerically.

pub mod config;
pub mod kv;
pub mod memory;
pub mod native;
pub mod tokenizer;
pub mod weights;

pub use config::ModelConfig;
pub use kv::{BatchSlot, KvBatchStore, KvCache, KvStore, SpecSlots, StoreBatch};
pub use native::NativeEngine;
pub use weights::{DenseModel, QuantizedModel};
