//! Byte-level tokenizer (vocab = 256).
//!
//! The tiny build-time model is a byte LM: token ids are raw UTF-8 bytes.
//! Byte 0x00 doubles as BOS/pad — the corpus generator never emits it.

pub const VOCAB: usize = 256;
pub const BOS: u32 = 0;

/// Encode text to token ids, prepending BOS.
pub fn encode(text: &str) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as u32));
    out
}

/// Encode without BOS (for continuation chunks).
pub fn encode_raw(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

/// Decode token ids to text (lossy on invalid UTF-8, skips BOS/pad).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t != BOS && t < 256)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "the quick brown fox";
        let toks = encode(text);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), text.len() + 1);
        assert_eq!(decode(&toks), text);
    }

    #[test]
    fn raw_has_no_bos() {
        assert_eq!(encode_raw("ab"), vec![97, 98]);
    }

    #[test]
    fn tokens_in_vocab() {
        for t in encode("hello, world! 123") {
            assert!((t as usize) < VOCAB);
        }
    }
}
