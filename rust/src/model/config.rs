//! Transformer architecture configuration.

use crate::util::json::Json;

/// LLaMA-style decoder configuration. The default is the tiny build-time
/// model; `llama3_8b()`/`llama3_70b()` give the paper's target shapes for
//  the analytic memory model (§7.3).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Vocabulary size (byte-level tokenizer: 256).
    pub vocab: usize,
    /// Residual width.
    pub dim: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Attention heads (no GQA in the tiny model).
    pub n_heads: usize,
    /// KV heads (GQA); equals `n_heads` when GQA is off. The tiny model
    /// always uses full MHA — this field only drives the analytic memory
    /// model for the paper's LLaMA-3 shapes (§7.3).
    pub n_kv_heads: usize,
    /// SwiGLU hidden width.
    pub ffn: usize,
    /// Maximum sequence length (RoPE table size, KV capacity).
    pub max_seq: usize,
    /// RoPE base.
    pub rope_theta: f32,
    /// RMSNorm epsilon.
    pub eps: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig::tiny()
    }
}

impl ModelConfig {
    /// The build-time trained model: ~6.6M parameters, dims chosen as
    /// multiples of 256 so every linear quantizes without padding.
    pub fn tiny() -> Self {
        ModelConfig {
            vocab: 256,
            dim: 256,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 8,
            ffn: 1024,
            max_seq: 256,
            rope_theta: 10_000.0,
            eps: 1e-5,
        }
    }

    /// A smaller unit-test model (fast to randomly initialize and run).
    pub fn test() -> Self {
        ModelConfig {
            vocab: 256,
            dim: 256,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 4,
            ffn: 512,
            max_seq: 64,
            rope_theta: 10_000.0,
            eps: 1e-5,
        }
    }

    /// LLaMA-3 8B shape (for the memory model only).
    pub fn llama3_8b() -> Self {
        ModelConfig {
            vocab: 128_256,
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            ffn: 14_336,
            max_seq: 8192,
            rope_theta: 500_000.0,
            eps: 1e-5,
        }
    }

    /// LLaMA-3 70B shape (for the §7.3 fit analysis).
    pub fn llama3_70b() -> Self {
        ModelConfig {
            vocab: 128_256,
            dim: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 8,
            ffn: 28_672,
            max_seq: 8192,
            rope_theta: 500_000.0,
            eps: 1e-5,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// KV projection width (`dim` scaled by the GQA ratio).
    pub fn kv_dim(&self) -> usize {
        self.dim * self.n_kv_heads / self.n_heads
    }

    /// Parameters in the seven quantizable linears per layer.
    pub fn linear_params_per_layer(&self) -> u64 {
        // wq, wo: dim x dim; wk, wv: kv_dim x dim (GQA);
        // w1, w3: ffn x dim; w2: dim x ffn.
        (2 * self.dim * self.dim
            + 2 * self.kv_dim() * self.dim
            + 3 * self.dim * self.ffn) as u64
    }

    /// Total parameter count (tied embedding counted once).
    pub fn param_count(&self) -> u64 {
        let embed = (self.vocab * self.dim) as u64;
        let norms = ((2 * self.n_layers + 1) * self.dim) as u64;
        embed + norms + self.n_layers as u64 * self.linear_params_per_layer()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("ffn", Json::num(self.ffn as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("rope_theta", Json::num(self.rope_theta as f64)),
            ("eps", Json::num(self.eps as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(ModelConfig {
            vocab: j.get("vocab")?.as_u64()? as usize,
            dim: j.get("dim")?.as_u64()? as usize,
            n_layers: j.get("n_layers")?.as_u64()? as usize,
            n_heads: j.get("n_heads")?.as_u64()? as usize,
            n_kv_heads: j
                .get("n_kv_heads")
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .unwrap_or(j.get("n_heads")?.as_u64()? as usize),
            ffn: j.get("ffn")?.as_u64()? as usize,
            max_seq: j.get("max_seq")?.as_u64()? as usize,
            rope_theta: j.get("rope_theta")?.as_f64()? as f32,
            eps: j.get("eps")?.as_f64()? as f32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_param_count() {
        let c = ModelConfig::tiny();
        // 4 layers x (4*256^2 + 3*256*1024) + 256*256 + 9*256
        let expect = 4 * (4 * 256 * 256 + 3 * 256 * 1024) + 256 * 256 + 9 * 256;
        assert_eq!(c.param_count(), expect as u64);
        assert!(c.param_count() > 4_000_000);
    }

    #[test]
    fn llama_70b_param_count_about_70b() {
        let p = ModelConfig::llama3_70b().param_count() as f64;
        assert!((6.5e10..7.3e10).contains(&p), "p={p}");
        // GQA matters: kv projections are 1/8 width.
        assert_eq!(ModelConfig::llama3_70b().kv_dim(), 1024);
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::tiny();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn head_dim_divides() {
        let c = ModelConfig::tiny();
        assert_eq!(c.head_dim() * c.n_heads, c.dim);
    }
}
