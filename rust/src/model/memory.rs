//! Analytic memory model — reproduces the paper's §7.3 claim that
//! LLaMA-3 70B at 3.125 b/w fits a 32 GiB GPU with KV-cache headroom for
//! a ~16K context, and the "Mem (GiB)" column of Table 1.

use super::ModelConfig;

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Weight bytes at a given bits/weight rate (linears at `bpw`, embeddings
/// and norms at fp16 — the convention llama.cpp and the paper share).
pub fn weight_bytes(cfg: &ModelConfig, bpw: f64) -> f64 {
    let linear = cfg.n_layers as f64 * cfg.linear_params_per_layer() as f64;
    let other = (cfg.param_count() - cfg.n_layers as u64 * cfg.linear_params_per_layer()) as f64;
    linear * bpw / 8.0 + other * 2.0
}

/// KV-cache bytes for `tokens` context at fp16 (GQA-aware).
pub fn kv_bytes(cfg: &ModelConfig, tokens: usize) -> f64 {
    // 2 (K and V) x layers x tokens x kv_dim x 2 bytes.
    2.0 * cfg.n_layers as f64 * tokens as f64 * cfg.kv_dim() as f64 * 2.0
}

/// Max context length that fits alongside the weights in `budget` bytes.
pub fn max_context(cfg: &ModelConfig, bpw: f64, budget: f64) -> usize {
    let spare = budget - weight_bytes(cfg, bpw);
    if spare <= 0.0 {
        return 0;
    }
    (spare / (2.0 * cfg.n_layers as f64 * cfg.kv_dim() as f64 * 2.0)) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_7_3_70b_fits_32gib() {
        let cfg = ModelConfig::llama3_70b();
        let w = weight_bytes(&cfg, 3.125) / GIB;
        // Paper: "~27.3 GiB". Our count lands within ~1.5 GiB (the paper
        // does not state its embedding precision).
        assert!((26.0..29.0).contains(&w), "w={w}");
        assert!(w < 32.0);
        // KV headroom: paper claims ~16K context in the remaining space.
        let ctx = max_context(&cfg, 3.125, 32.0 * GIB);
        assert!((10_000..24_000).contains(&ctx), "ctx={ctx} (paper: ~16K)");
    }

    #[test]
    fn paper_table1_8b_memory_column() {
        let cfg = ModelConfig::llama3_8b();
        // Table 1: FP16 15.0 GiB, ITQ3_S 3.1 GiB, Q4_K_M 4.8 GiB.
        let fp16 = weight_bytes(&cfg, 16.0) / GIB;
        let itq3 = weight_bytes(&cfg, 3.125) / GIB;
        let q4 = weight_bytes(&cfg, 4.5) / GIB;
        // (the paper's 15.0 includes LLaMA-3's untied LM head, which the
        // tied-embedding accounting here omits)
        assert!((12.5..16.5).contains(&fp16), "fp16={fp16}");
        assert!((2.6..4.2).contains(&itq3), "itq3={itq3}");
        assert!((4.0..5.5).contains(&q4), "q4={q4}");
    }

    #[test]
    fn fp16_cannot_load_70b() {
        let cfg = ModelConfig::llama3_70b();
        assert_eq!(max_context(&cfg, 16.0, 32.0 * GIB), 0);
    }
}
