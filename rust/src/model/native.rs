//! Native CPU inference engine.
//!
//! Implements the transformer forward pass directly over the quantized
//! (or dense) weights — the Rust analog of the paper's CUDA MMQ/MMVQ
//! kernels. Two entry points:
//!
//! - [`NativeEngine::decode_step`] — the MMVQ path (§5.4): one token,
//!   fused dequant matvecs, per-sequence KV cache.
//! - [`Engine::decode_batch`] — the fused multi-sequence MMVQ/MMQ
//!   hybrid: all sequences of a decode round advance one token through
//!   each layer together, so every linear runs one batched Q8 GEMM
//!   ([`crate::quant::matmul::QuantizedLinear::gemm_q8`]) that unpacks
//!   each weight block once for the whole batch. KV traffic stays
//!   per-sequence (ragged positions, ragged contexts) through
//!   [`KvBatchStore`]. Test-enforced bit-identical to stepping each
//!   sequence alone.
//! - [`NativeEngine::prefill`] — the MMQ path (§5.2): all prompt
//!   positions batched through each linear so every weight block is
//!   dequantized once per *tile* rather than once per token (the
//!   mechanism behind the paper's prefill-throughput win in Table 2).
//!
//! Math matches `python/compile/model.py` op-for-op (RMSNorm → QKV →
//! interleaved-pair RoPE → causal softmax(QKᵀ/√hd)V → Wo → residual →
//! RMSNorm → SwiGLU → residual; tied-embedding LM head), verified by the
//! integration tests in `rust/tests/pjrt_parity.rs`.

use super::{
    weights::PaddedLinear, BatchSlot, DenseModel, KvBatchStore, KvCache, KvStore, ModelConfig,
    QuantizedModel,
};
use crate::quant::audit::{AuditProbe, AuditReport};
use crate::quant::matmul::MatvecScratch;
use crate::tensor::{matvec_accum, Tensor};
use crate::util::profile;
use std::sync::Mutex;

/// Engine abstraction shared by the native and PJRT backends.
///
/// KV state goes through the [`KvStore`] trait so the same forward pass
/// runs against the dense per-sequence cache or a paged/quantized view
/// from [`crate::kvpaged`] — `&mut KvCache` coerces at every call site.
pub trait Engine: Send + Sync {
    fn config(&self) -> &ModelConfig;
    /// Append `token` at position `cache.len()`, returning next-token
    /// logits.
    fn decode_step(&self, cache: &mut dyn KvStore, token: u32) -> Vec<f32>;
    /// Advance every sequence of `batch` by one token (`tokens[i]` feeds
    /// sequence `i`), returning next-token logits per sequence.
    ///
    /// Contract (test-enforced in `rust/tests/batched_decode.rs`): the
    /// results are **bit-identical** to calling [`Engine::decode_step`]
    /// on each sequence independently, for any batch size or
    /// composition — batching is a throughput optimization, never a
    /// numerics change. The default is that sequential loop; the native
    /// engine overrides it with a fused pass that runs each linear as
    /// one batched Q8 GEMM over all sequences.
    fn decode_batch(&self, batch: &mut dyn KvBatchStore, tokens: &[u32]) -> Vec<Vec<f32>> {
        assert_eq!(batch.n_seqs(), tokens.len());
        let mut out = Vec::with_capacity(tokens.len());
        for (i, &t) in tokens.iter().enumerate() {
            let mut slot = BatchSlot { batch: &mut *batch, i };
            out.push(self.decode_step(&mut slot, t));
        }
        out
    }
    /// Feed `tokens` sequentially — writing KV as it goes — and return
    /// next-token logits at **every** fed position: the speculative
    /// verify pass. Returning full per-position logits (never just the
    /// argmax) is load-bearing: lossless *sampled* verification
    /// ([`crate::spec::spec_step_sampled`]) rebuilds the sampler's
    /// exact post-filter distribution at each drafted position from
    /// them. Unlike [`Engine::prefill`] (which runs the batched
    /// f32 MMQ path), this must replay the *decode* path's numerics:
    ///
    /// Contract (test-enforced in `rust/tests/spec_decode.rs`): the
    /// returned logits and the resulting KV state are **bit-identical**
    /// to feeding the same tokens one at a time through
    /// [`Engine::decode_step`]. The default is that sequential loop;
    /// the native engine overrides it with a fused pass that scores all
    /// positions through one batched Q8 GEMM per linear, so verifying
    /// `k` drafts costs roughly one weight-unpack sweep instead of `k`.
    fn score_tokens(&self, cache: &mut dyn KvStore, tokens: &[u32]) -> Vec<Vec<f32>> {
        tokens.iter().map(|&t| self.decode_step(cache, t)).collect()
    }
    /// Ingest a whole prompt, returning logits at every position
    /// (`(len, vocab)`).
    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u32]) -> Tensor;
    /// Restore any interior-mutable engine state after a caught panic
    /// (the coordinator calls this before requeuing survivors). The
    /// weights are immutable, so for most engines this is a no-op; the
    /// native engine clears and rebuilds its poisoned scratch mutexes.
    fn reset(&self) {}
    /// Static weight audit: walk every quantized tensor, check the
    /// reconstruction against the Theorem-2 bound (see
    /// [`crate::quant::audit`]). Engines without packed weights have
    /// nothing to audit and report trivially clean.
    fn audit_weights(&self) -> AuditReport {
        AuditReport::empty("dense")
    }
    /// Logit-drift shadow probe: re-score the position after `tokens`
    /// through both the production decode path and the f32 reference
    /// path (`act_quant = false`), in **fresh** KV caches — the live KV
    /// state, sampler RNG and scratch numerics are untouched, so probing
    /// can never perturb what it measures (test-enforced byte-identity
    /// of served tokens at any sample rate). `None` means the engine has
    /// no reference path to shadow against; the coordinator then skips
    /// the probe.
    fn audit_probe(&self, _tokens: &[u32]) -> Option<AuditProbe> {
        None
    }
}

/// Weight storage variants the native engine can run.
pub enum Weights {
    Dense(DenseModel),
    Quant(QuantizedModel),
}

pub struct NativeEngine {
    pub weights: Weights,
    /// Run quantized decode matvecs on the W3A8 integer path (default).
    /// Disabled only for f32-path comparison baselines.
    act_quant: bool,
    /// Per-worker matvec scratch, reused across decode steps so the
    /// MMVQ loop stops allocating (`x.to_vec()` + per-call Vecs) — the
    /// coordinator drives one engine from one worker thread, so this
    /// lock is uncontended.
    scratch: Mutex<MatvecScratch>,
    /// Staging buffers of the fused batched decode pass (`B·dim` /
    /// `B·ffn` activations), warm after the first round. Same
    /// single-worker story as `scratch`; when both are taken the batch
    /// scratch is locked first (the only multi-lock site is
    /// `decode_batch`, so the order cannot invert).
    batch_scratch: Mutex<BatchScratch>,
}

/// Residual/activation staging for [`Engine::decode_batch`], row-major
/// `(batch, width)` per buffer.
#[derive(Default)]
struct BatchScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    o: Vec<f32>,
    g1: Vec<f32>,
    g3: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
}

/// `x * w / rms(x)` into `out`.
fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = v * inv * g;
    }
}

/// Interleaved-pair RoPE applied in place to one `(dim,)` vector laid out
/// as `n_heads` x `head_dim`; pair `(2i, 2i+1)` within each head rotates
/// by `pos / theta^(2i/head_dim)`.
fn rope(x: &mut [f32], pos: usize, n_heads: usize, head_dim: usize, theta: f32) {
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..head_dim / 2 {
            let freq = 1.0 / (theta as f64).powf(2.0 * i as f64 / head_dim as f64);
            let ang = pos as f64 * freq;
            let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
            let (a, b) = (x[base + 2 * i], x[base + 2 * i + 1]);
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// In-place softmax over a slice.
fn softmax(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Apply a linear in whichever representation the layer holds.
enum Lin<'a> {
    Dense(&'a Tensor),
    Quant(&'a PaddedLinear),
}

impl<'a> Lin<'a> {
    /// Decode-path matvec. Quantized layers run the W3A8 integer kernels
    /// when the format has a specialized `dot_block_q8` (the generic
    /// fallback would be slower *and* noisier than f32) and `act_quant`
    /// is on; otherwise the row-sharded fused f32 path — so every format
    /// still gets the parallelism win, and `act_quant = false` gives the
    /// numeric comparison baseline.
    fn matvec(&self, x: &[f32], y: &mut [f32], scratch: &mut MatvecScratch, act_quant: bool) {
        match self {
            Lin::Dense(t) => {
                let _p = profile::scope(profile::Phase::Gemm);
                y.fill(0.0);
                matvec_accum(t, x, y);
            }
            Lin::Quant(q) => {
                if act_quant && q.has_q8_kernel() {
                    q.matvec_q8(x, y, scratch);
                } else {
                    q.matvec_par(x, y, scratch);
                }
            }
        }
    }

    fn matmul(&self, x: &Tensor) -> Tensor {
        match self {
            Lin::Dense(t) => {
                let _p = profile::scope(profile::Phase::Gemm);
                x.matmul(&t.transpose())
            }
            // Quantized prefill scopes itself inside `matmul_sharded`
            // (rotation → RotQuant, accumulation → Gemm).
            Lin::Quant(q) => q.matmul(x),
        }
    }

    /// Batched decode-path apply: `x` row-major `(batch, in)`, `y`
    /// row-major `(batch, out)`. Routing mirrors [`Lin::matvec`] per
    /// row: the fused Q8 GEMM runs only where the sequential path would
    /// run the integer matvec (specialized kernel + `act_quant`), and
    /// every other configuration replays the sequential path per row —
    /// so batched and sequential decode stay bit-identical in *every*
    /// configuration, not just the hot one.
    fn matmul_batch(
        &self,
        x: &[f32],
        batch: usize,
        y: &mut [f32],
        scratch: &mut MatvecScratch,
        act_quant: bool,
    ) {
        if let Lin::Quant(q) = self {
            if act_quant && q.has_q8_kernel() {
                q.matmul_q8(x, batch, y, scratch);
                return;
            }
        }
        // Everything the GEMM doesn't cover replays [`Lin::matvec`] per
        // row — one routing function, so batched and sequential decode
        // cannot drift apart in the non-hot configurations.
        let in_dim = x.len() / batch;
        let out_dim = y.len() / batch;
        for (xr, yr) in x.chunks_exact(in_dim).zip(y.chunks_exact_mut(out_dim)) {
            self.matvec(xr, yr, scratch, act_quant);
        }
    }
}

/// Uniform view over one layer's seven linears.
struct LayerView<'a> {
    attn_norm: &'a [f32],
    wq: Lin<'a>,
    wk: Lin<'a>,
    wv: Lin<'a>,
    wo: Lin<'a>,
    ffn_norm: &'a [f32],
    w1: Lin<'a>,
    w3: Lin<'a>,
    w2: Lin<'a>,
}

impl NativeEngine {
    pub fn dense(m: DenseModel) -> Self {
        NativeEngine {
            weights: Weights::Dense(m),
            act_quant: true,
            scratch: Mutex::new(MatvecScratch::new()),
            batch_scratch: Mutex::new(BatchScratch::default()),
        }
    }

    pub fn quantized(m: QuantizedModel) -> Self {
        NativeEngine {
            weights: Weights::Quant(m),
            act_quant: true,
            scratch: Mutex::new(MatvecScratch::new()),
            batch_scratch: Mutex::new(BatchScratch::default()),
        }
    }

    /// Toggle the W3A8 integer decode path (on by default). The f32 path
    /// is kept as the numeric baseline for parity tests and ablations.
    pub fn with_act_quant(mut self, on: bool) -> Self {
        self.act_quant = on;
        self
    }

    fn cfg(&self) -> &ModelConfig {
        match &self.weights {
            Weights::Dense(m) => &m.cfg,
            Weights::Quant(m) => &m.cfg,
        }
    }

    fn embed(&self) -> &Tensor {
        match &self.weights {
            Weights::Dense(m) => &m.embed,
            Weights::Quant(m) => &m.embed,
        }
    }

    fn final_norm(&self) -> &[f32] {
        match &self.weights {
            Weights::Dense(m) => &m.final_norm,
            Weights::Quant(m) => &m.final_norm,
        }
    }

    fn layer(&self, i: usize) -> LayerView<'_> {
        match &self.weights {
            Weights::Dense(m) => {
                let l = &m.layers[i];
                LayerView {
                    attn_norm: &l.attn_norm,
                    wq: Lin::Dense(&l.wq),
                    wk: Lin::Dense(&l.wk),
                    wv: Lin::Dense(&l.wv),
                    wo: Lin::Dense(&l.wo),
                    ffn_norm: &l.ffn_norm,
                    w1: Lin::Dense(&l.w1),
                    w3: Lin::Dense(&l.w3),
                    w2: Lin::Dense(&l.w2),
                }
            }
            Weights::Quant(m) => {
                let l = &m.layers[i];
                LayerView {
                    attn_norm: &l.attn_norm,
                    wq: Lin::Quant(&l.wq),
                    wk: Lin::Quant(&l.wk),
                    wv: Lin::Quant(&l.wv),
                    wo: Lin::Quant(&l.wo),
                    ffn_norm: &l.ffn_norm,
                    w1: Lin::Quant(&l.w1),
                    w3: Lin::Quant(&l.w3),
                    w2: Lin::Quant(&l.w2),
                }
            }
        }
    }

    /// LM-head logits for one hidden vector (tied embedding).
    fn logits_for(&self, h: &[f32]) -> Vec<f32> {
        let cfg = self.cfg();
        let mut hn = vec![0.0f32; cfg.dim];
        rmsnorm(h, self.final_norm(), cfg.eps, &mut hn);
        let mut logits = vec![0.0f32; cfg.vocab];
        matvec_accum(self.embed(), &hn, &mut logits);
        logits
    }

    /// Single-token MMVQ forward with the act-quant routing made an
    /// explicit parameter and an optional per-layer residual tee.
    /// [`Engine::decode_step`] is exactly `self.decode_step_at(cache,
    /// token, self.act_quant, None)` — when `capture` is `None` no code
    /// path differs, which is what keeps the audit machinery out of the
    /// production numerics. With `capture` set, the residual stream is
    /// cloned after each layer (quantized vs reference comparison points
    /// for the shadow probe's error-accumulation profile).
    fn decode_step_at(
        &self,
        cache: &mut dyn KvStore,
        token: u32,
        aq: bool,
        mut capture: Option<&mut Vec<Vec<f32>>>,
    ) -> Vec<f32> {
        let cfg = self.cfg().clone();
        let pos = cache.len();
        assert!(pos < cfg.max_seq.min(cache.capacity()), "sequence overflows max_seq");
        let (dim, hd, nh) = (cfg.dim, cfg.head_dim(), cfg.n_heads);

        let mut x = self.embed().row(token as usize).to_vec();
        let mut h = vec![0.0f32; dim];
        let mut q = vec![0.0f32; dim];
        let mut k = vec![0.0f32; dim];
        let mut v = vec![0.0f32; dim];
        let mut attn = vec![0.0f32; dim];
        let mut o = vec![0.0f32; dim];
        let mut g1 = vec![0.0f32; cfg.ffn];
        let mut g3 = vec![0.0f32; cfg.ffn];
        let mut ff = vec![0.0f32; dim];
        let mut scores = vec![0.0f32; pos + 1];
        // Engine-held matvec scratch: rotation copy, Q8 activation codes,
        // padding buffer — warm after the first step, so the per-token
        // MMVQ loop allocates nothing.
        let mut mv = self.scratch.lock().expect("matvec scratch poisoned");

        for li in 0..cfg.n_layers {
            let l = self.layer(li);
            // --- attention ---
            rmsnorm(&x, l.attn_norm, cfg.eps, &mut h);
            l.wq.matvec(&h, &mut q, &mut mv, aq);
            l.wk.matvec(&h, &mut k, &mut mv, aq);
            l.wv.matvec(&h, &mut v, &mut mv, aq);
            rope(&mut q, pos, nh, hd, cfg.rope_theta);
            rope(&mut k, pos, nh, hd, cfg.rope_theta);
            cache.write_kv(li, pos, &k, &v);
            let scale = 1.0 / (hd as f32).sqrt();
            {
                // Profiler: score/softmax/weighted-sum only — the QKV and
                // Wo linears above/below carry their own Gemm scopes.
                let _p = profile::scope(profile::Phase::Attention);
                for hh in 0..nh {
                    let qh = &q[hh * hd..(hh + 1) * hd];
                    for (t, s) in scores.iter_mut().enumerate() {
                        let kh = &cache.k_at(li, t)[hh * hd..(hh + 1) * hd];
                        *s = crate::quant::matmul::dot(qh, kh) * scale;
                    }
                    softmax(&mut scores);
                    let out = &mut attn[hh * hd..(hh + 1) * hd];
                    out.fill(0.0);
                    for (t, &p) in scores.iter().enumerate() {
                        let vh = &cache.v_at(li, t)[hh * hd..(hh + 1) * hd];
                        for (oj, &vj) in out.iter_mut().zip(vh) {
                            *oj += p * vj;
                        }
                    }
                }
            }
            l.wo.matvec(&attn, &mut o, &mut mv, aq);
            for (xi, oi) in x.iter_mut().zip(&o) {
                *xi += oi;
            }
            // --- SwiGLU FFN ---
            rmsnorm(&x, l.ffn_norm, cfg.eps, &mut h);
            l.w1.matvec(&h, &mut g1, &mut mv, aq);
            l.w3.matvec(&h, &mut g3, &mut mv, aq);
            for (a, &b) in g1.iter_mut().zip(&g3) {
                *a = silu(*a) * b;
            }
            l.w2.matvec(&g1, &mut ff, &mut mv, aq);
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
            if let Some(cap) = capture.as_mut() {
                cap.push(x.clone());
            }
        }
        drop(mv);
        cache.push_token(token);
        self.logits_for(&x)
    }
}

impl Engine for NativeEngine {
    fn config(&self) -> &ModelConfig {
        self.cfg()
    }

    fn reset(&self) {
        // A panic while a scratch lock was held poisons it; both locks
        // hold plain staging buffers with no cross-call invariants, so
        // recovery is: un-poison, then restore the pristine (empty)
        // state rather than trust buffers a forward pass died in.
        self.scratch.clear_poison();
        *self.scratch.lock().expect("just cleared") = MatvecScratch::new();
        self.batch_scratch.clear_poison();
        *self.batch_scratch.lock().expect("just cleared") = BatchScratch::default();
    }

    fn decode_step(&self, cache: &mut dyn KvStore, token: u32) -> Vec<f32> {
        self.decode_step_at(cache, token, self.act_quant, None)
    }

    fn audit_weights(&self) -> AuditReport {
        match &self.weights {
            Weights::Dense(_) => AuditReport::empty("dense"),
            Weights::Quant(m) => m.audit(),
        }
    }

    /// Replay `tokens` twice through [`NativeEngine::decode_step_at`] in
    /// fresh [`KvCache`]s — once on the production path (`self.act_quant`
    /// routing, so the probe shadows exactly what serving runs) and once
    /// on the f32 reference path — teeing the residual stream at the last
    /// position. O(len²) attention per replay, which is why the
    /// coordinator *samples* probes instead of running one per round.
    fn audit_probe(&self, tokens: &[u32]) -> Option<AuditProbe> {
        if tokens.is_empty() {
            return None;
        }
        let run = |aq: bool| {
            let mut cache = KvCache::new(self.cfg());
            let mut layers = Vec::new();
            let mut logits = Vec::new();
            for (i, &t) in tokens.iter().enumerate() {
                let cap = if i + 1 == tokens.len() { Some(&mut layers) } else { None };
                logits = self.decode_step_at(&mut cache, t, aq, cap);
            }
            (layers, logits)
        };
        let (layers_q, logits_quant) = run(self.act_quant);
        let (layers_r, logits_ref) = run(false);
        let layer_rel_l2 = layers_q
            .iter()
            .zip(&layers_r)
            .map(|(q, r)| crate::util::stats::rel_l2_err(r, q))
            .collect();
        Some(AuditProbe { layer_rel_l2, logits_quant, logits_ref })
    }

    /// Fused multi-sequence decode: one forward pass advances every
    /// sequence by one token, with each linear applied as a single
    /// batched Q8 GEMM over all sequences (each packed weight block
    /// unpacked once per output row for the whole batch). Positions and
    /// attention contexts are ragged — per-sequence — and all KV reads
    /// and writes go through the per-index [`KvBatchStore`] methods, so
    /// paged, quantized and dense stores all work unchanged. Per
    /// sequence, every operation replays [`NativeEngine::decode_step`]'s
    /// math exactly (the GEMM's per-column bit-identity contract plus
    /// shared scalar kernels), which is what keeps batched decode
    /// bit-identical to sequential decode.
    fn decode_batch(&self, batch: &mut dyn KvBatchStore, tokens: &[u32]) -> Vec<Vec<f32>> {
        let nb = tokens.len();
        assert_eq!(batch.n_seqs(), nb);
        if nb == 0 {
            return Vec::new();
        }
        let cfg = self.cfg().clone();
        let (dim, hd, nh) = (cfg.dim, cfg.head_dim(), cfg.n_heads);
        let pos: Vec<usize> = (0..nb).map(|i| batch.seq_len(i)).collect();
        for (i, &p) in pos.iter().enumerate() {
            assert!(
                p < cfg.max_seq.min(batch.capacity(i)),
                "sequence {i} overflows max_seq"
            );
        }

        let mut bs = self.batch_scratch.lock().expect("batch scratch poisoned");
        let BatchScratch { x, h, q, k, v, attn, o, g1, g3, ff, scores } = &mut *bs;
        let dim_bufs =
            [&mut *x, &mut *h, &mut *q, &mut *k, &mut *v, &mut *attn, &mut *o, &mut *ff];
        for buf in dim_bufs {
            buf.clear();
            buf.resize(nb * dim, 0.0);
        }
        for buf in [&mut *g1, &mut *g3] {
            buf.clear();
            buf.resize(nb * cfg.ffn, 0.0);
        }
        for (i, &t) in tokens.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(self.embed().row(t as usize));
        }
        let mut mv = self.scratch.lock().expect("matvec scratch poisoned");
        let aq = self.act_quant;
        // Chaos site: a panic while BOTH scratch locks are held — the
        // worst case for `reset`, which must clear two poisoned
        // mutexes before the engine is usable again.
        if crate::util::failpoint::should_fail("native.decode_locked") {
            panic!("failpoint 'native.decode_locked': injected panic under scratch locks");
        }

        for li in 0..cfg.n_layers {
            let l = self.layer(li);
            // --- attention ---
            for s in 0..nb {
                let xs = &x[s * dim..(s + 1) * dim];
                rmsnorm(xs, l.attn_norm, cfg.eps, &mut h[s * dim..(s + 1) * dim]);
            }
            l.wq.matmul_batch(&h[..], nb, &mut q[..], &mut mv, aq);
            l.wk.matmul_batch(&h[..], nb, &mut k[..], &mut mv, aq);
            l.wv.matmul_batch(&h[..], nb, &mut v[..], &mut mv, aq);
            for s in 0..nb {
                rope(&mut q[s * dim..(s + 1) * dim], pos[s], nh, hd, cfg.rope_theta);
                rope(&mut k[s * dim..(s + 1) * dim], pos[s], nh, hd, cfg.rope_theta);
                let (ks, vs) = (&k[s * dim..(s + 1) * dim], &v[s * dim..(s + 1) * dim]);
                batch.write_kv(s, li, pos[s], ks, vs);
            }
            let scale = 1.0 / (hd as f32).sqrt();
            {
                // Profiler: ragged per-sequence attention only (see the
                // matching scope in `decode_step`).
                let _p = profile::scope(profile::Phase::Attention);
                for s in 0..nb {
                    scores.resize(pos[s] + 1, 0.0);
                    for hh in 0..nh {
                        let qh = &q[s * dim + hh * hd..s * dim + (hh + 1) * hd];
                        for (t, sc) in scores.iter_mut().enumerate() {
                            let kh = &batch.k_at(s, li, t)[hh * hd..(hh + 1) * hd];
                            *sc = crate::quant::matmul::dot(qh, kh) * scale;
                        }
                        softmax(&mut scores[..]);
                        let out = &mut attn[s * dim + hh * hd..s * dim + (hh + 1) * hd];
                        out.fill(0.0);
                        for (t, &p) in scores.iter().enumerate() {
                            let vh = &batch.v_at(s, li, t)[hh * hd..(hh + 1) * hd];
                            for (oj, &vj) in out.iter_mut().zip(vh) {
                                *oj += p * vj;
                            }
                        }
                    }
                }
            }
            l.wo.matmul_batch(&attn[..], nb, &mut o[..], &mut mv, aq);
            for (xi, oi) in x.iter_mut().zip(o.iter()) {
                *xi += oi;
            }
            // --- SwiGLU FFN ---
            for s in 0..nb {
                let xs = &x[s * dim..(s + 1) * dim];
                rmsnorm(xs, l.ffn_norm, cfg.eps, &mut h[s * dim..(s + 1) * dim]);
            }
            l.w1.matmul_batch(&h[..], nb, &mut g1[..], &mut mv, aq);
            l.w3.matmul_batch(&h[..], nb, &mut g3[..], &mut mv, aq);
            for (a, &b) in g1.iter_mut().zip(g3.iter()) {
                *a = silu(*a) * b;
            }
            l.w2.matmul_batch(&g1[..], nb, &mut ff[..], &mut mv, aq);
            for (xi, fi) in x.iter_mut().zip(ff.iter()) {
                *xi += fi;
            }
        }
        drop(mv);
        for (i, &t) in tokens.iter().enumerate() {
            batch.push_token(i, t);
        }
        (0..nb).map(|s| self.logits_for(&x[s * dim..(s + 1) * dim])).collect()
    }

    /// Fused verify pass: `n` consecutive positions of one sequence run
    /// through [`NativeEngine::decode_batch`] via a [`SpecSlots`] view,
    /// so every linear is one batched Q8 GEMM over all positions (each
    /// weight block unpacked once for the whole span). Bit-identity
    /// with sequential `decode_step` follows from the batched pass's
    /// own per-slot contract plus causality of the slot layout: within
    /// each layer all slots write their K/V rows before any slot
    /// attends, and slot `i` reads only positions `0..=base + i` — so
    /// slot `i` sees exactly the state a sequential step at that
    /// position would, layer by layer, by induction.
    fn score_tokens(&self, cache: &mut dyn KvStore, tokens: &[u32]) -> Vec<Vec<f32>> {
        if tokens.len() < 2 {
            // Nothing to fuse; take the sequential path.
            return tokens.iter().map(|&t| self.decode_step(cache, t)).collect();
        }
        let mut slots = super::SpecSlots::new(cache, tokens.len());
        self.decode_batch(&mut slots, tokens)
    }

    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u32]) -> Tensor {
        let cfg = self.cfg().clone();
        let seq = tokens.len();
        let pos0 = cache.len();
        assert!(pos0 + seq <= cfg.max_seq.min(cache.capacity()), "prefill overflows max_seq");
        let (dim, hd, nh) = (cfg.dim, cfg.head_dim(), cfg.n_heads);

        // X: (seq, dim) residual stream.
        let mut x = Tensor::zeros(vec![seq, dim]);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed().row(tok as usize));
        }
        let mut hn = Tensor::zeros(vec![seq, dim]);
        for li in 0..cfg.n_layers {
            let l = self.layer(li);
            // Batched QKV over all positions (the MMQ path).
            for t in 0..seq {
                rmsnorm(x.row(t), l.attn_norm, cfg.eps, hn.row_mut(t));
            }
            let mut q = l.wq.matmul(&hn);
            let mut k = l.wk.matmul(&hn);
            let v = l.wv.matmul(&hn);
            for t in 0..seq {
                rope(q.row_mut(t), pos0 + t, nh, hd, cfg.rope_theta);
                rope(k.row_mut(t), pos0 + t, nh, hd, cfg.rope_theta);
                cache.write_kv(li, pos0 + t, k.row(t), v.row(t));
            }
            // Causal attention per position (reads K/V back from cache so
            // chunked prefill after a prior prefix is handled uniformly).
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn = Tensor::zeros(vec![seq, dim]);
            let mut scores = Vec::new();
            {
                // Profiler: causal attention only (see `decode_step`).
                let _p = profile::scope(profile::Phase::Attention);
                for t in 0..seq {
                    let ctx = pos0 + t + 1;
                    scores.resize(ctx, 0.0);
                    for hh in 0..nh {
                        let qh = &q.row(t)[hh * hd..(hh + 1) * hd];
                        for (u, s) in scores.iter_mut().enumerate() {
                            let kh = &cache.k_at(li, u)[hh * hd..(hh + 1) * hd];
                            *s = crate::quant::matmul::dot(qh, kh) * scale;
                        }
                        softmax(&mut scores);
                        let out = &mut attn.row_mut(t)[hh * hd..(hh + 1) * hd];
                        for (u, &p) in scores.iter().enumerate() {
                            let vh = &cache.v_at(li, u)[hh * hd..(hh + 1) * hd];
                            for (oj, &vj) in out.iter_mut().zip(vh) {
                                *oj += p * vj;
                            }
                        }
                    }
                }
            }
            let o = l.wo.matmul(&attn);
            for t in 0..seq {
                for (xi, oi) in x.row_mut(t).iter_mut().zip(o.row(t)) {
                    *xi += oi;
                }
            }
            // FFN, batched.
            for t in 0..seq {
                rmsnorm(x.row(t), l.ffn_norm, cfg.eps, hn.row_mut(t));
            }
            let mut g1 = l.w1.matmul(&hn);
            let g3 = l.w3.matmul(&hn);
            for t in 0..seq {
                for (a, &b) in g1.row_mut(t).iter_mut().zip(g3.row(t)) {
                    *a = silu(*a) * b;
                }
            }
            let ff = l.w2.matmul(&g1);
            for t in 0..seq {
                for (xi, fi) in x.row_mut(t).iter_mut().zip(ff.row(t)) {
                    *xi += fi;
                }
            }
        }
        for &t in tokens {
            cache.push_token(t);
        }
        // Logits at every position.
        let mut logits = Tensor::zeros(vec![seq, cfg.vocab]);
        for t in 0..seq {
            logits.row_mut(t).copy_from_slice(&self.logits_for(x.row(t)));
        }
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::KvCache;
    use crate::quant::format_by_name;

    fn engine_pair() -> (NativeEngine, NativeEngine) {
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 42, Some(5.0));
        let q = QuantizedModel::quantize(&dense, format_by_name("q8_0").unwrap());
        (NativeEngine::dense(dense), NativeEngine::quantized(q))
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [2.0f32, 2.0, 2.0, 2.0];
        let w = [1.0f32; 4];
        let mut out = [0.0f32; 4];
        rmsnorm(&x, &w, 0.0, &mut out);
        for &o in &out {
            assert!((o - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let orig = x.clone();
        rope(&mut x, 0, 2, 8, 10_000.0);
        assert_eq!(x, orig, "pos 0 must be identity");
        rope(&mut x, 7, 2, 8, 10_000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }

    #[test]
    fn softmax_normalizes() {
        let mut s = vec![1.0f32, 2.0, 3.0];
        softmax(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn prefill_matches_decode_loop() {
        // The MMQ (batched) and MMVQ (token-by-token) paths must produce
        // identical logits and identical KV state.
        let (dense, _) = engine_pair();
        let tokens = [0u32, 10, 20, 30, 5];
        let cfg = dense.config().clone();
        let mut c1 = KvCache::new(&cfg);
        let lp = dense.prefill(&mut c1, &tokens);
        let mut c2 = KvCache::new(&cfg);
        let mut last = Vec::new();
        for &t in &tokens {
            last = dense.decode_step(&mut c2, t);
        }
        assert_eq!(c1.len(), c2.len());
        for (a, b) in lp.row(tokens.len() - 1).iter().zip(&last) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
        // KV parity at a middle layer/position.
        for (a, b) in c1.k_at(1, 3).iter().zip(c2.k_at(1, 3)) {
            assert!((a - b).abs() < 2e-4);
        }
    }

    #[test]
    fn chunked_prefill_matches_single_shot() {
        let (dense, _) = engine_pair();
        let cfg = dense.config().clone();
        let tokens = [0u32, 3, 9, 27, 33, 11, 7];
        let mut c1 = KvCache::new(&cfg);
        let l1 = dense.prefill(&mut c1, &tokens);
        let mut c2 = KvCache::new(&cfg);
        dense.prefill(&mut c2, &tokens[..4]);
        let l2 = dense.prefill(&mut c2, &tokens[4..]);
        for (a, b) in l1.row(6).iter().zip(l2.row(2)) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_engine_tracks_dense() {
        // q8_0 is near-lossless, so its logits must track the dense
        // engine closely even after several layers.
        let (dense, quant) = engine_pair();
        let cfg = dense.config().clone();
        let tokens = [0u32, 4, 8, 15, 16, 23, 42];
        let mut cd = KvCache::new(&cfg);
        let mut cq = KvCache::new(&cfg);
        let ld = dense.prefill(&mut cd, &tokens);
        let lq = quant.prefill(&mut cq, &tokens);
        let rel = crate::util::stats::rel_l2_err(ld.data(), lq.data());
        assert!(rel < 0.04, "rel={rel}");
    }

    #[test]
    fn w3a8_decode_tracks_f32_decode() {
        // The integer decode path must shift logits by well under the
        // 1e-2 rel-L2 acceptance budget vs the fused f32 path on the
        // same quantized weights.
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 77, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let e_int = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt.clone()));
        let e_f32 =
            NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt)).with_act_quant(false);
        let toks = [0u32, 104, 101, 108, 108, 111, 32, 119];
        let mut c1 = KvCache::new(e_int.config());
        let mut c2 = KvCache::new(e_f32.config());
        let mut a = Vec::new();
        let mut b = Vec::new();
        for &t in &toks {
            a = e_int.decode_step(&mut c1, t);
            b = e_f32.decode_step(&mut c2, t);
        }
        let rel = crate::util::stats::rel_l2_err(&b, &a);
        assert!(rel < 1e-2, "W3A8 decode rel-L2 {rel}");
        // And the KV state they build must stay equally close.
        let relk = crate::util::stats::rel_l2_err(c2.k_at(1, 3), c1.k_at(1, 3));
        assert!(relk < 1e-2, "W3A8 KV rel-L2 {relk}");
    }

    #[test]
    fn w3a8_decode_is_deterministic() {
        // The integer path (with its row sharding and scratch reuse)
        // must stay bit-deterministic across engines and repeated runs.
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 78, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let e1 = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt.clone()));
        let e2 = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let mut c1 = KvCache::new(e1.config());
        let mut c2 = KvCache::new(e2.config());
        for &t in &[7u32, 7, 9] {
            let a = e1.decode_step(&mut c1, t);
            let b = e2.decode_step(&mut c2, t);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn audit_weights_walks_every_linear() {
        let (dense, quant) = engine_pair();
        let rep = quant.audit_weights();
        assert!(rep.ok(), "clean q8_0 artifact must audit clean");
        assert_eq!(rep.tensors.len(), quant.config().n_layers * 7);
        assert_eq!(rep.fmt, "q8_0");
        // Dense engines have no packed tensors: trivially clean.
        let rep_d = dense.audit_weights();
        assert!(rep_d.ok());
        assert!(rep_d.tensors.is_empty());
    }

    #[test]
    fn audit_probe_measures_drift_without_perturbing_decode() {
        // Twin engines on the same quantized weights: one is probed
        // after every decode step, the control never is. Served logits
        // must stay bitwise identical — the probe runs in fresh caches
        // and may not touch live state.
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 77, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let probed = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt.clone()));
        let control = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let toks = [0u32, 104, 101, 108, 108, 111];
        let mut c1 = KvCache::new(probed.config());
        let mut c2 = KvCache::new(control.config());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for (i, &t) in toks.iter().enumerate() {
            a = probed.decode_step(&mut c1, t);
            let p = probed.audit_probe(&toks[..=i]).expect("native engine has a probe");
            assert_eq!(p.layer_rel_l2.len(), cfg.n_layers);
            assert!(
                p.layer_rel_l2.iter().all(|r| r.is_finite() && *r < 5e-2),
                "per-layer drift {:?}",
                p.layer_rel_l2
            );
            assert!(p.kl_divergence().is_finite());
            // The probe's quantized side replays the decode path bit for
            // bit (same weights, same deterministic kernels).
            assert_eq!(p.logits_quant, a, "probe replay diverged at step {i}");
            b = control.decode_step(&mut c2, t);
        }
        assert_eq!(a, b, "probing must not change served logits");
    }

    #[test]
    fn audit_probe_on_dense_engine_reports_zero_drift() {
        // No quantized path to drift from: both probe passes run the
        // same f32 math, so every metric is exactly quiet.
        let (dense, _) = engine_pair();
        let p = dense.audit_probe(&[1, 2, 3]).expect("probe runs on dense too");
        assert!(p.layer_rel_l2.iter().all(|&r| r == 0.0));
        assert_eq!(p.kl_divergence(), 0.0);
        assert!(p.top1_agree());
        assert_eq!(p.max_logit_delta(), 0.0);
        // Empty history: nothing to probe.
        assert!(dense.audit_probe(&[]).is_none());
    }

    #[test]
    fn decode_batch_matches_decode_step_bitwise() {
        // Engine-level spot check of the batched-decode contract (the
        // full cross-format/ragged harness is tests/batched_decode.rs):
        // a fused 3-sequence round equals three sequential steps, bit
        // for bit, on ragged prompts.
        use crate::model::StoreBatch;
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 91, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 8], &[40, 41, 42, 43, 44]];
        let forced: [[u32; 2]; 3] = [[7, 11], [200, 201], [5, 6]];

        // Sequential reference runs.
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for (p, f) in prompts.iter().zip(&forced) {
            let mut c = KvCache::new(&cfg);
            eng.prefill(&mut c, p);
            want.push(f.iter().map(|&t| eng.decode_step(&mut c, t)).collect());
        }

        // Batched run over the same prompts.
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&cfg);
                eng.prefill(&mut c, p);
                c
            })
            .collect();
        for r in 0..2 {
            let toks: Vec<u32> = forced.iter().map(|f| f[r]).collect();
            let mut stores: Vec<&mut dyn crate::model::KvStore> = Vec::new();
            for c in caches.iter_mut() {
                stores.push(c);
            }
            let mut batch = StoreBatch { stores };
            let got = eng.decode_batch(&mut batch, &toks);
            for (s, g) in got.iter().enumerate() {
                assert_eq!(g, &want[s][r], "seq {s} round {r} diverged");
            }
        }
        for (c, p) in caches.iter().zip(&prompts) {
            assert_eq!(c.len(), p.len() + 2, "token history must advance");
        }
    }

    #[test]
    fn score_tokens_matches_sequential_decode_bitwise() {
        // Engine-level spot check of the verify-pass contract (the full
        // drafter/backend sweep is tests/spec_decode.rs): the fused
        // multi-position score equals the same tokens fed one at a time
        // through decode_step, bit for bit, logits and KV state alike.
        let cfg = ModelConfig::test();
        let dense = DenseModel::random(&cfg, 55, Some(5.0));
        let fmt = format_by_name("itq3_s").unwrap();
        let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let prompt = [3u32, 1, 4, 1, 5];
        let feed = [9u32, 2, 6, 5];

        let mut c_seq = KvCache::new(&cfg);
        eng.prefill(&mut c_seq, &prompt);
        let want: Vec<Vec<f32>> = feed.iter().map(|&t| eng.decode_step(&mut c_seq, t)).collect();

        let mut c_fused = KvCache::new(&cfg);
        eng.prefill(&mut c_fused, &prompt);
        let got = eng.score_tokens(&mut c_fused, &feed);

        assert_eq!(got.len(), feed.len());
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w, g, "position {i} diverged from sequential decode");
        }
        assert_eq!(c_seq.len(), c_fused.len());
        assert_eq!(c_seq.tokens, c_fused.tokens);
        for layer in 0..cfg.n_layers {
            for pos in 0..c_seq.len() {
                assert_eq!(
                    KvCache::k_at(&c_seq, layer, pos),
                    KvCache::k_at(&c_fused, layer, pos),
                    "K row ({layer},{pos}) diverged"
                );
                assert_eq!(
                    KvCache::v_at(&c_seq, layer, pos),
                    KvCache::v_at(&c_fused, layer, pos),
                    "V row ({layer},{pos}) diverged"
                );
            }
        }
    }

    #[test]
    fn decode_is_deterministic() {
        let (dense, _) = engine_pair();
        let cfg = dense.config().clone();
        let mut c1 = KvCache::new(&cfg);
        let mut c2 = KvCache::new(&cfg);
        let a = dense.decode_step(&mut c1, 7);
        let b = dense.decode_step(&mut c2, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn logits_shape() {
        let (dense, _) = engine_pair();
        let cfg = dense.config().clone();
        let mut c = KvCache::new(&cfg);
        let l = dense.prefill(&mut c, &[1, 2, 3]);
        assert_eq!(l.shape(), &[3, cfg.vocab]);
    }
}
