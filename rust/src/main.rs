//! `itq3s` — the L3 coordinator binary.
//!
//! Subcommands (hand-rolled parser; `clap` is not in the offline vendor
//! set):
//!
//! ```text
//! itq3s gen-corpus  [--out DIR] [--bytes N]        synthetic corpus splits
//! itq3s quantize    --model M.iguf --fmt F --out Q.iguf
//! itq3s inspect     --model M.iguf                 distribution + Thm1/2 stats
//! itq3s audit       --model Q.iguf                 per-tensor rel-L2 vs Thm-2 bound
//!                                                  (exit 1 on a violated artifact)
//! itq3s eval-ppl    --model M.iguf [--split valid|web] [--engine native|pjrt]
//! itq3s serve       --model M.iguf [--addr A] [--engine native|pjrt]
//!                   [--kv-budget BYTES] [--kv-block-tokens N] [--kv-quant f32|q8]
//!                   [--spec-draft-len K] [--spec-drafter ngram|self]
//!                   [--request-timeout-ms MS] [--max-queue-depth N]
//!                   [--replicas N] [--prefill-round-budget TOKENS]
//!                   [--audit-sample-rate R] [--audit-drift-warn KL]
//!
//! Every subcommand accepts `--log-level off|error|warn|info|debug`
//! (default info) controlling the structured stderr logger, and
//! `--no-simd` pinning the integer kernels to the scalar tier (the
//! `ITQ3S_NO_SIMD` env var does the same and wins over the flag; both
//! are A/B switches — all tiers are bit-identical by contract).
//! itq3s table1|table2|table3                       paper-table harnesses
//! itq3s e2e                                        end-to-end pipeline check
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage: itq3s <gen-corpus|quantize|inspect|audit|eval-ppl|serve|table1|table2|table3|e2e> [flags]"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (_pos, flags) = parse_flags(&args[1..]);
    if let Some(lvl) = flags.get("log-level") {
        let level = itq3s::util::log::Level::parse(lvl)
            .with_context(|| format!("unknown --log-level '{lvl}' (off|error|warn|info|debug)"))?;
        itq3s::util::log::set_level(level);
    }
    if flags.get("no-simd").map(|v| v != "false").unwrap_or(false) {
        itq3s::quant::simd::set_enabled(false);
    }
    match cmd.as_str() {
        "gen-corpus" => gen_corpus(&flags),
        "quantize" => quantize(&flags),
        "inspect" => inspect(&flags),
        "audit" => audit(&flags),
        "eval-ppl" => eval_ppl(&flags),
        "serve" => serve(&flags),
        "table1" => itq3s::bench::tables::table1(&flag_or(&flags, "artifacts", "artifacts")),
        "table2" => itq3s::bench::tables::table2(&flag_or(&flags, "artifacts", "artifacts")),
        "table3" => itq3s::bench::tables::table3(&flag_or(&flags, "artifacts", "artifacts")),
        "e2e" => e2e(&flags),
        _ => usage(),
    }
}

fn flag_or(flags: &HashMap<String, String>, key: &str, default: &str) -> String {
    flags.get(key).cloned().unwrap_or_else(|| default.to_string())
}

fn gen_corpus(flags: &HashMap<String, String>) -> Result<()> {
    let out = PathBuf::from(flag_or(flags, "out", "artifacts/corpus"));
    let bytes: usize = flag_or(flags, "bytes", "400000").parse()?;
    std::fs::create_dir_all(&out)?;
    let (train, valid, web) = itq3s::eval::corpus::standard_splits(bytes);
    for (name, text) in [("train.txt", &train), ("valid.txt", &valid), ("web.txt", &web)] {
        std::fs::write(out.join(name), text)?;
        println!("wrote {} ({} bytes)", out.join(name).display(), text.len());
    }
    Ok(())
}

fn quantize(flags: &HashMap<String, String>) -> Result<()> {
    let model = PathBuf::from(flags.get("model").context("--model required")?);
    let fmt_name = flag_or(flags, "fmt", "itq3_s");
    let out = PathBuf::from(flags.get("out").context("--out required")?);
    let fmt = itq3s::quant::format_by_name(&fmt_name)
        .with_context(|| format!("unknown format {fmt_name}"))?;
    let dense = itq3s::gguf::load_dense(&model)?;
    let t0 = std::time::Instant::now();
    let qm = itq3s::model::QuantizedModel::quantize(&dense, fmt.clone());
    let dt = t0.elapsed();
    itq3s::gguf::save_quantized(&qm, &out)?;
    println!(
        "quantized {} -> {} [{}], {} of packed linears ({:.3} b/w) in {:.2}s",
        model.display(),
        out.display(),
        fmt_name,
        itq3s::util::human_bytes(qm.linear_nbytes() as u64),
        fmt.bits_per_weight(),
        dt.as_secs_f64(),
    );
    Ok(())
}

fn inspect(flags: &HashMap<String, String>) -> Result<()> {
    let model = PathBuf::from(flags.get("model").context("--model required")?);
    let dense = itq3s::gguf::load_dense(&model)?;
    itq3s::bench::tables::inspect_model(&dense);
    Ok(())
}

fn audit(flags: &HashMap<String, String>) -> Result<()> {
    let model = PathBuf::from(flags.get("model").context("--model required")?);
    let engine = flag_or(flags, "engine", "native");
    let artifacts = flag_or(flags, "artifacts", "artifacts");
    let eng = load_engine(&model, &engine, &artifacts)?;
    let report = eng.audit_weights();
    print!("{}", report.render_table());
    if !report.ok() {
        bail!(
            "weight audit FAILED for {}: [{}] violate the Theorem-2 reconstruction bound",
            model.display(),
            report.violations().join(", ")
        );
    }
    Ok(())
}

fn load_engine(
    path: &Path,
    engine: &str,
    artifacts: &str,
) -> Result<Box<dyn itq3s::model::native::Engine>> {
    match engine {
        "native" => {
            // Accept either a dense or a quantized IGUF.
            if let Ok(qm) = itq3s::gguf::load_quantized(path) {
                Ok(Box::new(itq3s::model::NativeEngine::quantized(qm)))
            } else {
                let dense = itq3s::gguf::load_dense(path)?;
                Ok(Box::new(itq3s::model::NativeEngine::dense(dense)))
            }
        }
        "pjrt" => Ok(Box::new(itq3s::runtime::PjrtEngine::load(path, Path::new(artifacts))?)),
        other => bail!("unknown engine '{other}' (native|pjrt)"),
    }
}

fn eval_ppl(flags: &HashMap<String, String>) -> Result<()> {
    let model = PathBuf::from(flags.get("model").context("--model required")?);
    let split = flag_or(flags, "split", "valid");
    let artifacts = flag_or(flags, "artifacts", "artifacts");
    let engine = flag_or(flags, "engine", "native");
    let text = std::fs::read_to_string(
        PathBuf::from(&artifacts).join("corpus").join(format!("{split}.txt")),
    )?;
    let eng = load_engine(&model, &engine, &artifacts)?;
    let t0 = std::time::Instant::now();
    let r = itq3s::eval::perplexity(eng.as_ref(), &text);
    println!(
        "{} [{engine}] split={split}: ppl={:.4} nll={:.4} tokens={} ({:.1}s)",
        model.display(),
        r.ppl,
        r.nll,
        r.tokens,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let model = PathBuf::from(flags.get("model").context("--model required")?);
    let addr = flag_or(flags, "addr", "127.0.0.1:8090");
    let engine = flag_or(flags, "engine", "native");
    let artifacts = flag_or(flags, "artifacts", "artifacts");
    // Data-parallel engine replicas behind one shared admission queue
    // (1 = the single-engine path, exactly as before). Each replica is
    // a full engine instance loaded from the same weights, so N
    // replicas cost N× the weight memory.
    let replicas: usize = flag_or(flags, "replicas", "1").parse()?;
    if replicas == 0 {
        bail!("--replicas must be positive");
    }
    let engines: Vec<Box<dyn itq3s::model::native::Engine>> = (0..replicas)
        .map(|_| load_engine(&model, &engine, &artifacts))
        .collect::<Result<_>>()?;
    let kv_quant_name = flag_or(flags, "kv-quant", "f32");
    let kv_quant = itq3s::kvpaged::KvQuant::parse(&kv_quant_name)
        .with_context(|| format!("unknown --kv-quant '{kv_quant_name}' (f32|q8)"))?;
    let kv_block_tokens: usize = flag_or(flags, "kv-block-tokens", "16").parse()?;
    if kv_block_tokens == 0 {
        bail!("--kv-block-tokens must be positive");
    }
    // Speculative decoding defaults on for serving — lossless for
    // greedy AND sampled requests (rejection-sampling verification
    // replays each request's own sampler); per-request
    // `"speculation": false` opts out. 0 disables.
    let spec_draft_len: usize = flag_or(flags, "spec-draft-len", "4").parse()?;
    let spec_drafter_name = flag_or(flags, "spec-drafter", "ngram");
    let spec_drafter = itq3s::spec::DrafterKind::parse(&spec_drafter_name)
        .with_context(|| format!("unknown --spec-drafter '{spec_drafter_name}' (ngram|self)"))?;
    // Server-side deadline cap applied to every request (clients may
    // only tighten it with `deadline_ms`). 0 = no server default.
    let request_timeout_ms: u64 = flag_or(flags, "request-timeout-ms", "0").parse()?;
    let max_queue_depth: usize = flag_or(flags, "max-queue-depth", "256").parse()?;
    if max_queue_depth == 0 {
        bail!("--max-queue-depth must be positive");
    }
    // Per-round prefill-token ceiling per replica (0 = unbounded): see
    // CoordinatorConfig::prefill_round_budget.
    let prefill_round_budget: usize = flag_or(flags, "prefill-round-budget", "0").parse()?;
    // Sampled logit-drift shadow scoring (0 = off) and its warning
    // threshold in nats of KL: see CoordinatorConfig::audit_sample_rate.
    let audit_sample_rate: f64 = flag_or(flags, "audit-sample-rate", "0").parse()?;
    if !(0.0..=1.0).contains(&audit_sample_rate) {
        bail!("--audit-sample-rate must be in [0, 1]");
    }
    let audit_drift_warn: f64 = flag_or(flags, "audit-drift-warn", "0.05").parse()?;
    // Refuse to serve a corrupted artifact: static weight audit before
    // binding the socket (the `audit` op re-checks live on demand).
    // All replicas load the same file, so auditing one engine suffices.
    let report = engines[0].audit_weights();
    if !report.ok() {
        eprint!("{}", report.render_table());
        bail!(
            "refusing to serve {}: weight audit failed ([{}] violate the Theorem-2 bound)",
            model.display(),
            report.violations().join(", ")
        );
    }
    let cfg = itq3s::coordinator::CoordinatorConfig {
        max_batch: flag_or(flags, "max-batch", "8").parse()?,
        kv_budget_bytes: flag_or(flags, "kv-budget", "268435456").parse()?,
        kv_block_tokens,
        kv_quant,
        spec_draft_len,
        spec_drafter,
        request_timeout_ms: (request_timeout_ms > 0).then_some(request_timeout_ms),
        max_queue_depth,
        prefill_round_budget,
        audit_sample_rate,
        audit_drift_warn,
        ..Default::default()
    };
    println!(
        "serving {} on {addr} [{engine} x{replicas}] (kv: {} budget, {}-token blocks, {}; spec: {}; kernels: {})",
        model.display(),
        itq3s::util::human_bytes(cfg.kv_budget_bytes as u64),
        cfg.kv_block_tokens,
        kv_quant_name,
        if spec_draft_len == 0 {
            "off".to_string()
        } else {
            format!("{spec_drafter_name} x{spec_draft_len}")
        },
        itq3s::quant::simd::active_tier().name(),
    );
    itq3s::server::run_replicated(&addr, engines, cfg)
}

fn e2e(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = flag_or(flags, "artifacts", "artifacts");
    itq3s::bench::tables::e2e(&artifacts)
}
