//! Paged KV-cache integration: bit-identity against the dense cache,
//! copy-on-write divergence, prefix-cache reuse, Q8 error bounds, and
//! the §7.3 acceptance claim — under the same byte budget, paged
//! admission beats the old worst-case reservation bound on a
//! shared-prefix workload.

mod common;

use itq3s::coordinator::sampler::argmax;
use itq3s::coordinator::{kvpool, Coordinator, CoordinatorConfig, Event, FinishReason, GenRequest};
use itq3s::kvpaged::{BlockPool, KvQuant, PagedKvPool};
use itq3s::model::native::Engine;
use itq3s::model::{DenseModel, KvCache, KvStore, ModelConfig, NativeEngine};

fn engine(seed: u64) -> NativeEngine {
    NativeEngine::dense(DenseModel::random(&ModelConfig::test(), seed, Some(5.0)))
}

/// Greedy prefill + decode through any KvStore; returns per-step logits.
fn greedy_run(
    eng: &NativeEngine,
    store: &mut dyn itq3s::model::KvStore,
    prompt: &[u32],
    steps: usize,
) -> Vec<Vec<f32>> {
    let prefill_logits = eng.prefill(store, prompt);
    let mut out = Vec::with_capacity(steps + 1);
    out.push(prefill_logits.row(prompt.len() - 1).to_vec());
    let mut tok = argmax(out.last().unwrap());
    for _ in 0..steps {
        let logits = eng.decode_step(store, tok);
        tok = argmax(&logits);
        out.push(logits);
    }
    out
}

#[test]
fn paged_f32_greedy_decode_is_bit_identical_to_dense() {
    // Acceptance: across block sizes, every logit of a greedy run
    // through the paged f32 store equals the dense-cache run exactly.
    let cfg = ModelConfig::test();
    for &bt in &[4usize, 16, 64] {
        for seed in [7u64, 8] {
            let eng = engine(seed);
            let prompt: Vec<u32> = (0..13).map(|i| (i * 19 + seed as u32) % 256).collect();

            let mut dense = KvCache::new(&cfg);
            let want = greedy_run(&eng, &mut dense, &prompt, 10);

            let mut pool = PagedKvPool::new(&cfg, bt, KvQuant::F32, 64 << 20);
            let id = pool.create_seq();
            let got = greedy_run(&eng, &mut pool.seq_view(id), &prompt, 10);

            assert_eq!(want.len(), got.len());
            for (step, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w, g, "bt={bt} seed={seed} step={step} diverged");
            }
            pool.release_seq(id);
            assert_eq!(pool.in_use_blocks(), 0);
        }
    }
}

#[test]
fn cow_fork_divergence_matches_unshared_runs() {
    // Two sequences fork from a shared prefix, then continue with
    // different tokens. Each continuation must be bit-identical to a
    // fresh, unshared sequence fed the same tokens — proving the fork
    // isolates writes (COW) without disturbing shared state.
    let cfg = ModelConfig::test();
    let eng = engine(3);
    let prompt: Vec<u32> = (0..10).map(|i| i * 11 % 256).collect(); // 10 % 4 != 0: shared tail
    let cont_a = [50u32, 51, 52];
    let cont_b = [120u32, 121, 122];

    let mut pool = PagedKvPool::new(&cfg, 4, KvQuant::F32, 64 << 20);
    let a = pool.create_seq();
    eng.prefill(&mut pool.seq_view(a), &prompt);
    let b = pool.fork_seq(a);

    // Interleave the two continuations to stress isolation.
    let mut la = Vec::new();
    let mut lb = Vec::new();
    for i in 0..cont_a.len() {
        la.push(eng.decode_step(&mut pool.seq_view(a), cont_a[i]));
        lb.push(eng.decode_step(&mut pool.seq_view(b), cont_b[i]));
    }
    assert!(pool.cow_forks() >= 1, "appending into the shared tail must fork");

    // References: unshared sequences on fresh pools.
    for (cont, got) in [(&cont_a, &la), (&cont_b, &lb)] {
        let mut refpool = PagedKvPool::new(&cfg, 4, KvQuant::F32, 64 << 20);
        let r = refpool.create_seq();
        eng.prefill(&mut refpool.seq_view(r), &prompt);
        for (i, &t) in cont.iter().enumerate() {
            let want = eng.decode_step(&mut refpool.seq_view(r), t);
            assert_eq!(&want, &got[i], "continuation diverged at step {i}");
        }
    }
    pool.release_seq(a);
    pool.release_seq(b);
    assert_eq!(pool.in_use_blocks(), 0);
}

#[test]
fn q8_kv_decode_stays_within_error_bound() {
    // Teacher-forced run: identical token stream through a dense f32
    // cache and a paged Q8 store; final logits must stay within a tight
    // relative-L2 bound (per-row Q8 KV error is sub-1%; attention mixes
    // it down further).
    let cfg = ModelConfig::test();
    let eng = engine(11);
    let prompt: Vec<u32> = (0..12).map(|i| (i * 7 + 3) % 256).collect();
    let forced = [9u32, 200, 33, 71, 154, 18];

    let mut dense = KvCache::new(&cfg);
    eng.prefill(&mut dense, &prompt);
    let mut pool = PagedKvPool::new(&cfg, 16, KvQuant::Q8, 64 << 20);
    let id = pool.create_seq();
    eng.prefill(&mut pool.seq_view(id), &prompt);

    let mut want = Vec::new();
    let mut got = Vec::new();
    for &t in &forced {
        want = eng.decode_step(&mut dense, t);
        got = eng.decode_step(&mut pool.seq_view(id), t);
    }
    let rel = itq3s::util::stats::rel_l2_err(&want, &got);
    assert!(rel < 0.05, "q8 KV logits rel-L2 {rel}");
}

#[test]
fn paged_q8_kv_rows_obey_the_q8_error_bound() {
    // The PR-2 test gap: Q8 KV accuracy was only asserted end-to-end
    // with a magic tolerance. Here the real engine drives a Q8 paged
    // store through a tee that records every f32 row it writes; every
    // row read back must sit within the *deterministic* per-row Q8
    // bound from quant/error.rs — and the decode logits must stay
    // within the established relative budget of a dense-f32-cache run.
    let cfg = ModelConfig::test();
    let eng = engine(13);
    let prompt: Vec<u32> = (0..11).map(|i| (i * 13 + 5) % 256).collect();
    let forced = [17u32, 90, 211, 44, 133];

    // Dense f32 reference run.
    let mut dense = KvCache::new(&cfg);
    eng.prefill(&mut dense, &prompt);
    let mut want = Vec::new();
    for &t in &forced {
        want = eng.decode_step(&mut dense, t);
    }

    // Q8 paged run, with every engine write recorded in a dense shadow.
    let mut pool = PagedKvPool::new(&cfg, 4, KvQuant::Q8, 64 << 20);
    let id = pool.create_seq();
    let mut got = Vec::new();
    let shadow = {
        let mut view = pool.seq_view(id);
        let mut tee = common::TeeStore::new(&mut view, &cfg);
        eng.prefill(&mut tee, &prompt);
        for &t in &forced {
            got = eng.decode_step(&mut tee, t);
        }
        tee.shadow
    };

    // (a) Row-level: every stored K and V row is within the Q8 bound of
    // the exact row the engine wrote (f32-rounded scale ⇒ ulp slack).
    let stored = prompt.len() + forced.len(); // every fed token wrote KV
    let mut view = pool.seq_view(id);
    for layer in 0..cfg.n_layers {
        for pos in 0..stored {
            let wk = shadow.k_at(layer, pos).to_vec();
            let wv = shadow.v_at(layer, pos).to_vec();
            let rk = view.k_at(layer, pos).to_vec();
            let rv = view.v_at(layer, pos).to_vec();
            for (written, read) in [(&wk, &rk), (&wv, &rv)] {
                let err_sq: f64 = written
                    .iter()
                    .zip(read)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                let bound =
                    itq3s::quant::error::q8_row_l2_bound(written) * (1.0 + 1e-5) + 1e-9;
                assert!(
                    err_sq.sqrt() <= bound,
                    "layer {layer} pos {pos}: row err {} > Q8 bound {bound}",
                    err_sq.sqrt()
                );
            }
        }
    }

    // (b) Logits-level: within the PR-2 relative budget of dense f32.
    let rel = itq3s::util::stats::rel_l2_err(&want, &got);
    assert!(rel < 0.05, "q8 KV logits rel-L2 {rel}");
}

#[test]
fn truncate_after_cow_fork_leaks_zero_blocks() {
    // Rollback audit: fork a sequence mid-block (shared tail), let the
    // fork write (COW), roll the fork back, and account for every
    // block. The shared original must keep its content; releasing
    // everything must drain the pool to its starting free count.
    let cfg = ModelConfig::test();
    let eng = engine(19);
    let mut pool = PagedKvPool::new(&cfg, 4, KvQuant::F32, 64 << 20);
    let baseline_in_use = pool.in_use_blocks();
    assert_eq!(baseline_in_use, 0);

    let prompt: Vec<u32> = (0..10).map(|i| (i * 3 + 1) % 256).collect(); // 10 % 4 != 0
    let a = pool.create_seq();
    eng.prefill(&mut pool.seq_view(a), &prompt);
    let b = pool.fork_seq(a);

    // The fork extends into the shared tail block (COW) and beyond.
    for &t in &[70u32, 71, 72, 73, 74] {
        eng.decode_step(&mut pool.seq_view(b), t);
    }
    assert!(pool.cow_forks() >= 1, "shared-tail append must have forked");
    let before_rollback = pool.in_use_blocks();

    // Roll the fork all the way back to the shared prompt length.
    pool.truncate_seq(b, prompt.len());
    assert!(
        pool.in_use_blocks() < before_rollback,
        "rollback must release the fork's private tail blocks"
    );

    // The original's state is untouched: decoding from `a` equals a
    // fresh unshared run, bit for bit.
    let cont = [90u32, 91];
    let mut la = Vec::new();
    for &t in &cont {
        la.push(eng.decode_step(&mut pool.seq_view(a), t));
    }
    let mut refpool = PagedKvPool::new(&cfg, 4, KvQuant::F32, 64 << 20);
    let r = refpool.create_seq();
    eng.prefill(&mut refpool.seq_view(r), &prompt);
    for (i, &t) in cont.iter().enumerate() {
        let want = eng.decode_step(&mut refpool.seq_view(r), t);
        assert_eq!(&want, &la[i], "original diverged after fork rollback at step {i}");
    }

    pool.release_seq(a);
    pool.release_seq(b);
    pool.clear_prefix_cache();
    assert_eq!(pool.in_use_blocks(), baseline_in_use, "block leak after rollback");
}

#[test]
fn rollback_heavy_spec_run_returns_pool_to_baseline() {
    // Adversarial drafts force a rejection (and so a KV rollback) every
    // single round; after the run the pool must hold exactly what a
    // vanilla run would — and releasing the sequence must drain it.
    struct WrongDrafter;
    impl itq3s::spec::Drafter for WrongDrafter {
        fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
            // Guess tokens that shift the last token by odd offsets —
            // the verify argmax may coincide on the first, but runs of
            // eight will reject quickly and trigger deep rollbacks.
            let last = *history.last().unwrap_or(&0);
            (0..k as u32).map(|i| (last + 2 * i + 1) % 256).collect()
        }
        fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
        fn name(&self) -> &'static str {
            "wrong"
        }
    }

    let cfg = ModelConfig::test();
    let eng = engine(21);
    let prompt: Vec<u32> = (0..9).map(|i| (i * 17 + 2) % 256).collect();
    for &quant in &[KvQuant::F32, KvQuant::Q8] {
        let mut pool = PagedKvPool::new(&cfg, 4, quant, 64 << 20);
        let id = pool.create_seq();
        let mut drafter = WrongDrafter;
        let mut pending = {
            let mut view = pool.seq_view(id);
            let l = eng.prefill(&mut view, &prompt);
            argmax(l.row(prompt.len() - 1))
        };
        let mut produced = 1usize;
        while produced < 16 {
            let drafts = {
                use itq3s::spec::Drafter;
                let k = 8usize.min(cfg.max_seq - pool.seq_len(id) - 1);
                drafter.draft(&prompt, k)
            };
            let o = itq3s::spec::spec_step(&eng, &mut pool.seq_view(id), pending, &drafts);
            produced += o.accepted + 1;
            pending = o.next;
        }
        // The store holds exactly the consumed tokens (prompt plus the
        // fed share of the produced stream) — no verify-pass residue —
        // and block accounting matches that length exactly.
        let len = pool.seq_len(id);
        assert_eq!(len, prompt.len() + produced - 1, "rejected spans must be trimmed");
        let expect_blocks = len.div_ceil(4);
        assert_eq!(pool.in_use_blocks(), expect_blocks, "quant={quant:?}");
        pool.release_seq(id);
        pool.clear_prefix_cache();
        assert_eq!(pool.in_use_blocks(), 0, "quant={quant:?}: leaked blocks");
    }
}

#[test]
fn prefix_cache_never_serves_a_truncated_span() {
    // Register a prefix that extends into decoded tokens, roll the
    // sequence back below the registered span, and prove the cache (a)
    // no longer serves the dropped blocks and (b) what it still serves
    // reproduces a fresh run bit for bit.
    let cfg = ModelConfig::test();
    let eng = engine(23);
    let bt = 4usize;
    let mut pool = PagedKvPool::new(&cfg, bt, KvQuant::F32, 64 << 20);
    let prompt: Vec<u32> = (0..8).map(|i| (i * 5 + 3) % 256).collect(); // 2 whole blocks

    let a = pool.create_seq();
    eng.prefill(&mut pool.seq_view(a), &prompt);
    // Teacher-force 8 more tokens and cache the now-16-token prefix.
    let forced = [60u32, 61, 62, 63, 64, 65, 66, 67];
    for &t in &forced {
        eng.decode_step(&mut pool.seq_view(a), t);
    }
    pool.cache_prefix(a); // 4 whole blocks registered
    let full: Vec<u32> = prompt.iter().chain(&forced).copied().collect();

    // Rollback into the third block: blocks 2 and 3 of the chain must
    // be invalidated, blocks 0 and 1 (wholly inside the kept prefix)
    // must survive.
    pool.truncate_seq(a, 10);
    let probe = pool.create_seq();
    let mapped = pool.map_cached_prefix(probe, &full);
    assert_eq!(mapped, 2 * bt, "only the kept whole blocks may be served");

    // What the cache serves is real KV state: continue the probe over
    // the mapped prefix and compare with an entirely fresh pool.
    let rest = &full[mapped..12];
    let got = {
        let mut view = pool.seq_view(probe);
        let l = eng.prefill(&mut view, rest);
        l.row(rest.len() - 1).to_vec()
    };
    let want = {
        // Chunked exactly like the probe's path (mapped 8-token prefix
        // + one continuation prefill), so the comparison is bit-exact.
        let mut fresh = PagedKvPool::new(&cfg, bt, KvQuant::F32, 64 << 20);
        let r = fresh.create_seq();
        eng.prefill(&mut fresh.seq_view(r), &full[..mapped]);
        let l = eng.prefill(&mut fresh.seq_view(r), rest);
        l.row(rest.len() - 1).to_vec()
    };
    assert_eq!(got, want, "served prefix must reproduce the fresh run exactly");

    pool.release_seq(probe);
    pool.release_seq(a);
    pool.clear_prefix_cache();
    assert_eq!(pool.in_use_blocks(), 0, "invalidation must not leak references");
}

#[test]
fn q8_pool_holds_about_4x_more_tokens_per_byte() {
    let cfg = ModelConfig::test();
    let budget = 1 << 20;
    let f = BlockPool::new(&cfg, 16, KvQuant::F32, budget);
    let q = BlockPool::new(&cfg, 16, KvQuant::Q8, budget);
    let ratio = q.capacity_blocks() as f64 / f.capacity_blocks() as f64;
    assert!(ratio > 3.5, "q8 capacity ratio {ratio}");
}

fn collect_done(rx: &std::sync::mpsc::Receiver<Event>) -> (usize, FinishReason) {
    for ev in rx.iter() {
        if let Event::Done { reason, gen_tokens, .. } = ev {
            return (gen_tokens, reason);
        }
    }
    panic!("stream ended without Done");
}

#[test]
fn prefix_cache_skips_reprefill_for_repeated_prompts() {
    // N identical prompts run one after another: every run after the
    // first must map the cached whole-block prefix instead of
    // re-prefilling it.
    let cfg = ModelConfig::test();
    let eng = NativeEngine::dense(DenseModel::random(&cfg, 5, None));
    let c = Coordinator::new(
        Box::new(eng),
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            kv_block_tokens: 4,
            kv_quant: KvQuant::F32,
            ..Default::default()
        },
    );
    let prompt = "the shared prefix of every request".to_string(); // 35 tokens with BOS
    let n = 4;
    for _ in 0..n {
        let rx = c.generate(GenRequest {
            prompt: prompt.clone(),
            max_new_tokens: 4,
            ..Default::default()
        });
        let (gen_tokens, reason) = collect_done(&rx);
        assert_eq!((gen_tokens, reason), (4, FinishReason::MaxTokens));
    }
    let stats = c.stats().unwrap();
    // 35 prompt tokens -> 8 whole blocks of 4 cached; runs 2..n map them.
    let reused = stats.get("prefix_reused_tokens").unwrap().as_u64().unwrap();
    assert!(reused >= ((n - 1) * 32) as u64, "reused={reused}");
    let ratio = stats.get("prefix_hit_ratio").unwrap().as_f64().unwrap();
    assert!(ratio > 0.3, "hit ratio {ratio}");
    c.shutdown();
}

#[test]
fn shared_prefix_batch_beats_worst_case_admission_bound() {
    // The §7.3 acceptance claim: same kv_budget_bytes, strictly more
    // concurrent sequences than the old worst-case byte reservation
    // would ever admit.
    let cfg = ModelConfig::test();
    let bt = 4usize;
    let unit = BlockPool::new(&cfg, bt, KvQuant::F32, 1).block_bytes();
    let budget = 18 * unit;
    let prompt = "a".repeat(40); // 41 tokens with BOS
    let worst = 41 + 16; // prompt + max_new of the long request
    let old_bound = kvpool::worst_case_bound(&cfg, budget, worst);
    assert_eq!(old_bound, 2, "test geometry: old policy admits only 2");

    let eng = NativeEngine::dense(DenseModel::random(&cfg, 5, None));
    let c = Coordinator::new(
        Box::new(eng),
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: budget,
            prefill_chunk: 8,
            kv_block_tokens: bt,
            kv_quant: KvQuant::F32,
            ..Default::default()
        },
    );
    // Long request first; wait for its first token so its prefix is
    // cached and it is still decoding (15 rounds left).
    let rx_long = c.generate(GenRequest {
        prompt: prompt.clone(),
        max_new_tokens: 16,
        ..Default::default()
    });
    let mut first_token_seen = false;
    for ev in rx_long.iter() {
        if matches!(ev, Event::Token { .. }) {
            first_token_seen = true;
            break;
        }
    }
    assert!(first_token_seen);
    // Three sharers: map 10 cached blocks each, then need ~1 fresh block.
    let followers: Vec<_> = (0..3)
        .map(|_| {
            c.generate(GenRequest {
                prompt: prompt.clone(),
                max_new_tokens: 4,
                ..Default::default()
            })
        })
        .collect();
    for rx in &followers {
        let (gen_tokens, reason) = collect_done(rx);
        assert_eq!((gen_tokens, reason), (4, FinishReason::MaxTokens));
    }
    let (gen_tokens, reason) = collect_done(&rx_long);
    assert_eq!((gen_tokens, reason), (16, FinishReason::MaxTokens));

    let stats = c.stats().unwrap();
    let occupancy = stats.get("batch_occupancy_max").unwrap().as_f64().unwrap();
    assert!(
        occupancy > old_bound as f64,
        "paged occupancy {occupancy} must exceed the worst-case bound {old_bound}"
    );
    let reused = stats.get("prefix_reused_tokens").unwrap().as_u64().unwrap();
    assert!(reused >= 3 * 40, "followers must share the cached prefix, reused={reused}");
    c.shutdown();
}
