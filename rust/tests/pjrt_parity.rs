//! Cross-layer integration: the PJRT engine (AOT-lowered JAX graph, with
//! the fused Pallas dequant kernel in-graph for the quantized artifact)
//! must agree with the native Rust engine on the same checkpoint.
//!
//! These tests need `make artifacts` to have run; they self-skip when the
//! artifacts directory is absent so `cargo test` works on a fresh clone.

use itq3s::model::native::Engine;
use itq3s::model::{KvCache, NativeEngine, QuantizedModel};
use std::path::Path;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() && p.join("model_fp32.iguf").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn fp32_artifact_matches_native_logits() {
    let Some(art) = artifacts() else { return };
    let ckpt = art.join("model_fp32.iguf");
    let dense = itq3s::gguf::load_dense(&ckpt).unwrap();
    let native = NativeEngine::dense(dense);
    let pjrt = itq3s::runtime::PjrtEngine::load(&ckpt, art).unwrap();

    let toks: Vec<u32> = itq3s::model::tokenizer::encode("the archive of the glass city");
    let mut c1 = KvCache::new(native.config());
    let mut c2 = KvCache::new(pjrt.config());
    let l1 = native.prefill(&mut c1, &toks);
    let l2 = pjrt.prefill(&mut c2, &toks);
    assert_eq!(l1.shape(), l2.shape());
    let rel = itq3s::util::stats::rel_l2_err(l1.data(), l2.data());
    assert!(rel < 1e-4, "fp32 parity rel={rel}");
}

#[test]
fn quantized_artifact_matches_native_quantized_engine() {
    let Some(art) = artifacts() else { return };
    // Quantize in-process with the Rust encoder; the PJRT path re-packs
    // the same bytes into plane arrays for the Pallas kernel.
    let dense = itq3s::gguf::load_dense(&art.join("model_fp32.iguf")).unwrap();
    let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
    let qm = QuantizedModel::quantize(&dense, fmt);
    let qpath = std::env::temp_dir().join("itq3s-parity.iguf");
    itq3s::gguf::save_quantized(&qm, &qpath).unwrap();

    let native = NativeEngine::quantized(qm);
    let pjrt = itq3s::runtime::PjrtEngine::load(&qpath, art).unwrap();

    let toks: Vec<u32> = itq3s::model::tokenizer::encode("quick update: rowan fixed the kiln");
    let mut c1 = KvCache::new(native.config());
    let mut c2 = KvCache::new(pjrt.config());
    let l1 = native.prefill(&mut c1, &toks);
    let l2 = pjrt.prefill(&mut c2, &toks);
    let rel = itq3s::util::stats::rel_l2_err(l1.data(), l2.data());
    // Same packed bytes, two independent decode+IFWHT+matmul
    // implementations (Rust scalar vs Pallas interpret): tight tolerance.
    assert!(rel < 1e-3, "itq3s parity rel={rel}");
}

#[test]
fn pjrt_decode_step_matches_prefill_row() {
    let Some(art) = artifacts() else { return };
    let ckpt = art.join("model_fp32.iguf");
    let pjrt = itq3s::runtime::PjrtEngine::load(&ckpt, art).unwrap();
    let toks: Vec<u32> = itq3s::model::tokenizer::encode("in the year");
    let mut c1 = KvCache::new(pjrt.config());
    let all = pjrt.prefill(&mut c1, &toks);
    let mut c2 = KvCache::new(pjrt.config());
    let mut last = Vec::new();
    for &t in &toks {
        last = pjrt.decode_step(&mut c2, t);
    }
    let rel = itq3s::util::stats::rel_l2_err(all.row(toks.len() - 1), &last);
    assert!(rel < 1e-5, "decode/prefill consistency rel={rel}");
}
