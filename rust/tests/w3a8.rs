//! End-to-end W3A8 acceptance tests: the integer (DP4A-analog) decode
//! path must track the f32 fused path through the whole transformer, on
//! the real trained checkpoint when `make artifacts` has run (the same
//! fixture `pjrt_parity.rs` uses) and on a random heavy-tailed model
//! otherwise.

mod common;

use itq3s::model::native::Engine;
use itq3s::model::{DenseModel, KvCache, NativeEngine, QuantizedModel};
use itq3s::quant::format_by_name;

/// The shared artifacts-or-random fixture from `common` (same seed the
/// suite has always used).
fn dense_fixture() -> DenseModel {
    common::dense_fixture_or_random(23)
}

#[test]
fn decode_logits_shift_under_budget_all_hot_formats() {
    let dense = dense_fixture();
    for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0"] {
        let fmt = format_by_name(name).unwrap();
        let e_int = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt.clone()));
        let e_f32 =
            NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt)).with_act_quant(false);
        let toks: Vec<u32> = itq3s::model::tokenizer::encode("the glass city");
        let mut c1 = KvCache::new(e_int.config());
        let mut c2 = KvCache::new(e_f32.config());
        let mut a = Vec::new();
        let mut b = Vec::new();
        for &t in &toks {
            a = e_int.decode_step(&mut c1, t);
            b = e_f32.decode_step(&mut c2, t);
        }
        let rel = itq3s::util::stats::rel_l2_err(&b, &a);
        assert!(rel < 1e-2, "{name}: W3A8 decode logits rel-L2 {rel}");
    }
}

#[test]
fn w3a8_decode_consistent_with_f32_prefill() {
    // Prefill runs the batched f32 MMQ path; decode runs the W3A8 MMVQ
    // path. Scoring the same tokens both ways must agree to within the
    // activation-quantization budget — the cross-path invariant the
    // coordinator relies on when it mixes chunked prefill with decode.
    let dense = dense_fixture();
    let fmt = format_by_name("itq3_s").unwrap();
    let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
    let toks: Vec<u32> = itq3s::model::tokenizer::encode("rowan fixed the kiln");

    let mut c1 = KvCache::new(eng.config());
    let prefill_logits = eng.prefill(&mut c1, &toks);

    let mut c2 = KvCache::new(eng.config());
    let mut last = Vec::new();
    for &t in &toks {
        last = eng.decode_step(&mut c2, t);
    }
    let rel = itq3s::util::stats::rel_l2_err(prefill_logits.row(toks.len() - 1), &last);
    assert!(rel < 2e-2, "prefill/decode cross-path rel-L2 {rel}");
}
