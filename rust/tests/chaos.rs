//! Deterministic chaos suite: scripted failpoint schedules driven
//! through the real coordinator and server, asserting the typed
//! wreckage — every request ends in exactly one terminal event, the KV
//! pool leaks nothing, and the worker keeps serving after injected
//! panics. Requires `--features failpoints`; without it this whole
//! binary compiles to nothing and cargo reports zero tests.
//!
//! The failpoint registry is process-global, so every test here takes
//! [`failpoint::exclusive`] for its whole body: armed *real* sites must
//! not bleed into each other (cargo runs integration binaries one at a
//! time, so only tests within this file race).
#![cfg(feature = "failpoints")]

mod common;

use itq3s::coordinator::{Coordinator, CoordinatorConfig, Event, FinishReason, GenRequest};
use itq3s::gguf::{IgufFile, TensorEntry};
use itq3s::server::{spawn_ephemeral, Client};
use itq3s::util::failpoint::{self, FailAction};
use itq3s::util::json::Json;

fn chaos_coordinator(max_batch: usize) -> Coordinator {
    Coordinator::new(
        Box::new(common::dense_engine(7)),
        CoordinatorConfig {
            max_batch,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            ..Default::default()
        },
    )
}

/// Drain a receiver and count terminal events (`Done` or `Error`).
fn terminals(rx: std::sync::mpsc::Receiver<Event>) -> usize {
    let mut n = 0;
    for ev in rx.iter() {
        if matches!(ev, Event::Done { .. } | Event::Error(_)) {
            n += 1;
        }
    }
    n
}

#[test]
fn scripted_chaos_schedule_recovers_and_leaks_nothing() {
    let _g = failpoint::exclusive();
    // The schedule: a prefill panic early, a decode panic a few rounds
    // in, a panic *while holding the engine scratch locks* (exercises
    // poison recovery in `Engine::reset`), and one block-allocation
    // failure. All one-shot windows, all hit by any 6-request workload
    // on a 4-slot batch — deterministic because the coordinator is a
    // single worker thread.
    failpoint::arm_at("engine.prefill", 2, FailAction::Panic);
    failpoint::arm_at("engine.decode", 3, FailAction::Panic);
    failpoint::arm_at("native.decode_locked", 1, FailAction::Panic);
    failpoint::arm_at("kvpaged.alloc", 5, FailAction::Error);

    let c = chaos_coordinator(4);
    let mut kept = Vec::new();
    for i in 0..4 {
        kept.push(c.generate(GenRequest {
            prompt: format!("shared prefix, request number {i}"),
            max_new_tokens: 6 + i,
            ..Default::default()
        }));
    }
    // One client that vanishes immediately...
    drop(c.generate(GenRequest {
        prompt: "shared prefix, abandoned".into(),
        max_new_tokens: 400,
        ..Default::default()
    }));
    // ...and one whose deadline cannot be met.
    kept.push(c.generate(GenRequest {
        prompt: "z".repeat(400),
        max_new_tokens: 500,
        deadline_ms: Some(1),
        ..Default::default()
    }));

    for (i, rx) in kept.into_iter().enumerate() {
        assert_eq!(terminals(rx), 1, "request {i}: exactly one terminal event");
    }

    // The worker survived every injected fault and still serves.
    let (_, done) = c.generate_collect(GenRequest {
        prompt: "after the storm".into(),
        max_new_tokens: 4,
        ..Default::default()
    });
    assert!(
        matches!(done, Some(Event::Done { reason: FinishReason::MaxTokens, .. })),
        "fresh request after recovery must complete normally: {done:?}"
    );

    // Leak audit: with every request resolved, dropping the cached
    // prefixes must leave zero blocks in use.
    c.clear_prefix_cache().unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(
        stats.get("kv_blocks_in_use").unwrap().as_u64(),
        Some(0),
        "resolved workload must not leak KV blocks"
    );
    assert!(
        stats.get("worker_restarts").unwrap().as_u64().unwrap() >= 1,
        "the injected panics must have restarted the worker"
    );
    assert!(stats.get("deadline_expired").unwrap().as_u64().unwrap() >= 1);
    assert!(stats.get("requests_cancelled").unwrap().as_u64().unwrap() >= 1);
    c.shutdown();
}

#[test]
fn decode_phase_dead_client_cancelled_within_rounds() {
    let _g = failpoint::exclusive();
    // Pace decode rounds so the client provably disconnects mid-decode
    // (on a tiny model whole requests otherwise finish between two
    // receiver operations).
    failpoint::arm_from("engine.decode", 1, FailAction::Sleep(15));

    let c = chaos_coordinator(2);
    let rx = c.generate(GenRequest {
        prompt: "about to be abandoned".into(),
        max_new_tokens: 400,
        ..Default::default()
    });
    // Read two streamed tokens — the sequence is decoding — then vanish.
    let mut seen = 0;
    for ev in rx.iter() {
        if matches!(ev, Event::Token { .. }) {
            seen += 1;
            if seen == 2 {
                break;
            }
        }
    }
    drop(rx);

    // The decode-round heartbeat probe cancels the abandoned sequence
    // within a round; a fresh request completes and the total token
    // spend stays far below the abandoned request's 400-token budget.
    let (_, done) = c.generate_collect(GenRequest {
        prompt: "alive".into(),
        max_new_tokens: 2,
        ..Default::default()
    });
    assert!(matches!(done, Some(Event::Done { .. })));
    let stats = c.stats().unwrap();
    assert!(stats.get("requests_cancelled").unwrap().as_u64().unwrap() >= 1);
    assert!(
        stats.get("gen_tokens").unwrap().as_u64().unwrap() <= 20,
        "abandoned request must not decode on toward max_tokens"
    );
    c.shutdown();
}

#[test]
fn engine_panic_dumps_flight_recorder_with_implicated_trace() {
    let _g = failpoint::exclusive();
    // The flight ring is process-global; start from a clean slate so
    // the dump below is exactly this test's story.
    itq3s::util::flight::clear();
    // Let prefill and one decode round run clean, then panic the
    // second decode round — the traced request is mid-generation.
    failpoint::arm_at("engine.decode", 2, FailAction::Panic);

    let c = chaos_coordinator(2);
    let rx = c.generate(GenRequest {
        prompt: "watched by the flight recorder".into(),
        max_new_tokens: 8,
        trace: true,
        ..Default::default()
    });
    assert_eq!(terminals(rx), 1, "the survivor is requeued and finishes");

    // The dump tells the crash story in order: round summaries naming
    // the active request precede the panic, and the restart event
    // names it implicated.
    let events = c.dump();
    let arr = events.as_arr().unwrap();
    let kind = |e: &Json| e.get("kind").unwrap().as_str().unwrap().to_string();
    let detail = |e: &Json| e.get("detail").unwrap().as_str().unwrap().to_string();
    let panic_pos = arr
        .iter()
        .position(|e| kind(e) == "panic")
        .expect("the injected panic must be recorded");
    let round_before = arr[..panic_pos]
        .iter()
        .rev()
        .find(|e| kind(e) == "round")
        .expect("round summaries must precede the panic");
    assert!(
        detail(round_before).contains("active=[1]"),
        "the round summary names the active request: {}",
        detail(round_before)
    );
    let restart = arr[panic_pos..]
        .iter()
        .find(|e| kind(e) == "restart")
        .expect("the restart must be recorded after the panic");
    assert!(
        detail(restart).contains("implicated=[1]"),
        "the restart names the implicated request: {}",
        detail(restart)
    );

    // The request's own timeline records the implication, and the
    // trace id matches the one the dump implicated.
    let timelines = c.trace(4).unwrap();
    let tl = timelines.as_arr().unwrap();
    assert_eq!(tl.len(), 1);
    assert_eq!(tl[0].get("id").unwrap().as_u64(), Some(1));
    assert_eq!(tl[0].get("reason").unwrap().as_str(), Some("max_tokens"));
    let evs = tl[0].get("events").unwrap().as_arr().unwrap();
    assert!(
        evs.iter().any(|e| e.get("what").unwrap().as_str() == Some("restart_implicated")),
        "the timeline must record the restart implication"
    );

    let stats = c.stats().unwrap();
    assert!(stats.get("worker_restarts").unwrap().as_u64().unwrap() >= 1);
    c.shutdown();
}

#[test]
fn replica_panic_restarts_one_replica_and_the_pool_keeps_serving() {
    let _g = failpoint::exclusive();
    itq3s::util::flight::clear();
    // Panic the second decode call in the process. Failpoint counters
    // are process-global and replica rounds run concurrently, so the
    // test does not know (or assert) WHICH replica draws the panic —
    // only that exactly one restart happens, it is replica-stamped,
    // and every request still resolves.
    failpoint::arm_at("engine.decode", 2, FailAction::Panic);

    let engines: Vec<Box<dyn itq3s::model::native::Engine>> = (0..2)
        .map(|_| Box::new(common::dense_engine(7)) as Box<dyn itq3s::model::native::Engine>)
        .collect();
    let c = Coordinator::new_replicated(
        engines,
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            c.generate(GenRequest {
                prompt: format!("replica chaos request {i}"),
                max_new_tokens: 6,
                ..Default::default()
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        assert_eq!(terminals(rx), 1, "request {i}: exactly one terminal event");
    }

    // The pool as a whole keeps serving after the restart.
    let (_, done) = c.generate_collect(GenRequest {
        prompt: "after the replica storm".into(),
        max_new_tokens: 4,
        ..Default::default()
    });
    assert!(
        matches!(done, Some(Event::Done { reason: FinishReason::MaxTokens, .. })),
        "fresh request after a replica restart must complete: {done:?}"
    );

    // Merged stats see the restart, and the per-replica breakdown
    // attributes it: restarts sum to the aggregate, and at least one
    // replica reports zero (the panic stayed in its blast radius).
    let stats = c.stats().unwrap();
    let merged_restarts = stats.get("worker_restarts").unwrap().as_u64().unwrap();
    assert!(merged_restarts >= 1, "the injected panic must restart a replica");
    assert_eq!(stats.get("replicas").unwrap().as_u64(), Some(2));
    let per = stats.get("per_replica").unwrap().as_arr().unwrap();
    assert_eq!(per.len(), 2);
    let per_restarts: Vec<u64> =
        per.iter().map(|p| p.get("worker_restarts").unwrap().as_u64().unwrap()).collect();
    assert_eq!(per_restarts.iter().sum::<u64>(), merged_restarts);
    assert!(
        per_restarts.iter().any(|&r| r == 0),
        "a panic in one replica must not restart the other: {per_restarts:?}"
    );

    // The flight recorder's restart record names its replica.
    let dump = c.dump();
    let restart = dump
        .as_arr()
        .unwrap()
        .iter()
        .find(|e| e.get("kind").unwrap().as_str() == Some("restart"))
        .expect("the restart must be recorded");
    let detail = restart.get("detail").unwrap().as_str().unwrap();
    assert!(detail.contains(" r="), "restart record is replica-stamped: {detail}");

    // Leak audit across both pools.
    c.clear_prefix_cache().unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("kv_blocks_in_use").unwrap().as_u64(), Some(0));
    c.shutdown();
}

#[test]
fn server_conn_error_surfaces_and_server_survives() {
    let _g = failpoint::exclusive();
    // The very first wire send in the server process fails (a client
    // whose socket died). That connection's handler exits with an
    // error; the server logs it, counts it, and keeps accepting.
    failpoint::arm_at("server.send", 1, FailAction::Error);

    let (addr, handle) = spawn_ephemeral(
        Box::new(common::dense_engine(7)),
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = addr.to_string();

    let mut a = Client::connect(&addr).unwrap();
    assert!(
        a.generate("doomed", 3).is_err(),
        "the injected send failure must kill this connection"
    );

    // The failed handler closes the socket *before* it reports the
    // error to the coordinator, so poll briefly instead of racing it.
    let mut b = Client::connect(&addr).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        b.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
        let stats = b.recv().unwrap();
        if stats.get("conn_errors").unwrap().as_u64().unwrap() >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "the dead connection was never counted under conn_errors"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let done = b.generate("still serving", 3).unwrap();
    assert_eq!(done.get("done"), Some(&Json::Bool(true)));
    b.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
    let _ = b.recv();
    handle.join().unwrap().unwrap();
}

#[test]
fn gguf_failpoints_surface_typed_errors() {
    let _g = failpoint::exclusive();
    let file = IgufFile {
        meta: Json::obj(vec![("kind", Json::str("chaos"))]),
        tensors: vec![
            TensorEntry::from_f32("a", 2, 2, &[1., 2., 3., 4.]),
            TensorEntry::from_f32("b", 1, 3, &[5., 6., 7.]),
        ],
    };
    let bytes = file.to_bytes();

    failpoint::arm_at("gguf.parse.header", 1, FailAction::Error);
    let err = IgufFile::parse(&bytes).expect_err("armed header site must fail");
    assert!(err.to_string().contains("failpoint"), "typed error names the site: {err}");
    IgufFile::parse(&bytes).expect("one-shot window passed; same bytes parse clean");

    failpoint::arm_at("gguf.parse.tensor", 1, FailAction::Error);
    let err = IgufFile::parse(&bytes).expect_err("armed tensor site must fail");
    assert!(err.to_string().contains("failpoint"));
    IgufFile::parse(&bytes).expect("tensor window passed");

    let dir = std::env::temp_dir().join("itq3s-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chaos.iguf");
    file.save(&path).unwrap();
    failpoint::arm_at("gguf.load.io", 1, FailAction::Error);
    let err = IgufFile::load(&path).expect_err("armed IO site must fail");
    assert!(err.to_string().contains("failpoint"));
    IgufFile::load(&path).expect("IO window passed; the file itself is fine");
}
