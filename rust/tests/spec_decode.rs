//! Speculative-decoding acceptance harness: draft-and-verify must be
//! **token-identical** to vanilla sequential `decode_step` decoding for
//! every (drafter, draft length, KV backend) combination — acceptance
//! logic changes latency, never outputs — and the verify pass itself
//! must be bit-identical to sequential decode on every backend.
//! Deterministic oracle/adversarial drafters pin the accept-all (bonus
//! token) and reject-all (rollback every round) extremes; the real
//! ngram/self drafters cover the mixed paths.
//!
//! Sampled speculation is held to the same bar, per mode:
//!
//! - point-mass drafters (the default): same-seed **token identity**
//!   with vanilla sampled decode across (temperature, top-k, top-p)
//!   compositions and every KV backend — the coupled-replay accept
//!   rule makes speculation sample-path identical, not merely
//!   distribution-preserving;
//! - spread (non-degenerate) proposals: a χ²-style check that the
//!   produced-token distribution matches the target's post-filter
//!   distribution (rejection + residual resampling is lossless even
//!   when the proposal is wrong), plus support-containment;
//! - rollback: sampled rejections release paged blocks exactly (leak
//!   audit on f32 and Q8 pools).

mod common;

use common::{dense_engine, prompt_tokens, quant_engine};
use itq3s::coordinator::sampler::{argmax, Sampler};
use itq3s::kvpaged::{KvQuant, PagedKvPool};
use itq3s::model::native::Engine;
use itq3s::model::{KvCache, KvStore, ModelConfig};
use itq3s::spec::{
    run_greedy, run_sampled, spec_step_sampled, DraftDist, Drafter, DrafterKind, NgramDrafter,
    SelfDraft, SpecRun,
};
use itq3s::util::XorShift;

/// KV backends the sweep runs each combination against.
#[derive(Clone, Copy, Debug)]
enum Backend {
    Dense,
    PagedF32(usize),
    PagedQ8(usize),
}

const BACKENDS: [Backend; 4] =
    [Backend::Dense, Backend::PagedF32(4), Backend::PagedF32(16), Backend::PagedQ8(4)];

/// Run `f` against a fresh store of the given backend; paged stores are
/// leak-audited on the way out.
fn with_store<R>(
    backend: Backend,
    cfg: &ModelConfig,
    f: impl FnOnce(&mut dyn KvStore) -> R,
) -> R {
    match backend {
        Backend::Dense => {
            let mut c = KvCache::new(cfg);
            f(&mut c)
        }
        Backend::PagedF32(bt) | Backend::PagedQ8(bt) => {
            let quant = match backend {
                Backend::PagedQ8(_) => KvQuant::Q8,
                _ => KvQuant::F32,
            };
            let mut p = PagedKvPool::new(cfg, bt, quant, 64 << 20);
            let id = p.create_seq();
            let r = f(&mut p.seq_view(id));
            p.release_seq(id);
            assert_eq!(p.in_use_blocks(), 0, "{backend:?}: leaked blocks");
            r
        }
    }
}

/// Vanilla greedy reference: first token from the prefill logits, then
/// one `decode_step` per token.
fn vanilla_greedy(eng: &dyn Engine, store: &mut dyn KvStore, prompt: &[u32], n: usize) -> Vec<u32> {
    let l = eng.prefill(store, prompt);
    let mut tok = argmax(l.row(prompt.len() - 1));
    let mut out = vec![tok];
    while out.len() < n {
        let logits = eng.decode_step(store, tok);
        tok = argmax(&logits);
        out.push(tok);
    }
    out
}

/// Drafts the true greedy continuation (verification accepts
/// everything — pins the bonus-token path).
struct OracleDrafter {
    script: Vec<u32>,
    prompt_len: usize,
}

impl Drafter for OracleDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        let produced = history.len() - self.prompt_len;
        let end = (produced + k).min(self.script.len());
        self.script.get(produced..end).map(|s| s.to_vec()).unwrap_or_default()
    }
    fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
    fn name(&self) -> &'static str {
        "oracle"
    }
}

/// Drafts the true continuation shifted by one — the first draft is
/// always rejected (pins the full-rollback path: one true token per
/// verify pass, every pass truncates).
struct AntiOracleDrafter {
    script: Vec<u32>,
    prompt_len: usize,
}

impl Drafter for AntiOracleDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        let produced = history.len() - self.prompt_len;
        let end = (produced + k).min(self.script.len());
        self.script
            .get(produced..end)
            .map(|s| s.iter().map(|&t| (t + 1) % 256).collect())
            .unwrap_or_default()
    }
    fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
    fn name(&self) -> &'static str {
        "anti"
    }
}

/// A repetitive prompt (gives the ngram drafter something to find) —
/// distinct from `prompt_tokens`, which is the adversarial one.
fn repetitive_prompt(len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| 30 + (i % 3)).collect()
}

#[test]
fn spec_decode_token_identical_for_every_drafter_length_backend() {
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 51);
    let n = 18;
    for prompt in [repetitive_prompt(12), prompt_tokens(11, 9)] {
        for backend in BACKENDS {
            let want = with_store(backend, &cfg, |s| vanilla_greedy(&eng, s, &prompt, n));
            for k in [1usize, 2, 4, 8] {
                // Real drafters plus the two deterministic extremes.
                let mut drafters: Vec<(&str, Box<dyn Drafter>)> = vec![
                    ("ngram", DrafterKind::Ngram.build()),
                    ("self", DrafterKind::SelfDraft.build()),
                    (
                        "oracle",
                        Box::new(OracleDrafter {
                            script: want.clone(),
                            prompt_len: prompt.len(),
                        }),
                    ),
                    (
                        "anti",
                        Box::new(AntiOracleDrafter {
                            script: want.clone(),
                            prompt_len: prompt.len(),
                        }),
                    ),
                ];
                for (name, drafter) in drafters.iter_mut() {
                    let run: SpecRun = with_store(backend, &cfg, |s| {
                        run_greedy(&eng, s, &prompt, n, drafter.as_mut(), k)
                    });
                    assert_eq!(
                        run.tokens, want,
                        "{name} k={k} {backend:?}: speculative tokens diverged"
                    );
                    match *name {
                        "oracle" => {
                            assert!(run.drafted > 0);
                            assert_eq!(
                                run.accepted, run.drafted,
                                "oracle drafts must all be accepted"
                            );
                        }
                        "anti" => {
                            assert!(run.drafted > 0);
                            assert_eq!(run.accepted, 0, "anti-oracle drafts must all be rejected");
                        }
                        _ => {}
                    }
                }
            }
        }
    }
}

#[test]
fn spec_decode_token_identical_on_dense_weights() {
    // The dense (unquantized) engine takes the non-GEMM route through
    // the same verify pass; one smaller sweep pins it.
    let cfg = ModelConfig::test();
    let eng = dense_engine(53);
    let prompt = repetitive_prompt(10);
    for backend in [Backend::Dense, Backend::PagedF32(4)] {
        let want = with_store(backend, &cfg, |s| vanilla_greedy(&eng, s, &prompt, 14));
        for k in [2usize, 5] {
            let mut ngram = NgramDrafter::default();
            let mut selfd = SelfDraft::default();
            let drafters: [&mut dyn Drafter; 2] = [&mut ngram, &mut selfd];
            for d in drafters {
                let run =
                    with_store(backend, &cfg, |s| run_greedy(&eng, s, &prompt, 14, d, k));
                assert_eq!(run.tokens, want, "k={k} {backend:?} dense-weight run diverged");
            }
        }
    }
}

#[test]
fn score_tokens_bitwise_matches_sequential_on_every_backend() {
    // The verify pass's own contract, exercised through the paged
    // stores (the engine-level dense check lives in model/native.rs).
    let cfg = ModelConfig::test();
    for fmt in ["itq3_s", "q8_0"] {
        let eng = quant_engine(fmt, 57);
        let prompt = prompt_tokens(9, 3);
        let feed = [7u32, 19, 4, 2, 250];
        for backend in BACKENDS {
            let want = with_store(backend, &cfg, |s| {
                eng.prefill(s, &prompt);
                feed.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
            });
            let got = with_store(backend, &cfg, |s| {
                eng.prefill(s, &prompt);
                eng.score_tokens(s, &feed)
            });
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w, g, "{fmt} {backend:?}: position {i} logits diverged");
            }
        }
    }
}

/// Vanilla sampled reference: first token from the prefill logits
/// through `sampler`, then one `decode_step` + sample per token.
fn vanilla_sampled(
    eng: &dyn Engine,
    store: &mut dyn KvStore,
    prompt: &[u32],
    n: usize,
    sampler: &mut Sampler,
) -> Vec<u32> {
    let l = eng.prefill(store, prompt);
    let mut tok = sampler.sample(l.row(prompt.len() - 1));
    let mut out = vec![tok];
    while out.len() < n {
        let logits = eng.decode_step(store, tok);
        tok = sampler.sample(&logits);
        out.push(tok);
    }
    out
}

#[test]
fn sampled_spec_token_identical_to_vanilla_for_every_filter_and_backend() {
    // Point-mass drafters: same-seed sampled speculation must stream
    // exactly the tokens vanilla sampling streams, for every filter
    // composition (plain temperature, top-k, top-p, both) on every KV
    // backend, whatever the drafter guesses.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 51);
    let n = 16;
    let configs: [(f32, Option<usize>, Option<f32>); 4] = [
        (0.7, None, None),
        (0.9, Some(8), None),
        (0.8, None, Some(0.85)),
        (1.1, Some(12), Some(0.7)),
    ];
    let prompt = repetitive_prompt(12);
    for (temperature, top_k, top_p) in configs {
        let mk = || Sampler::new(temperature, 1234).with_top_k(top_k).with_top_p(top_p);
        for backend in BACKENDS {
            let want = with_store(backend, &cfg, |s| {
                vanilla_sampled(&eng, s, &prompt, n, &mut mk())
            });
            for k in [1usize, 2, 4] {
                let mut drafters: Vec<(&str, Box<dyn Drafter>)> = vec![
                    ("ngram", DrafterKind::Ngram.build()),
                    ("self", DrafterKind::SelfDraft.build()),
                ];
                for (name, drafter) in drafters.iter_mut() {
                    let run = with_store(backend, &cfg, |s| {
                        run_sampled(&eng, s, &prompt, n, drafter.as_mut(), k, &mut mk())
                    });
                    assert_eq!(
                        run.tokens, want,
                        "t={temperature} k={top_k:?} p={top_p:?} {name} draft_len={k} \
                         {backend:?}: sampled speculation diverged from vanilla"
                    );
                }
            }
        }
    }
}

#[test]
fn sampled_spec_spread_drafts_preserve_the_target_distribution() {
    // A genuinely spread (non-point-mass) proposal takes the
    // accept-ratio + residual-resampling branch. Over many
    // independently-seeded single-draft rounds the token produced at
    // the drafted position must (a) never leave the target's
    // post-filter support and (b) follow the target distribution — the
    // speculative-sampling losslessness theorem, checked χ²-style.
    // Everything is seeded, so the statistic is deterministic.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 61);
    let prompt = prompt_tokens(8, 2);
    let pending = 7u32;
    let mk = |seed: u64| Sampler::new(0.8, seed).with_top_k(Some(8));

    // Target distribution at the drafted position, from the vanilla
    // logits (score_tokens is bit-identical to decode_step, so the
    // verify pass sees these exact logits).
    let mut probe = KvCache::new(&cfg);
    eng.prefill(&mut probe, &prompt);
    let logits = eng.decode_step(&mut probe, pending);
    let target = mk(0).dist(&logits);
    let support: Vec<(u32, f64)> = target.support().to_vec();
    assert_eq!(support.len(), 8, "top-8 support expected");

    // Proposal: spread over the target's two most likely tokens plus
    // two tokens outside the support (always-rejected mass).
    let outside: Vec<u32> = (0..256u32).filter(|t| target.prob_of(*t) == 0.0).take(2).collect();
    let q = vec![
        (support[0].0, 0.4f64),
        (support[1].0, 0.3),
        (outside[0], 0.2),
        (outside[1], 0.1),
    ];

    let n_trials = 1200usize;
    let mut counts: std::collections::HashMap<u32, usize> = Default::default();
    let (mut accepts, mut resamples) = (0usize, 0usize);
    let mut store = KvCache::new(&cfg);
    eng.prefill(&mut store, &prompt);
    let base = store.len();
    let mut proposal_rng = XorShift::new(999);
    for trial in 0..n_trials {
        // The theorem requires the proposed token be drawn from q.
        let mut u = proposal_rng.next_f64();
        let mut tok = q[q.len() - 1].0;
        for &(t, p) in &q {
            if u < p {
                tok = t;
                break;
            }
            u -= p;
        }
        let d = DraftDist { token: tok, probs: q.clone() };
        let mut s = mk(1000 + trial as u64);
        let o = spec_step_sampled(&eng, &mut store, pending, &[d], &mut s);
        let produced = if o.accepted == 1 {
            accepts += 1;
            tok
        } else {
            o.next
        };
        if o.resampled {
            resamples += 1;
        }
        *counts.entry(produced).or_insert(0) += 1;
        store.truncate(base); // reset for the next independent trial
    }
    assert!(
        accepts > 0 && resamples > 0,
        "both branches must fire (accepts={accepts}, resamples={resamples})"
    );
    // (a) support containment.
    let total_in: usize = support.iter().map(|&(t, _)| *counts.get(&t).unwrap_or(&0)).sum();
    assert_eq!(total_in, n_trials, "produced tokens left the post-filter support");
    // (b) χ² against the target, pooling thin cells (exp < 15) so no
    // single near-empty tail cell dominates the statistic.
    let (mut chi2, mut pooled_exp, mut pooled_obs) = (0.0f64, 0.0f64, 0.0f64);
    let mut cells = 0usize;
    for &(t, p) in &support {
        let exp = p * n_trials as f64;
        let obs = *counts.get(&t).unwrap_or(&0) as f64;
        if exp < 15.0 {
            pooled_exp += exp;
            pooled_obs += obs;
        } else {
            chi2 += (obs - exp) * (obs - exp) / exp;
            cells += 1;
        }
    }
    if pooled_exp > 0.0 {
        chi2 += (pooled_obs - pooled_exp) * (pooled_obs - pooled_exp) / pooled_exp;
        cells += 1;
    }
    // Threshold derived from the cell count the pooling actually
    // produced, not the nominal 8-cell support: dof = cells - 1, and
    // the bound is χ²_dof(0.999) (upper 0.1% quantile) times a 1.45
    // safety factor. The factor preserves the margin the historical
    // fixed bound encoded (35 against χ²₇(0.999) ≈ 24.32 ≈ 1.44×) so
    // seed-luck in the deterministic statistic keeps the same headroom
    // at every dof, while a broken sampler (skipped residual
    // restriction or resampling) still lands orders of magnitude above.
    const CHI2_999: [f64; 7] = [10.828, 13.816, 16.266, 18.467, 20.515, 22.458, 24.322];
    let dof = cells.saturating_sub(1).clamp(1, CHI2_999.len());
    let threshold = 1.45 * CHI2_999[dof - 1];
    assert!(
        chi2 < threshold,
        "chi2={chi2} >= {threshold} (dof={dof}, counts={counts:?})"
    );
}

/// Always proposes `tok` — under sampling this is rejected most rounds,
/// hammering the rollback path.
struct ConstDrafter {
    tok: u32,
}

impl Drafter for ConstDrafter {
    fn draft(&mut self, _history: &[u32], k: usize) -> Vec<u32> {
        vec![self.tok; k]
    }
    fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
    fn name(&self) -> &'static str {
        "const"
    }
}

#[test]
fn sampled_rejection_rollback_leaks_no_paged_blocks() {
    // Rejection-heavy sampled speculation on the paged pools: every
    // rolled-back suffix must return its tail blocks, leaving exactly
    // the blocks the surviving tokens occupy — and nothing after
    // release.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 63);
    let prompt = prompt_tokens(9, 4);
    let n = 14;
    for (quant, bt) in [(KvQuant::F32, 4usize), (KvQuant::Q8, 4), (KvQuant::F32, 16)] {
        let mut pool = PagedKvPool::new(&cfg, bt, quant, 64 << 20);
        let id = pool.create_seq();
        let mut drafter = ConstDrafter { tok: 201 };
        let mut sampler = Sampler::new(0.9, 31).with_top_k(Some(4));
        let run = run_sampled(
            &eng,
            &mut pool.seq_view(id),
            &prompt,
            n,
            &mut drafter,
            4,
            &mut sampler,
        );
        assert_eq!(run.tokens.len(), n);
        assert!(run.drafted > 0, "const drafter always proposes");
        // The store holds prompt + everything fed; the pool must hold
        // exactly the blocks for that many tokens — a leaked
        // speculative block would show up here.
        let held = prompt.len() + run.tokens.len() - 1;
        let expected_blocks = held.div_ceil(bt);
        assert_eq!(
            pool.in_use_blocks(),
            expected_blocks,
            "{quant:?} bt={bt}: rollback leaked blocks"
        );
        pool.release_seq(id);
        assert_eq!(pool.in_use_blocks(), 0, "{quant:?} bt={bt}: release leaked blocks");
    }
}

#[test]
fn truncate_then_continue_matches_never_speculated_run() {
    // Rollback leaves no ghost state: write a junk span through
    // score_tokens, truncate it away, continue decoding — the
    // continuation must equal a run that never speculated, bit for
    // bit, on every backend.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 59);
    let prompt = prompt_tokens(10, 5);
    let junk = [201u32, 202, 203, 204];
    let cont = [17u32, 71];
    for backend in BACKENDS {
        let want = with_store(backend, &cfg, |s| {
            eng.prefill(s, &prompt);
            cont.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
        });
        let got = with_store(backend, &cfg, |s| {
            eng.prefill(s, &prompt);
            let base = s.len();
            eng.score_tokens(s, &junk);
            s.truncate(base);
            cont.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
        });
        assert_eq!(want, got, "{backend:?}: rollback left ghost state");
    }
}
