//! Speculative-decoding acceptance harness: greedy draft-and-verify
//! must be **token-identical** to vanilla sequential `decode_step`
//! decoding for every (drafter, draft length, KV backend) combination —
//! acceptance logic changes latency, never outputs — and the verify
//! pass itself must be bit-identical to sequential decode on every
//! backend. Deterministic oracle/adversarial drafters pin the
//! accept-all (bonus token) and reject-all (rollback every round)
//! extremes; the real ngram/self drafters cover the mixed paths.

mod common;

use common::{dense_engine, prompt_tokens, quant_engine};
use itq3s::coordinator::sampler::argmax;
use itq3s::kvpaged::{KvQuant, PagedKvPool};
use itq3s::model::native::Engine;
use itq3s::model::{KvCache, KvStore, ModelConfig};
use itq3s::spec::{run_greedy, Drafter, DrafterKind, NgramDrafter, SelfDraft, SpecRun};

/// KV backends the sweep runs each combination against.
#[derive(Clone, Copy, Debug)]
enum Backend {
    Dense,
    PagedF32(usize),
    PagedQ8(usize),
}

const BACKENDS: [Backend; 4] =
    [Backend::Dense, Backend::PagedF32(4), Backend::PagedF32(16), Backend::PagedQ8(4)];

/// Run `f` against a fresh store of the given backend; paged stores are
/// leak-audited on the way out.
fn with_store<R>(
    backend: Backend,
    cfg: &ModelConfig,
    f: impl FnOnce(&mut dyn KvStore) -> R,
) -> R {
    match backend {
        Backend::Dense => {
            let mut c = KvCache::new(cfg);
            f(&mut c)
        }
        Backend::PagedF32(bt) | Backend::PagedQ8(bt) => {
            let quant = match backend {
                Backend::PagedQ8(_) => KvQuant::Q8,
                _ => KvQuant::F32,
            };
            let mut p = PagedKvPool::new(cfg, bt, quant, 64 << 20);
            let id = p.create_seq();
            let r = f(&mut p.seq_view(id));
            p.release_seq(id);
            assert_eq!(p.in_use_blocks(), 0, "{backend:?}: leaked blocks");
            r
        }
    }
}

/// Vanilla greedy reference: first token from the prefill logits, then
/// one `decode_step` per token.
fn vanilla_greedy(eng: &dyn Engine, store: &mut dyn KvStore, prompt: &[u32], n: usize) -> Vec<u32> {
    let l = eng.prefill(store, prompt);
    let mut tok = argmax(l.row(prompt.len() - 1));
    let mut out = vec![tok];
    while out.len() < n {
        let logits = eng.decode_step(store, tok);
        tok = argmax(&logits);
        out.push(tok);
    }
    out
}

/// Drafts the true greedy continuation (verification accepts
/// everything — pins the bonus-token path).
struct OracleDrafter {
    script: Vec<u32>,
    prompt_len: usize,
}

impl Drafter for OracleDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        let produced = history.len() - self.prompt_len;
        let end = (produced + k).min(self.script.len());
        self.script.get(produced..end).map(|s| s.to_vec()).unwrap_or_default()
    }
    fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
    fn name(&self) -> &'static str {
        "oracle"
    }
}

/// Drafts the true continuation shifted by one — the first draft is
/// always rejected (pins the full-rollback path: one true token per
/// verify pass, every pass truncates).
struct AntiOracleDrafter {
    script: Vec<u32>,
    prompt_len: usize,
}

impl Drafter for AntiOracleDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        let produced = history.len() - self.prompt_len;
        let end = (produced + k).min(self.script.len());
        self.script
            .get(produced..end)
            .map(|s| s.iter().map(|&t| (t + 1) % 256).collect())
            .unwrap_or_default()
    }
    fn observe(&mut self, _p: &[u32], _a: usize, _v: &[u32]) {}
    fn name(&self) -> &'static str {
        "anti"
    }
}

/// A repetitive prompt (gives the ngram drafter something to find) —
/// distinct from `prompt_tokens`, which is the adversarial one.
fn repetitive_prompt(len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| 30 + (i % 3)).collect()
}

#[test]
fn spec_decode_token_identical_for_every_drafter_length_backend() {
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 51);
    let n = 18;
    for prompt in [repetitive_prompt(12), prompt_tokens(11, 9)] {
        for backend in BACKENDS {
            let want = with_store(backend, &cfg, |s| vanilla_greedy(&eng, s, &prompt, n));
            for k in [1usize, 2, 4, 8] {
                // Real drafters plus the two deterministic extremes.
                let mut drafters: Vec<(&str, Box<dyn Drafter>)> = vec![
                    ("ngram", DrafterKind::Ngram.build()),
                    ("self", DrafterKind::SelfDraft.build()),
                    (
                        "oracle",
                        Box::new(OracleDrafter {
                            script: want.clone(),
                            prompt_len: prompt.len(),
                        }),
                    ),
                    (
                        "anti",
                        Box::new(AntiOracleDrafter {
                            script: want.clone(),
                            prompt_len: prompt.len(),
                        }),
                    ),
                ];
                for (name, drafter) in drafters.iter_mut() {
                    let run: SpecRun = with_store(backend, &cfg, |s| {
                        run_greedy(&eng, s, &prompt, n, drafter.as_mut(), k)
                    });
                    assert_eq!(
                        run.tokens, want,
                        "{name} k={k} {backend:?}: speculative tokens diverged"
                    );
                    match *name {
                        "oracle" => {
                            assert!(run.drafted > 0);
                            assert_eq!(
                                run.accepted, run.drafted,
                                "oracle drafts must all be accepted"
                            );
                        }
                        "anti" => {
                            assert!(run.drafted > 0);
                            assert_eq!(run.accepted, 0, "anti-oracle drafts must all be rejected");
                        }
                        _ => {}
                    }
                }
            }
        }
    }
}

#[test]
fn spec_decode_token_identical_on_dense_weights() {
    // The dense (unquantized) engine takes the non-GEMM route through
    // the same verify pass; one smaller sweep pins it.
    let cfg = ModelConfig::test();
    let eng = dense_engine(53);
    let prompt = repetitive_prompt(10);
    for backend in [Backend::Dense, Backend::PagedF32(4)] {
        let want = with_store(backend, &cfg, |s| vanilla_greedy(&eng, s, &prompt, 14));
        for k in [2usize, 5] {
            let mut ngram = NgramDrafter::default();
            let mut selfd = SelfDraft::default();
            let drafters: [&mut dyn Drafter; 2] = [&mut ngram, &mut selfd];
            for d in drafters {
                let run =
                    with_store(backend, &cfg, |s| run_greedy(&eng, s, &prompt, 14, d, k));
                assert_eq!(run.tokens, want, "k={k} {backend:?} dense-weight run diverged");
            }
        }
    }
}

#[test]
fn score_tokens_bitwise_matches_sequential_on_every_backend() {
    // The verify pass's own contract, exercised through the paged
    // stores (the engine-level dense check lives in model/native.rs).
    let cfg = ModelConfig::test();
    for fmt in ["itq3_s", "q8_0"] {
        let eng = quant_engine(fmt, 57);
        let prompt = prompt_tokens(9, 3);
        let feed = [7u32, 19, 4, 2, 250];
        for backend in BACKENDS {
            let want = with_store(backend, &cfg, |s| {
                eng.prefill(s, &prompt);
                feed.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
            });
            let got = with_store(backend, &cfg, |s| {
                eng.prefill(s, &prompt);
                eng.score_tokens(s, &feed)
            });
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w, g, "{fmt} {backend:?}: position {i} logits diverged");
            }
        }
    }
}

#[test]
fn truncate_then_continue_matches_never_speculated_run() {
    // Rollback leaves no ghost state: write a junk span through
    // score_tokens, truncate it away, continue decoding — the
    // continuation must equal a run that never speculated, bit for
    // bit, on every backend.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 59);
    let prompt = prompt_tokens(10, 5);
    let junk = [201u32, 202, 203, 204];
    let cont = [17u32, 71];
    for backend in BACKENDS {
        let want = with_store(backend, &cfg, |s| {
            eng.prefill(s, &prompt);
            cont.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
        });
        let got = with_store(backend, &cfg, |s| {
            eng.prefill(s, &prompt);
            let base = s.len();
            eng.score_tokens(s, &junk);
            s.truncate(base);
            cont.iter().map(|&t| eng.decode_step(s, t)).collect::<Vec<_>>()
        });
        assert_eq!(want, got, "{backend:?}: rollback left ghost state");
    }
}
