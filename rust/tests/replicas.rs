//! Data-parallel replica serving: same-seed token identity against the
//! single-engine coordinator, prefix-affinity placement, and bounded
//! per-round prefill under a flood of long prompts.
//!
//! Replicas must be *invisible* in the token stream: a request's output
//! depends only on its own sampler and the (shared) weights, never on
//! which replica ran it or who shared its batch. These tests pin that
//! end to end for greedy, sampled, and speculative decoding.

mod common;

use itq3s::coordinator::{
    Coordinator, CoordinatorConfig, Event, FinishReason, GenRequest,
};
use itq3s::model::native::Engine;
use itq3s::util::json::Json;

fn replicated(n: usize, cfg: CoordinatorConfig) -> Coordinator {
    // Same seed per replica: identical weights, so placement cannot
    // change tokens (the real deployment shape — one checkpoint,
    // N engine instances).
    let engines: Vec<Box<dyn Engine>> = (0..n)
        .map(|_| Box::new(common::dense_engine(5)) as Box<dyn Engine>)
        .collect();
    Coordinator::new_replicated(engines, cfg)
}

/// Stream every request to completion, returning (text, gen_tokens)
/// per request in submission order.
fn collect_all(rxs: Vec<std::sync::mpsc::Receiver<Event>>) -> Vec<(String, usize)> {
    rxs.into_iter()
        .map(|rx| {
            let mut text = String::new();
            for ev in rx.iter() {
                match ev {
                    Event::Heartbeat => {}
                    Event::Token { text: t, .. } => text.push_str(&t),
                    Event::Done { gen_tokens, reason, .. } => {
                        assert_eq!(reason, FinishReason::MaxTokens);
                        return (text, gen_tokens);
                    }
                    Event::Error(e) => panic!("unexpected error: {e}"),
                }
            }
            panic!("stream ended without a terminal");
        })
        .collect()
}

/// A mixed greedy/sampled workload (fixed seeds) through an N-replica
/// coordinator; `spec_draft` switches speculative decoding on and
/// `audit_rate` switches sampled logit-drift shadow scoring on.
fn run_workload(n: usize, spec_draft: usize, audit_rate: f64) -> Vec<(String, usize)> {
    let c = replicated(
        n,
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            spec_draft_len: spec_draft,
            audit_sample_rate: audit_rate,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            c.generate(GenRequest {
                prompt: format!("determinism workload {i} abcabcabc"),
                max_new_tokens: 10,
                temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
                top_k: if i % 2 == 0 { None } else { Some(12) },
                seed: 1000 + i as u64,
                ..Default::default()
            })
        })
        .collect();
    let out = collect_all(rxs);
    c.shutdown();
    out
}

#[test]
fn replica_count_is_invisible_in_the_token_streams() {
    // N=1 is the reference (the pre-replica coordinator, bit for bit);
    // N=2 and N=4 must stream the same text per request across greedy,
    // sampled, and speculative decoding.
    for spec_draft in [0usize, 4] {
        let want = run_workload(1, spec_draft, 0.0);
        assert_eq!(want.len(), 6);
        for n in [2usize, 4] {
            let got = run_workload(n, spec_draft, 0.0);
            assert_eq!(
                got, want,
                "replicas={n} spec_draft={spec_draft}: token streams diverged from N=1"
            );
        }
    }
}

#[test]
fn audit_sampling_is_invisible_in_the_token_streams() {
    // Audit-off (rate 0.0, the default) must reproduce the pre-audit
    // baseline byte for byte, and audit-on (rate 1.0 — every decode
    // round shadow-scored) must too: the probe replays histories on
    // fresh scratch KV with its own schedule RNG, never touching a
    // sampler. Both across N∈{1, 2} replicas.
    let baseline = run_workload(1, 0, 0.0);
    assert_eq!(baseline.len(), 6);
    for n in [1usize, 2] {
        assert_eq!(
            run_workload(n, 0, 0.0),
            baseline,
            "replicas={n}: audit-off streams diverged from the baseline"
        );
        assert_eq!(
            run_workload(n, 0, 1.0),
            baseline,
            "replicas={n}: audit-on streams diverged — the shadow probe leaked state"
        );
    }

    // And audit-on really probes: the merged stats of a replicated
    // audited run accumulate shadow rounds from the replica shards.
    let c = replicated(
        2,
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            audit_sample_rate: 1.0,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            c.generate(GenRequest {
                prompt: format!("audited workload {i}"),
                max_new_tokens: 6,
                ..Default::default()
            })
        })
        .collect();
    collect_all(rxs);
    let stats = c.stats().unwrap();
    assert!(
        stats.get("audit_rounds").unwrap().as_u64().unwrap() >= 1,
        "rate 1.0 must record shadow probes"
    );
    c.shutdown();
}

/// Fish the completed timeline with `id` out of the `trace` op result.
fn timeline_by_id(timelines: &Json, id: u64) -> Json {
    timelines
        .as_arr()
        .unwrap()
        .iter()
        .find(|t| t.get("id").unwrap().as_u64() == Some(id))
        .unwrap_or_else(|| panic!("no timeline for request {id}"))
        .clone()
}

/// The replica stamped into a timeline's (last) admitted event.
fn admitted_replica(timeline: &Json) -> u64 {
    timeline
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .rev()
        .find(|e| e.get("what").unwrap().as_str() == Some("admitted"))
        .expect("timeline has an admitted event")
        .get("replica")
        .unwrap()
        .as_u64()
        .unwrap()
}

#[test]
fn placement_prefers_the_replica_holding_the_cached_prefix() {
    let c = replicated(
        2,
        CoordinatorConfig {
            max_batch: 4,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 16,
            ..Default::default()
        },
    );
    let warm_prompt = "w".repeat(300); // truncated to ~62 tokens
    // Request 1: first ever, both replicas idle and cold -> replica 0
    // (lowest id tie-break). Its prefix is cached there on release.
    let (_, done) = c.generate_collect(GenRequest {
        prompt: warm_prompt.clone(),
        max_new_tokens: 2,
        trace: true,
        ..Default::default()
    });
    assert!(matches!(done, Some(Event::Done { .. })));
    // Request 2: distinct prompt, lands on replica 0 too (idle again).
    // It runs long, so replica 0 is *busier* when request 3 arrives.
    let busy = c.generate(GenRequest {
        prompt: "completely different busy work".into(),
        max_new_tokens: 40,
        trace: true,
        ..Default::default()
    });
    // Request 3: shares the warm prefix. Affinity must beat load:
    // replica 0 (prefix hit, one active) over replica 1 (idle, cold).
    let (_, done) = c.generate_collect(GenRequest {
        prompt: warm_prompt,
        max_new_tokens: 2,
        trace: true,
        ..Default::default()
    });
    assert!(matches!(done, Some(Event::Done { .. })));
    for _ in busy.iter() {} // drain request 2
    let timelines = c.trace(16).unwrap();
    let warm = timeline_by_id(&timelines, 1);
    let repeat = timeline_by_id(&timelines, 3);
    assert_eq!(admitted_replica(&warm), 0, "first request seeds replica 0");
    assert_eq!(
        admitted_replica(&repeat),
        0,
        "prefix affinity must outrank the load tie-break"
    );
    let reused = repeat
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|e| e.get("what").unwrap().as_str() == Some("admitted"))
        .unwrap()
        .get("prefix_reused")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(reused > 0, "repeat prompt must map cached prefix blocks, got {reused}");
    c.shutdown();
}

#[test]
fn prefill_flood_is_budgeted_while_decode_continues_elsewhere() {
    // Budget 6 < chunk 8: with the budget on, NO prefill chunk may
    // exceed 6 tokens, and two co-resident prefilling sequences cannot
    // both ingest in one round (6 < 2 chunks) — the flood serializes
    // on its replica while short requests decode on the other one.
    let c = replicated(
        2,
        CoordinatorConfig {
            max_batch: 4,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 8,
            prefill_round_budget: 6,
            ..Default::default()
        },
    );
    // ~41 prompt tokens: long enough to cache whole prefix blocks and
    // need several budgeted rounds, short enough that the ` tail {i}`
    // suffixes and 3 decode tokens fit under the 64-token context cap.
    let flood_prompt = "f".repeat(40);
    // Warm replica 0 so the flood has prefix affinity to it.
    let (_, done) = c.generate_collect(GenRequest {
        prompt: flood_prompt.clone(),
        max_new_tokens: 1,
        ..Default::default()
    });
    assert!(matches!(done, Some(Event::Done { .. })));
    // The flood: three long warm-prefixed prompts (requests 2-4), all
    // placed on replica 0 by the prefix probe...
    let flood: Vec<_> = (0..3)
        .map(|i| {
            c.generate(GenRequest {
                prompt: format!("{flood_prompt} tail {i}"),
                max_new_tokens: 3,
                trace: true,
                ..Default::default()
            })
        })
        .collect();
    // ...while short fresh prompts (requests 5-6) go to replica 1 (no
    // prefix hit anywhere -> least loaded) and keep decoding there.
    let shorts: Vec<_> = (0..2)
        .map(|i| {
            c.generate(GenRequest {
                prompt: format!("short decode {i}"),
                max_new_tokens: 6,
                trace: true,
                ..Default::default()
            })
        })
        .collect();
    for rx in shorts {
        let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
        let Some(Event::Done { reason, gen_tokens, .. }) = done else { panic!("no done") };
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(gen_tokens, 6, "short requests must decode to completion");
    }
    for rx in flood {
        let done = rx.iter().find(|e| matches!(e, Event::Done { .. }));
        assert!(
            matches!(done, Some(Event::Done { reason: FinishReason::MaxTokens, .. })),
            "flooded prefills must still finish"
        );
    }
    let timelines = c.trace(16).unwrap();
    for id in 2..=4u64 {
        let t = timeline_by_id(&timelines, id);
        assert_eq!(admitted_replica(&t), 0, "flood request {id} must follow its prefix");
        for ev in t.get("events").unwrap().as_arr().unwrap() {
            if ev.get("what").unwrap().as_str() == Some("prefill_chunk") {
                let tokens = ev.get("tokens").unwrap().as_u64().unwrap();
                assert!(
                    tokens <= 6,
                    "request {id}: prefill chunk of {tokens} exceeds the round budget of 6"
                );
            }
        }
    }
    for id in 5..=6u64 {
        let t = timeline_by_id(&timelines, id);
        assert_eq!(
            admitted_replica(&t),
            1,
            "short request {id} must land on the unflooded replica"
        );
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("replicas").unwrap().as_u64(), Some(2));
    let per = stats.get("per_replica").unwrap().as_arr().unwrap();
    assert_eq!(per.len(), 2);
    let finished: u64 =
        per.iter().map(|p| p.get("requests_finished").unwrap().as_u64().unwrap()).sum();
    assert_eq!(finished, 6, "per-replica finishes must cover all six requests");
    c.shutdown();
}

#[test]
fn prefill_round_budget_is_inert_on_one_replica_by_default() {
    // Defaults (budget 0 = unbounded) must reproduce the pre-budget
    // chunking exactly: a ~62-token prompt with chunk 16 ingests
    // 16/16/16/14 — visible in its trace timeline.
    let c = replicated(
        1,
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 16,
            ..Default::default()
        },
    );
    let (_, done) = c.generate_collect(GenRequest {
        prompt: "p".repeat(300),
        max_new_tokens: 2,
        trace: true,
        ..Default::default()
    });
    assert!(matches!(done, Some(Event::Done { .. })));
    let timelines = c.trace(4).unwrap();
    let t = timeline_by_id(&timelines, 1);
    let chunks: Vec<u64> = t
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("what").unwrap().as_str() == Some("prefill_chunk"))
        .map(|e| e.get("tokens").unwrap().as_u64().unwrap())
        .collect();
    assert_eq!(chunks, vec![16, 16, 16, 14], "unbudgeted chunking must be flat");
    c.shutdown();
}
