//! End-to-end serving integration over the real trained checkpoint when
//! artifacts exist, falling back to a random model otherwise: quantize →
//! coordinator → TCP server → concurrent clients → consistent results.

mod common;

use common::quant_fixture;
use itq3s::coordinator::{CoordinatorConfig, Event, FinishReason, GenRequest};
use itq3s::model::NativeEngine;
use itq3s::server;
use itq3s::util::json::Json;

/// The shared serving fixture (trained checkpoint when artifacts
/// exist, deterministic random model otherwise), quantized to itq3_s.
fn test_engine() -> NativeEngine {
    quant_fixture("itq3_s", 11)
}

#[test]
fn quantized_model_serves_coherent_text() {
    let engine = test_engine();
    let trained = common::have_artifacts();
    let coord = itq3s::coordinator::Coordinator::new(
        Box::new(engine),
        CoordinatorConfig {
            max_batch: 2,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 16,
            ..Default::default()
        },
    );
    let (text, done) = coord.generate_collect(GenRequest {
        prompt: "the archive of ".into(),
        max_new_tokens: 24,
        ..Default::default()
    });
    let Some(Event::Done { reason, gen_tokens, .. }) = done else { panic!("no done") };
    assert_eq!(reason, FinishReason::MaxTokens);
    assert_eq!(gen_tokens, 24);
    if trained {
        // A trained 3-bit model must produce ascii words from the corpus
        // distribution, not byte noise.
        assert!(
            text.bytes().all(|b| b.is_ascii()),
            "expected ascii continuation, got {text:?}"
        );
        assert!(text.contains(' '), "expected words, got {text:?}");
    }
    coord.shutdown();
}

#[test]
fn tcp_serving_full_stack() {
    let engine = test_engine();
    let (addr, handle) = server::spawn_ephemeral(
        Box::new(engine),
        CoordinatorConfig {
            max_batch: 4,
            kv_budget_bytes: 64 << 20,
            prefill_chunk: 16,
            ..Default::default()
        },
    )
    .unwrap();
    let addrs = addr.to_string();

    // Concurrent clients with interleaved generations.
    let threads: Vec<_> = (0..3)
        .map(|i| {
            let a = addrs.clone();
            std::thread::spawn(move || {
                let mut c = server::Client::connect(&a).unwrap();
                let done = c.generate(&format!("prompt {i} says "), 8).unwrap();
                assert_eq!(done.get("gen_tokens").unwrap().as_u64(), Some(8));
                assert!(done.get("total_ms").unwrap().as_f64().unwrap() > 0.0);
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let mut c = server::Client::connect(&addrs).unwrap();
    c.send(&Json::obj(vec![
        ("op", Json::str("score")),
        ("text", Json::str("the ledger of the old harbor was restored. ")),
    ]))
    .unwrap();
    let score = c.recv().unwrap();
    let ppl = score.get("ppl").unwrap().as_f64().unwrap();
    assert!(ppl.is_finite() && ppl > 1.0);

    c.send(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    let stats = c.recv().unwrap();
    assert_eq!(stats.get("requests_finished").unwrap().as_u64(), Some(3));
    assert!(stats.get("kv_peak_bytes").unwrap().as_f64().unwrap() > 0.0);

    c.send(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
    let _ = c.recv();
    handle.join().unwrap().unwrap();
}

#[test]
fn greedy_generation_is_reproducible_across_servers() {
    let run = || {
        let engine = test_engine();
        let coord = itq3s::coordinator::Coordinator::new(
            Box::new(engine),
            CoordinatorConfig::default(),
        );
        let (text, _) = coord.generate_collect(GenRequest {
            prompt: "merek studied the".into(),
            max_new_tokens: 12,
            ..Default::default()
        });
        coord.shutdown();
        text
    };
    assert_eq!(run(), run());
}
